#!/usr/bin/env python
"""Diff fresh benchmark JSON output against committed baselines.

Usage::

    python scripts/bench_diff.py --fresh bench-results \
        [--baselines benchmarks/baselines] [name ...]

For every ``BENCH_<name>.json`` in the baseline directory (or the names
given), the fresh run must:

* produce exactly the same set of ``(param, metric)`` rows — a vanished
  or newly appearing row means the benchmark's coverage silently changed;
* match **exactly** on invariant metrics (replica-hit purity, commit-
  protocol survival, trace-replay identity) — these are pass/fail
  determinism guarantees, not measurements;
* stay finite and non-negative on everything else — timing metrics drift
  with machine load even on the virtual clock (thread interleaving), so
  their values are tracked by the artifact trail, not gated here.

Exit status is non-zero on any mismatch, so CI can gate on it.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, Tuple

# metrics whose values are deterministic invariants — compared exactly
EXACT_METRICS = {
    "chunks_reuploaded",
    "survived",
    "replay_identical",
    "all_ok",
    "restore_extra_fetches",      # gang reshard: single-flight CAS reads
    "restored_ranks",             # gang shrink lands on exactly the floor
    "restore_bitexact",           # async device path restores losslessly
    "floor3x_ok",                 # device-exit byte cut (deterministic)
    "floor5x_ok",                 # staged-capture stall cut vs sync save
    "telemetry_detected",         # slowdowns caught by the EWMA watchdog
    "overhead_ok",                # telemetry cost on the ckpt path < 5%
    "pooled_beats_static",        # fleet wins p99 AND qps/host vs static
    "coldstart_reuploads",        # adoption cold starts write 0 objects
    "tokens_bitexact",            # suspend-mid-decode stream is identical
}


def _load(path: str) -> Dict[Tuple[str, str], float]:
    with open(path) as f:
        data = json.load(f)
    return {(r["param"], r["metric"]): r["value"] for r in data["rows"]}


def diff_one(name: str, base_dir: str, fresh_dir: str) -> int:
    fname = f"BENCH_{name}.json"
    base_path = os.path.join(base_dir, fname)
    fresh_path = os.path.join(fresh_dir, fname)
    if not os.path.exists(fresh_path):
        print(f"FAIL {name}: fresh run produced no {fname}")
        return 1
    base, fresh = _load(base_path), _load(fresh_path)
    errors = 0
    missing = sorted(set(base) - set(fresh))
    extra = sorted(set(fresh) - set(base))
    for param, metric in missing:
        print(f"FAIL {name}: row disappeared: {param},{metric}")
        errors += 1
    for param, metric in extra:
        print(f"FAIL {name}: unexpected new row: {param},{metric} "
              f"(regenerate the baseline if intentional)")
        errors += 1
    for key in sorted(set(base) & set(fresh)):
        param, metric = key
        bval, fval = base[key], fresh[key]
        if metric in EXACT_METRICS:
            if bval != fval:
                print(f"FAIL {name}: {param},{metric} = {fval} "
                      f"(baseline {bval}) — invariant metric drifted")
                errors += 1
        elif not math.isfinite(fval) or fval < 0:
            print(f"FAIL {name}: {param},{metric} = {fval} not a sane value")
            errors += 1
    if not errors:
        print(f"ok   {name}: {len(base)} rows match "
              f"({sum(1 for _, m in base if m in EXACT_METRICS)} exact)")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baselines", default="benchmarks/baselines")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("names", nargs="*",
                    help="benchmark names to diff (default: every baseline)")
    args = ap.parse_args()
    names = args.names or sorted(
        f[len("BENCH_"):-len(".json")]
        for f in os.listdir(args.baselines)
        if f.startswith("BENCH_") and f.endswith(".json"))
    if not names:
        print(f"no baselines found in {args.baselines}", file=sys.stderr)
        sys.exit(2)
    errors = sum(diff_one(n, args.baselines, args.fresh) for n in names)
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
