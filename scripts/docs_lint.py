"""Docs sanity check (make docs-lint).

Verifies the project docs exist and that every backtick-quoted file
reference in them points at a real file — READMEs rot fastest through
renamed modules, so dangling references fail the build.
"""
import pathlib
import re
import sys

DOCS = ("README.md", "docs/architecture.md")
ROOTS = ("", "src/repro/", "src/")


def main() -> int:
    bad = 0
    for doc in DOCS:
        p = pathlib.Path(doc)
        if not p.is_file():
            print(f"missing required doc: {doc}")
            bad = 1
            continue
        text = p.read_text()
        for ref in re.findall(r"`([\w./-]+\.(?:py|md))`", text):
            if not any(pathlib.Path(root + ref).exists() for root in ROOTS):
                print(f"{doc}: dangling file reference {ref!r}")
                bad = 1
    if not bad:
        print("docs-lint OK")
    return bad


if __name__ == "__main__":
    sys.exit(main())
