#!/usr/bin/env python
"""Summarize a JSONL span export (repro.obs.trace.Tracer.export_jsonl).

Usage::

    python scripts/trace_view.py trace.jsonl [--trace tr-job-0000]
                                             [--cat ckpt] [--tree]

Default output is one row per (cat, name): span count, total/mean/max
duration in paper-seconds, plus how many distinct trace_ids touched it.
``--tree`` instead prints each trace_id's spans nested by parent, in
start order — the save pin→encode→upload→commit lifecycle reads top to
bottom. Both views work on the deterministic canonical export, so two
seeded runs summarize identically.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List


def load(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def summarize(rows: List[Dict[str, Any]]) -> str:
    stats: Dict[tuple, Dict[str, Any]] = defaultdict(
        lambda: {"n": 0, "total": 0.0, "max": 0.0, "traces": set()})
    for r in rows:
        st = stats[(r.get("cat", ""), r["name"])]
        st["n"] += 1
        st["total"] += r.get("dur", 0.0)
        st["max"] = max(st["max"], r.get("dur", 0.0))
        st["traces"].add(r.get("trace_id", ""))
    header = (f"{'cat':<12} {'name':<28} {'count':>6} {'total_s':>10} "
              f"{'mean_s':>10} {'max_s':>10} {'traces':>7}")
    lines = [header, "-" * len(header)]
    for (cat, name), st in sorted(stats.items()):
        mean = st["total"] / st["n"]
        lines.append(f"{cat:<12} {name:<28} {st['n']:>6} "
                     f"{st['total']:>10.4f} {mean:>10.4f} "
                     f"{st['max']:>10.4f} {len(st['traces']):>7}")
    lines.append(f"{len(rows)} spans")
    return "\n".join(lines)


def tree(rows: List[Dict[str, Any]]) -> str:
    by_id = {r["id"]: r for r in rows}
    kids: Dict[Any, List[Dict[str, Any]]] = defaultdict(list)
    for r in rows:
        parent = r.get("parent")
        kids[parent if parent in by_id else None].append(r)
    for children in kids.values():
        children.sort(key=lambda r: (r.get("trace_id", ""), r["ts"],
                                     r["id"]))
    lines: List[str] = []

    def walk(r: Dict[str, Any], depth: int) -> None:
        dur = r.get("dur", 0.0)
        tag = f"{dur:.4f}s" if dur > 0 else "·"
        lines.append(f"{'  ' * depth}{r['name']} [{r.get('cat', '')}] {tag}")
        for c in kids.get(r["id"], ()):
            walk(c, depth + 1)

    last_trace = object()
    for r in kids[None]:
        if r.get("trace_id", "") != last_trace:
            last_trace = r.get("trace_id", "")
            lines.append(f"== trace {last_trace or '(untraced)'} ==")
        walk(r, 1)
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="JSONL span export")
    ap.add_argument("--trace", default=None, help="filter by trace_id")
    ap.add_argument("--cat", default=None, help="filter by category")
    ap.add_argument("--tree", action="store_true",
                    help="print spans nested by parent instead of the table")
    args = ap.parse_args()
    rows = load(args.path)
    if args.trace is not None:
        rows = [r for r in rows if r.get("trace_id") == args.trace]
    if args.cat is not None:
        rows = [r for r in rows if r.get("cat") == args.cat]
    if not rows:
        print("no spans match", file=sys.stderr)
        sys.exit(1)
    print(tree(rows) if args.tree else summarize(rows))


if __name__ == "__main__":
    main()
