#!/usr/bin/env python
"""Observability smoke: one seeded run, one correlated trace (ISSUE 9).

Runs the full telemetry loop on the discrete-event ``SimClock``:

  1. a GlobalScheduler places one job (sched/submit + placement events);
  2. an explicit checkpoint drives the save lifecycle — pin, encode,
     upload, manifest, commit spans;
  3. a degraded host starves the job until the throughput-EWMA watchdog
     (NOT the liveness path — the straggler check is disabled) reports
     low performance and the app manager proactively suspends it.

Every one of those records carries the job's deterministic trace_id; the
script hard-verifies the correlation, then exports the trace as JSONL
(for scripts/trace_view.py) and Chrome trace-event JSON (open in
https://ui.perfetto.dev). CI runs this via ``make obs-smoke`` and
uploads the exports as artifacts. Exit status is non-zero on any
missing span, so it doubles as a regression gate.

Usage::

    PYTHONPATH=src python scripts/obs_smoke.py [--out-dir obs-artifacts]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))

from repro.clusters import SnoozeBackend
from repro.core.application import SimulatedApp
from repro.core.coordinator import ASR, CheckpointPolicy, CoordState
from repro.core.monitoring import LowPerfConfig
from repro.core.scheduler import GlobalScheduler
from repro.core.service import CACSService
from repro.obs import (MetricsRegistry, Tracer, use_registry, use_tracer)
from repro.sim import SimClock, use_clock

# the save path must show this lifecycle, the monitor its detection, the
# scheduler its placement decision — all under ONE trace_id
REQUIRED_SPANS = (
    ("ckpt", "ckpt/pin"),
    ("ckpt", "ckpt/save"),
    ("ckpt", "ckpt/encode"),
    ("ckpt", "ckpt/upload"),
    ("ckpt", "ckpt/manifest"),
    ("ckpt", "ckpt/commit"),
    ("sched", "sched/submit"),
    ("monitor", "monitor/poll"),
    ("monitor", "monitor/low_performance"),
)


def run(out_dir: str) -> int:
    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({backend.name: backend})
    svc.apps.monitor.straggler_threshold = float("inf")
    svc.apps.monitor.poll_interval_s = 0.01
    svc.apps.monitor.lowperf = LowPerfConfig(warmup_samples=2)
    sched = GlobalScheduler(svc)           # synchronous ticks (no thread)
    svc.attach_scheduler(sched)
    asr = ASR(name="obs-smoke", n_vms=2, backend=backend.name,
              app_factory=lambda: SimulatedApp(iter_time_s=0.4,
                                               state_mb=0.05),
              policy=CheckpointPolicy(period_s=0.0))
    cid = sched.submit(asr)
    try:
        coord = svc.wait_for_state(cid, CoordState.RUNNING, timeout=60)
        trace_id = coord.trace_id
        step = svc.trigger_checkpoint(cid)
        print(f"committed step {step} for {cid} ({trace_id})")
        # starve the job: 40x steps drop throughput well past the
        # degradation factor; the EWMA watchdog must suspend it
        backend.sim.degrade_host(coord.vms[0].host.host_id, 40.0)
        svc.wait_for_state(cid, CoordState.SUSPENDED, timeout=60)
        reason = next((r[2] for r in coord.history
                       if r[1] == "SUSPENDED" and len(r) > 2), "")
        print(f"suspended via {reason!r}")
        if reason != "low_performance":
            print(f"FAIL: suspend reason {reason!r}, expected telemetry "
                  f"detection (low_performance)")
            return 1
    finally:
        svc.shutdown()
    return verify_and_export(trace_id, out_dir)


def verify_and_export(trace_id: str, out_dir: str) -> int:
    from repro.obs import tracer
    tr = tracer()
    errors = 0
    for cat, name in REQUIRED_SPANS:
        n = len(tr.spans(cat=cat, trace_id=trace_id, name=name))
        mark = "ok  " if n else "FAIL"
        print(f"{mark} {cat:<8} {name:<26} x{n} [{trace_id}]")
        errors += int(n == 0)
    os.makedirs(out_dir, exist_ok=True)
    jsonl = os.path.join(out_dir, "obs_smoke.trace.jsonl")
    chrome = os.path.join(out_dir, "obs_smoke.chrome.json")
    n = tr.export_jsonl(jsonl)
    tr.export_chrome(chrome)
    print(f"exported {n} spans -> {jsonl}")
    print(f"Perfetto view: load {chrome} at https://ui.perfetto.dev")
    if errors:
        print(f"FAIL: {errors} required span kinds missing")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="obs-artifacts")
    args = ap.parse_args()
    clk = SimClock()
    try:
        with use_clock(clk), use_registry(MetricsRegistry()), \
                use_tracer(Tracer()):
            errors = run(args.out_dir)
    finally:
        clk.close()
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
