"""Job swapping in an over-subscribed, *cloud-spanning* deployment
(paper use case 2): low-priority jobs are checkpointed to stable storage
when a high-priority job needs their VMs — and, when their images are
replicated to a standby cloud, they resume THERE with zero chunk
re-uploads instead of waiting for home capacity.

    PYTHONPATH=src python examples/job_swapping.py
"""
import time

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        GlobalScheduler, ImageReplicator, ReplicationPolicy,
                        SimulatedApp, StandbyTarget)


def state_of(svc, cids):
    return {svc.db.get(c).asr.name:
            f"{svc.db.get(c).state.value}@{svc.db.get(c).asr.backend}"
            for c in cids}


def main() -> None:
    snooze = SnoozeBackend(n_hosts=8)
    openstack = OpenStackBackend(n_hosts=4)
    store_a, store_b = InMemoryStore(), InMemoryStore()
    svc = CACSService({"snooze": snooze, "openstack": openstack},
                      {"default": store_a, "standby": store_b})
    replicator = ImageReplicator(svc)
    replicator.add_target(StandbyTarget("openstack", store=store_b,
                                        backend="openstack"))
    svc.attach_replicator(replicator)
    sched = GlobalScheduler(svc, cloud_stores={"snooze": "default",
                                               "openstack": "standby"})
    svc.attach_scheduler(sched)
    sched.start()
    replicator.start()

    def make_asr(name, n_vms, priority, **kw):
        return ASR(name=name, n_vms=n_vms, backend="snooze",
                   priority=priority,
                   app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                                    state_mb=0.05),
                   policy=CheckpointPolicy(period_s=0.5, keep_last=2), **kw)

    low = [sched.submit(make_asr(f"batch-{i}", 4, priority=1))
           for i in range(2)]
    for cid in low:
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=60)
        replicator.watch(cid, ReplicationPolicy(targets=("openstack",)))
        svc.trigger_checkpoint(cid)
    print(f"[swap] 2 low-priority jobs running on snooze; idle hosts: "
          f"snooze={snooze.capacity()} openstack={openstack.capacity()}")

    print("[swap] submitting URGENT job needing all 8 snooze VMs ...")
    hi = sched.submit(make_asr("urgent", 8, priority=10,
                               clouds=("snooze",)))
    svc.wait_for_state(hi, CoordState.RUNNING, timeout=60)
    print(f"[swap] states: {state_of(svc, low + [hi])} "
          f"(preemptions={sched.preemptions})")

    # one victim backfills onto the standby cloud the moment its swap-out
    # image finishes replicating (event-driven; the other waits for home)
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline and sched.backfills < 1:
        time.sleep(0.1)
    print(f"[swap] after backfill: {state_of(svc, low)} "
          f"(backfills={sched.backfills}, "
          f"chunks re-uploaded={sched.backfill_reuploads})")
    assert sched.backfills >= 1 and sched.backfill_reuploads == 0

    print("[swap] urgent job done — terminating it")
    svc.delete_coordinator(hi)
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        if all(svc.db.get(c).state == CoordState.RUNNING for c in low):
            break
        time.sleep(0.1)
    print(f"[swap] states after resume: {state_of(svc, low)} "
          f"(resumes={sched.resumes})")
    for c in low:
        coord = svc.db.get(c)
        print(f"[swap]   {coord.asr.name}: iteration={coord.app.iteration} "
              f"on {coord.asr.backend} (progress preserved across swaps)")
        assert coord.app.iteration > 0
    print("[swap] decision trace:")
    for seq, op, name, backend, detail, trace_id in sched.decision_trace():
        print(f"[swap]   {seq:3d} {op:14s} {name:10s} {backend} "
              f"{detail} {trace_id}")
    sched.stop()
    replicator.stop()
    svc.shutdown()


if __name__ == "__main__":
    main()
