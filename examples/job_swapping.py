"""Job swapping in an over-subscribed cloud (paper use case 2):
low-priority jobs are checkpointed to stable storage when a high-priority
job needs their VMs, and resume automatically when it finishes.

    PYTHONPATH=src python examples/job_swapping.py
"""
import time

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        PriorityScheduler, SimulatedApp)


def state_of(svc, cids):
    return {svc.db.get(c).asr.name: svc.db.get(c).state.value for c in cids}


def main() -> None:
    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    sched = PriorityScheduler(svc, "snooze")
    sched.start()

    def make_asr(name, n_vms, priority):
        return ASR(name=name, n_vms=n_vms, backend="snooze",
                   priority=priority,
                   app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                                    state_mb=0.05),
                   policy=CheckpointPolicy(period_s=0.5, keep_last=2))

    low = [sched.submit(make_asr(f"batch-{i}", 4, priority=1))
           for i in range(2)]
    for cid in low:
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=60)
    print(f"[swap] 2 low-priority jobs running; idle hosts: "
          f"{backend.capacity()}")

    print("[swap] submitting URGENT job needing 6 VMs ...")
    hi = sched.submit(make_asr("urgent", 6, priority=10))
    svc.wait_for_state(hi, CoordState.RUNNING, timeout=60)
    print(f"[swap] states: {state_of(svc, low + [hi])} "
          f"(preemptions={sched.preemptions})")
    assert any(svc.db.get(c).state == CoordState.SUSPENDED for c in low)

    time.sleep(1.0)
    print("[swap] urgent job done — terminating it")
    svc.delete_coordinator(hi)
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        if all(svc.db.get(c).state == CoordState.RUNNING for c in low):
            break
        time.sleep(0.1)
    print(f"[swap] states after resume: {state_of(svc, low)} "
          f"(resumes={sched.resumes})")
    for c in low:
        coord = svc.db.get(c)
        print(f"[swap]   {coord.asr.name}: iteration={coord.app.iteration} "
              f"(progress preserved across the swap)")
        assert coord.app.iteration > 0
    sched.stop()
    svc.shutdown()


if __name__ == "__main__":
    main()
