"""End-to-end driver: train the ~100M-param ``repro-100m`` config for a few
hundred steps under full CACS management — periodic async checkpoints with
int8+zlib-compressed images, health monitoring, and a mid-run host failure
with automatic recovery.

    PYTHONPATH=src python examples/train_e2e.py            # full (~100M)
    PYTHONPATH=src python examples/train_e2e.py --quick    # reduced config

The full run is CPU-heavy (a real 100M-param model); --quick exercises the
identical control plane on the reduced config in ~2 minutes.
"""
import argparse
import dataclasses
import time

from repro.ckpt import InMemoryStore, LocalFSStore, TwoTierStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.train import AdamWConfig, TrainerApp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    if args.quick:
        cfg = dataclasses.replace(reduced(get_config("repro-100m")),
                                  dtype="float32")
        steps = args.steps or 120
        batch, seq = args.batch or 4, args.seq or 64
    else:
        cfg = dataclasses.replace(get_config("repro-100m"), dtype="float32")
        steps = args.steps or 300
        batch, seq = args.batch or 8, args.seq or 256
    print(f"[e2e] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps, batch {batch} x seq {seq}")

    # two-tier image store: fast local tier + durable "remote" tier
    store = TwoTierStore(InMemoryStore(), LocalFSStore(args.ckpt_dir))
    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({"snooze": backend}, {"default": store})

    asr = ASR(
        name="e2e-train", n_vms=4, backend="snooze",
        app_factory=lambda: TrainerApp(
            cfg, global_batch=batch, seq_len=seq, n_steps=steps,
            opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps)),
        policy=CheckpointPolicy(period_s=15.0, codec="zlib", keep_last=3),
    )
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=600)
    coord = svc.db.get(cid)
    print(f"[e2e] RUNNING on {[vm.vm_id for vm in coord.vms]}")

    failed = False
    t0 = time.monotonic()
    while not coord.app.is_done():
        time.sleep(5.0)
        s = coord.app.current_step
        if coord.app.step_times:
            sps = 1.0 / max(1e-9, sum(coord.app.step_times[-10:]) /
                            min(10, len(coord.app.step_times)))
        else:
            sps = 0.0
        print(f"[e2e] t={time.monotonic()-t0:6.1f}s step={s:4d}/{steps} "
              f"loss={coord.app.last_loss:.4f} {sps:.2f} steps/s "
              f"images={svc.list_checkpoints(cid)} "
              f"recoveries={coord.recoveries}")
        if args.inject_failure and not failed and s > steps // 3 \
                and svc.list_checkpoints(cid):
            print(f"[e2e] !!! injecting host failure at step {s}")
            backend.sim.fail_host(coord.vms[0].host.host_id)
            failed = True

    print(f"[e2e] done: step {coord.app.current_step}, "
          f"final loss {coord.app.last_loss:.4f}, "
          f"recoveries {coord.recoveries}, "
          f"first->last loss {coord.app.losses[0]:.3f} -> "
          f"{coord.app.losses[-1]:.3f}")
    assert coord.app.losses[-1] < coord.app.losses[0], "no learning?"
    if args.inject_failure:
        assert coord.recoveries >= 1, "failure was not recovered"
    svc.shutdown()
    store.close()
    print("[e2e] OK")


if __name__ == "__main__":
    main()
