"""Cross-cloud migration of a live training job (paper §5.3 / §7.3.2):
checkpoint on a Snooze-like cloud, restart on an OpenStack-like cloud with a
DIFFERENT virtual-cluster size — the trajectory continues bit-exactly.

    PYTHONPATH=src python examples/cloud_migration.py
"""
import dataclasses
import time

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        migrate)
from repro.train import TrainerApp

CFG = dataclasses.replace(reduced(get_config("granite-8b")), dtype="float32")
N_STEPS = 40


def main() -> None:
    shared_ceph = InMemoryStore()           # one Ceph instance, two clouds
    snooze = CACSService({"snooze": SnoozeBackend(8)},
                         {"default": shared_ceph})
    ostack = CACSService({"openstack": OpenStackBackend(8)},
                         {"default": shared_ceph})

    asr = ASR(name="migrating-train", n_vms=4, backend="snooze",
              app_factory=lambda: TrainerApp(CFG, global_batch=4, seq_len=64,
                                             n_steps=N_STEPS),
              policy=CheckpointPolicy(period_s=2.0, keep_last=2))
    cid = snooze.submit(asr)
    snooze.wait_for_state(cid, CoordState.RUNNING, timeout=120)
    coord = snooze.db.get(cid)
    while coord.app.current_step < N_STEPS // 3:
        time.sleep(0.2)
    print(f"[migrate] at step {coord.app.current_step} on snooze "
          f"({len(coord.vms)} VMs) — migrating to openstack (2 VMs)")

    res = migrate(snooze, cid, ostack, backend="openstack", n_vms=2)
    print(f"[migrate] checkpoint {res.checkpoint_s:.2f}s + transfer "
          f"{res.transfer_s:.2f}s + restart {res.restart_s:.2f}s "
          f"= {res.total_s:.2f}s")
    assert not snooze.list_coordinators(), "source must be terminated"

    c2 = ostack.db.get(res.dst_id)
    print(f"[migrate] resumed on openstack at step {c2.app.current_step}")
    while not c2.app.is_done():
        time.sleep(0.5)
    print(f"[migrate] finished on destination cloud: step "
          f"{c2.app.current_step}, loss {c2.app.last_loss:.4f}")
    snooze.shutdown()
    ostack.shutdown()


if __name__ == "__main__":
    main()
