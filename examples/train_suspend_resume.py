"""Train → suspend to an int8 swap-out image → resume: the device data
path end to end.

A real JAX training job runs under CACS with two codecs in play:

  * periodic/explicit checkpoints stay **lossless** (``codec="zlib"``) —
    restoring one resumes the exact optimizer trajectory;
  * the **suspend** image uses ``swap_codec="int8"``: the Pallas qsnap
    kernel quantizes the state on the accelerator, so the device-exit
    copy carries ~4x fewer bytes — the right trade for swap-out state
    that will be read back once, soon (over-subscription eviction).

Along the way the storyline shows what ``snapshot_async`` costs the
training loop (microseconds — compare ``app.ckpt_stalls`` with the
step time) and proves the lossless path is bit-exact by replaying the
suspended run against an uninterrupted reference.

    PYTHONPATH=src python examples/train_suspend_resume.py
"""
import dataclasses
import time

import numpy as np

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.train import AdamWConfig, TrainerApp


def main() -> None:
    cfg = dataclasses.replace(reduced(get_config("repro-100m")),
                              dtype="float32")
    steps, batch, seq = 40, 2, 64
    opt = AdamWConfig(lr=3e-4, warmup_steps=5, total_steps=steps)

    def make_app() -> TrainerApp:
        return TrainerApp(cfg, global_batch=batch, seq_len=seq,
                          n_steps=steps, opt=opt)

    # uninterrupted reference run (for the bit-exactness check at the end)
    print(f"[swap] reference run: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps")
    ref = make_app()
    ref.start(None, None)
    while not ref.is_done():
        time.sleep(0.1)
    ref.stop()

    store = InMemoryStore()
    svc = CACSService({"snooze": SnoozeBackend(n_hosts=4)},
                      {"default": store})
    asr = ASR(name="swap-train", n_vms=1, backend="snooze",
              app_factory=make_app,
              policy=CheckpointPolicy(period_s=0, codec="zlib",
                                      swap_codec="int8"))
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=600)
    coord = svc.db.get(cid)
    while coord.app.current_step < steps // 3:
        time.sleep(0.1)

    # explicit checkpoint: lossless image, staged capture (µs stall)
    ckpt_step = svc.trigger_checkpoint(cid)
    info = svc.apps.ckpt.image_info(coord, ckpt_step)
    print(f"[swap] explicit image: codec={info['codec']} "
          f"bytes={info['bytes']/1e6:.1f}MB "
          f"capture stall={coord.app.ckpt_stalls[-1]*1e6:.0f}µs "
          f"(step time {np.median(coord.app.step_times):.3f}s)")

    # suspend: the swap-out image goes through the on-device int8 encode
    print(f"[swap] suspending at step {coord.app.current_step}")
    svc.apps.suspend(cid)
    info = svc.apps.ckpt.image_info(coord, ckpt_step + 1)
    print(f"[swap] swap-out image: codec={info['codec']} "
          f"bytes={info['bytes']/1e6:.1f}MB")
    assert info["codec"] == "int8"

    # resume from the int8 image and train to completion
    svc.apps.resume(cid)
    coord = svc.db.get(cid)
    while not coord.app.is_done():
        time.sleep(0.1)
    print(f"[swap] resumed run done: step {coord.app.current_step}, "
          f"loss {coord.app.last_loss:.4f} "
          f"(reference {ref.last_loss:.4f}), "
          f"restarts {coord.app.restarts}")
    assert coord.app.restarts == 1
    assert np.isfinite(coord.app.last_loss)

    # the lossless path is bit-exact: replay the reference from the
    # explicit zlib image and compare against the uninterrupted run
    from repro.ckpt import restore
    snap, _ = restore(store, coord.ckpt_prefix, ckpt_step)
    replay = make_app()
    replay.start(None, snap)
    while not replay.is_done():
        time.sleep(0.1)
    replay.stop()
    assert replay.losses[-1] == ref.losses[-1], "lossless path diverged"
    print(f"[swap] bit-exact replay from the lossless image: "
          f"final loss {replay.losses[-1]:.6f} == reference")
    svc.shutdown()
    print("[swap] OK")


if __name__ == "__main__":
    main()
