"""Cross-cloud replication & standby failover, two acts.

Act 1 — warm standby survives a whole-cloud outage: a job runs on a
Snooze-like primary cloud while an ImageReplicator continuously ships
every committed checkpoint image to an OpenStack-like standby cloud
(separate object store). A seeded `cloud_outage` then partitions every
primary host at once — recovery on the home cloud is impossible by
construction — and the FailoverController restarts the job on the standby
from the newest *fully replicated* image, re-uploading zero chunks.

Act 2 — warm migration economics: with the standby kept warm, a planned
`clone` to that cloud moves only the unreplicated delta across the
inter-cloud link; the same clone to a cold cloud re-transfers everything.

    PYTHONPATH=src python examples/cross_cloud_failover.py [--seed N]
"""
import argparse

import numpy as np

from repro.ckpt.storage import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        ImageReplicator, ReplicationPolicy, SimulatedApp,
                        StandbyTarget, clone, run_failover_scenario)


class ShardedApp(SimulatedApp):
    """SimulatedApp whose checkpoint state is split into n shard leaves —
    a training step dirties a subset, so consecutive images share most of
    their content (what replication dedup and warm migration exploit)."""

    def __init__(self, n_shards: int = 8, total_mb: float = 8.0, **kw):
        super().__init__(state_mb=0.001, **kw)
        per = int(total_mb * 1024 * 1024 / 8 / n_shards)
        rng = np.random.Generator(np.random.PCG64(0))
        self.shards = [rng.standard_normal(per) for _ in range(n_shards)]

    def checkpoint_state(self):
        base = super().checkpoint_state()
        return {**base, **{f"shard{i:02d}": s
                           for i, s in enumerate(self.shards)}}


def act1_seeded_failover(seed: int) -> None:
    print(f"[failover] act 1: seeded whole-cloud outage (seed={seed})")
    res = run_failover_scenario(seed=seed, outage_at_s=20.0, period_s=0.05)
    fo = res.failover
    print(f"[failover]   outage at t={res.outage_at_s}s (virtual); primary "
          f"ended {res.primary_final_state}")
    print(f"[failover]   standby restarted from step {fo.step} "
          f"({res.standby_state}); MTTR {fo.mttr_s:.3f}s wall, "
          f"chunks re-uploaded: {fo.chunks_reuploaded}")
    print(f"[failover]   RPO: {fo.rpo_images} image(s), "
          f"{res.iterations_lost} iteration(s) lost "
          f"(restored {res.restored_iteration} / primary was at "
          f"{res.primary_iteration})")
    stats = res.replication["targets"]["standby"]
    print(f"[failover]   replication at failover time: "
          f"{stats['images_replicated']} images, "
          f"{stats['bytes_copied'] / 1e6:.2f} MB shipped, "
          f"{stats['bytes_skipped'] / 1e6:.2f} MB deduped")
    assert fo.ok and fo.chunks_reuploaded == 0
    print(f"[failover]   trace: {res.trace}")


def act2_warm_migration() -> None:
    print("[failover] act 2: warm vs cold migration of the same image")
    src_store = InMemoryStore(latency_s=0.002, bandwidth_bps=1e8)
    warm_store, cold_store = InMemoryStore(), InMemoryStore()
    src = CACSService({"snooze": SnoozeBackend(16)}, {"default": src_store})
    warm = CACSService({"openstack": OpenStackBackend(16)},
                       {"default": warm_store})
    cold = CACSService({"openstack": OpenStackBackend(16)},
                       {"default": cold_store})
    rep = ImageReplicator(src)
    try:
        cid = src.submit(ASR(
            name="warm-mig", n_vms=2, backend="snooze",
            app_factory=lambda: ShardedApp(8, 8.0, iter_time_s=0.2),
            policy=CheckpointPolicy(period_s=0.0)))
        src.wait_for_state(cid, CoordState.RUNNING, 60)
        src.trigger_checkpoint(cid)
        rep.add_target(StandbyTarget("warm", store=warm_store, service=warm,
                                     backend="openstack"))
        rep.watch(cid, ReplicationPolicy(targets=("warm",)))
        rep.sync()

        app = src.db.get(cid).app              # a training step dirties 2
        for i in range(2):                     # of the 8 shards
            app.shards[i] = app.shards[i] + 1e-3
        step = src.trigger_checkpoint(cid)     # the delta since replication
        for name, dst, store in (("cold", cold, cold_store),
                                 ("warm", warm, warm_store)):
            before = src_store.bytes_out
            res = clone(src, cid, dst, backend="openstack", step=step,
                        fresh_checkpoint=False)
            cross = (src_store.bytes_out - before) / 1e6
            local = store.dedup_stats()["replica_bytes_local"] / 1e6
            print(f"[failover]   {name}: transfer {res.transfer_s * 1e3:.1f} "
                  f"ms, {cross:.2f} MB cross-cloud, {local:.2f} MB from "
                  f"local replica")
    finally:
        rep.stop()
        for svc in (cold, warm, src):
            svc.shutdown()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()
    act1_seeded_failover(args.seed)
    act2_warm_migration()
    print("[failover] done")


if __name__ == "__main__":
    main()
