"""Virtual time tour: the same chaos scenario on the wall clock and on
the discrete-event SimClock (identical event trace, none of the wall
cost), then a thousand-host week on the pure SimEngine.

    PYTHONPATH=src python examples/virtual_time.py
"""
import time

from repro.core.chaos import FaultSchedule, run_scenario
from repro.sim import SimClock, SimEngine, use_clock


def scenario():
    # a seeded multi-fault storyline (see examples/fault_tolerance.py)
    return FaultSchedule.generate(seed=21, n_events=3)


def main() -> None:
    # 1. Baseline: the chaos harness on the wall clock — every fault
    #    offset and settle wait really sleeps (TIME_SCALE-compressed).
    t0 = time.monotonic()
    wall_res = run_scenario(scenario())
    wall_cost = time.monotonic() - t0
    print(f"[virtual-time] wall clock:   {wall_cost:5.2f}s wall, "
          f"{len(wall_res.trace)} trace events, all_ok={wall_res.all_ok}")

    # 2. Same scenario on SimClock: virtual time jumps straight to the
    #    next deadline, so the run costs only the actual control-plane
    #    work.  Ordering (the trace) is preserved.
    clk = SimClock()
    try:
        with use_clock(clk):
            t0 = time.monotonic()
            sim_res = run_scenario(scenario())
            sim_cost = time.monotonic() - t0
    finally:
        clk.close()
    print(f"[virtual-time] SimClock:     {sim_cost:5.2f}s wall, "
          f"{len(sim_res.trace)} trace events, all_ok={sim_res.all_ok}, "
          f"{clk.advances} time jumps")
    print(f"[virtual-time] traces identical: {wall_res.trace == sim_res.trace}")

    # 3. Scale: a simulated day over 1,000 hosts and 3,000 job
    #    lifecycles with Poisson host faults, on the pure event-loop
    #    engine.  Same seed -> byte-identical trace, any machine.
    t0 = time.monotonic()
    eng = SimEngine(n_hosts=1000, seed=7, host_mtbf_s=200_000.0)
    eng.load(n_jobs=3000, horizon_s=86_400.0)
    eng.run()
    cost = time.monotonic() - t0
    print(f"[virtual-time] SimEngine:    {cost:5.2f}s wall for "
          f"{eng.now / 3600:.1f} simulated hours on {eng.n_hosts} hosts — "
          f"{eng.events_fired} events, {eng.completed} jobs, "
          f"{eng.recoveries} fault recoveries")
    print(f"[virtual-time] trace digest: {eng.trace_digest()[:16]}")


if __name__ == "__main__":
    main()
