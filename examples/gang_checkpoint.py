"""Gang-consistent checkpointing of a multi-VM job, three acts.

Act 1 — a consistent cut of a live message-passing job: a 4-rank gang
exchanges messages over the simulated fabric while the two-phase
barrier (quiesce → drain → save → commit) snapshots all ranks plus
every in-flight message into ONE image. The conservation invariant
(sent == applied + in-flight) holds on the restored cut.

Act 2 — all-or-nothing under a mid-barrier fault: a rank's host dies
inside the drain phase. The epoch aborts, the torn step never becomes
visible, and the previous committed image still restores at full rank
count.

Act 3 — outage-driven elastic shrink: the gang's home cloud dies; the
GlobalScheduler reshards the 4-rank image onto the standby cloud's 2
surviving ranks (zero chunk re-uploads, every shared chunk fetched
exactly once) and the survivors resume from the cut.

Runs on the discrete-event virtual clock: tens of virtual seconds of
outage detection and recovery complete in a few wall seconds.

    PYTHONPATH=src python examples/gang_checkpoint.py
"""
import time
import types

from repro.ckpt.gang import GangCheckpointer, load_gang_ranks
from repro.ckpt.reader import list_steps
from repro.ckpt.storage import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.clusters.base import SimBackend, VMTemplate
from repro.clusters.simulator import ClusterSim
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        GangApp, GangBarrierError, GangCoordinator,
                        GlobalScheduler, gang_invariant)
from repro.core.chaos import VirtualClock
from repro.core.gang import GANG_ROUTED, GANG_SHARDED
from repro.sim import SimClock, active_clock, use_clock


def _wait(pred, timeout_s: float = 60.0) -> bool:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        active_clock().sleep(0.01)
    return False


def _harness(n_ranks=4, rows=16):
    sim = ClusterSim(n_ranks * 2, name="c0")
    backend = SimBackend(sim)
    vms = backend.allocate_vms(n_ranks, VMTemplate(), "gang")
    app = GangApp(global_rows=rows, iter_time_s=0.05)
    ctx = types.SimpleNamespace(coord_id="demo", vms=vms, service=None,
                                transport=sim)
    app.start(ctx, None)
    store = InMemoryStore()
    ck = GangCheckpointer(store, "apps/demo")
    coord = GangCoordinator(
        app, sim,
        lambda step, trees: ck.save(step, trees, sharded=GANG_SHARDED,
                                    routed=GANG_ROUTED),
        trace_id="tr-demo-0000")
    return sim, vms, app, store, coord


def act1_consistent_cut() -> None:
    print("[gang] act 1: consistent cut of a live message-passing job")
    sim, _, app, store, coord = _harness()
    try:
        active_clock().sleep(1.0)              # messages in flight
        coord.snapshot(1)
        trees, man, stats = load_gang_ranks(store, "apps/demo", n_ranks=4)
        inv = gang_invariant(trees)
        print(f"[gang]   committed epoch 1: {man.metadata['gang']['ranks']} "
              f"ranks, {int(inv['inflight'])} in-flight rows in the image")
        print(f"[gang]   conservation sent==applied+inflight: "
              f"{'OK' if inv['consistent'] == 1.0 else 'TORN'} "
              f"(sent={int(inv['sent'])}, applied={int(inv['applied'])})")
    finally:
        app.stop()


def act2_mid_barrier_crash() -> None:
    print("[gang] act 2: rank crash mid-drain aborts all-or-nothing")
    sim, vms, app, store, coord = _harness()
    try:
        active_clock().sleep(1.0)
        coord.snapshot(1)
        hid = vms[2].host.host_id
        coord.arm("drain", lambda: sim.fail_host(hid))
        try:
            coord.snapshot(2)
        except GangBarrierError as e:
            print(f"[gang]   epoch 2 aborted: {e.reason}")
        steps = list_steps(store, "apps/demo")
        print(f"[gang]   visible steps: {steps} (torn step 2 invisible)")
        trees, _, _ = load_gang_ranks(store, "apps/demo", n_ranks=4)
        ok = gang_invariant(trees)["consistent"] == 1.0
        print(f"[gang]   previous image restores consistent: "
              f"{'OK' if ok else 'TORN'}")
    finally:
        app.stop()


def act3_outage_shrink() -> None:
    print("[gang] act 3: cloud outage -> elastic shrink onto 2 survivors")
    home = SnoozeBackend(n_hosts=8)
    standby = OpenStackBackend(n_hosts=2)
    svc = CACSService({"snooze": home, "openstack": standby},
                      {"default": InMemoryStore()})
    sched = GlobalScheduler(svc, clock=VirtualClock(),
                            cloud_stores={"snooze": "default",
                                          "openstack": "default"})
    svc.attach_scheduler(sched)
    sched.start()
    try:
        cid = sched.submit(ASR(
            name="gang-demo", n_vms=4, backend="snooze", priority=5,
            app_factory=lambda: GangApp(global_rows=16, iter_time_s=0.05),
            policy=CheckpointPolicy(period_s=0, keep_last=3),
            gang=True, min_vms=2))
        svc.wait_for_state(cid, CoordState.RUNNING, 30)
        active_clock().paper_sleep(1.0)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        print(f"[gang]   4-rank gang RUNNING on snooze, image committed")
        t0 = active_clock().timestamp()
        home.sim.cloud_outage()
        assert _wait(lambda: coord.state != CoordState.RUNNING)
        assert _wait(lambda: coord.state == CoordState.RUNNING)
        mttr = (active_clock().timestamp() - t0) / active_clock().scale
        m = coord.metrics
        print(f"[gang]   outage detected, shrink-restored onto "
              f"{len(coord.vms)} ranks of {coord.asr.backend} "
              f"in {mttr:.1f}s (virtual)")
        print(f"[gang]   chunks re-uploaded: "
              f"{int(m.get('backfill_reuploads', -1))}; restore fetches "
              f"{int(m['gang_restore_fetches'])} of "
              f"{int(m['gang_restore_unique'])} unique (single-flight)")
        for seq, op, name, backend, detail, trace_id in \
                sched.decision_trace():
            print(f"[gang]     {seq:3d} {trace_id} {op:8s} "
                  f"{name}@{backend} {detail}")
    finally:
        sched.stop()
        svc.shutdown()


def main() -> None:
    clk = SimClock()
    try:
        with use_clock(clk):
            act1_consistent_cut()
            act2_mid_barrier_crash()
            act3_outage_shrink()
    finally:
        clk.close()
    print("[gang] done")


if __name__ == "__main__":
    main()
