"""Fault tolerance, two acts.

Act 1 — bit-exact single failure (the paper's §6.3 case 1): a host dies
mid-training; CACS detects it (native notification on the Snooze-like
backend), allocates a replacement VM, restores the latest image and
resumes — bit-exact with the failure-free run.

Act 2 — seeded chaos storyline: a deterministic multi-fault schedule
(VM crash, mid-save storage fault, raising health hook, monitor
partition, restore-time get fault, straggler) drives the whole recovery
control plane through `repro.core.chaos`. Same seed → same event trace;
every fault ends back in RUNNING off the latest COMMITTED image.

    PYTHONPATH=src python examples/fault_tolerance.py [--skip-reference]
                                                      [--seed N]
"""
import argparse
import dataclasses
import time

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.core.chaos import FaultSchedule, run_scenario
from repro.train import TrainerApp

CFG = dataclasses.replace(reduced(get_config("internlm2-1.8b")),
                          dtype="float32")
N_STEPS = 60


def run_reference() -> float:
    app = TrainerApp(CFG, global_batch=4, seq_len=64, n_steps=N_STEPS)
    app.start(None, None)
    while not app.is_done():
        time.sleep(0.2)
    app.stop()
    return app.losses[-1]


def act1_bit_exact_recovery() -> None:
    print("[ft] training failure-free reference ...")
    ref_loss = run_reference()
    print(f"[ft] reference final loss: {ref_loss:.6f}")

    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    asr = ASR(name="ft-train", n_vms=4, backend="snooze",
              app_factory=lambda: TrainerApp(CFG, global_batch=4, seq_len=64,
                                             n_steps=N_STEPS),
              policy=CheckpointPolicy(period_s=1.0, keep_last=3))
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=120)
    coord = svc.db.get(cid)

    while coord.app.current_step < N_STEPS // 3:
        time.sleep(0.2)
    victim = coord.vms[1].host.host_id
    print(f"[ft] step {coord.app.current_step}: killing host {victim}")
    backend.sim.fail_host(victim)

    while coord.recoveries < 1 or coord.state != CoordState.RUNNING:
        time.sleep(0.1)
    print(f"[ft] recovered (recovery #{coord.recoveries}); resumed at "
          f"step {coord.app.current_step} on fresh VM "
          f"{[vm.vm_id for vm in coord.vms]}")

    while not coord.app.is_done():
        time.sleep(0.5)
    print(f"[ft] finished: loss {coord.app.last_loss:.6f} "
          f"(reference {ref_loss:.6f})")
    # Deterministic pipeline + step-consistent snapshots => identical run.
    assert abs(coord.app.last_loss - ref_loss) < 1e-6, "trajectory diverged!"
    print("[ft] OK: post-failure trajectory identical to failure-free run")
    svc.shutdown()


def act2_chaos_storyline(seed: int) -> None:
    sched = FaultSchedule.storyline(seed=seed)
    print(f"[chaos] storyline (seed={seed}): {', '.join(sched.describe())}")
    res = run_scenario(sched, period_s=0.3, settle_timeout_s=60)
    for o in res.outcomes:
        times = ("" if o.mttr_s is None else
                 f"  detect={o.detection_s:.3f}s restore={o.restore_s:.3f}s "
                 f"mttr={o.mttr_s:.3f}s (wall)")
        print(f"[chaos]   {o.event.kind.value:<18} -> "
              f"{'OK ' if o.ok else 'FAIL'} [{o.final_state}] "
              f"{o.detail}{times}")
    print(f"[chaos] final={res.final_state} recoveries={res.recoveries} "
          f"duplicate-events-dropped={res.events_deduped} "
          f"partition-fallbacks={res.partition_fallbacks}")
    assert res.all_ok, "a fault did not recover cleanly"
    replay = run_scenario(sched, period_s=0.3, settle_timeout_s=60)
    assert replay.trace == res.trace, "storyline did not replay identically"
    print("[chaos] OK: every fault recovered; replay trace identical")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-reference", action="store_true",
                    help="skip the (slow) bit-exact trainer act")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()
    if not args.skip_reference:
        act1_bit_exact_recovery()
    act2_chaos_storyline(args.seed)


if __name__ == "__main__":
    main()
