"""Quickstart: submit a JAX training job to CACS, checkpoint it, restart it.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.train import TrainerApp


def main() -> None:
    # 1. A CACS service instance over a Snooze-like cloud backend.
    svc = CACSService({"snooze": SnoozeBackend(n_hosts=8)},
                      {"default": InMemoryStore()})

    # 2. Submit an application with a checkpoint policy (paper §5.1):
    #    a 4-VM virtual cluster, periodic checkpoints every 2 seconds.
    cfg = dataclasses.replace(reduced(get_config("repro-100m")),
                              dtype="float32")
    asr = ASR(
        name="quickstart-train",
        n_vms=4,
        backend="snooze",
        app_factory=lambda: TrainerApp(cfg, global_batch=4, seq_len=64,
                                       n_steps=60),
        policy=CheckpointPolicy(period_s=2.0, codec="zlib", keep_last=3),
    )
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=120)
    print(f"[quickstart] {cid} RUNNING on "
          f"{[vm.vm_id for vm in svc.db.get(cid).vms]}")

    # 3. Watch it train; the service checkpoints in the background.
    coord = svc.db.get(cid)
    while coord.app.current_step < 30:
        time.sleep(1.0)
        print(f"[quickstart] step={coord.app.current_step} "
              f"loss={coord.app.last_loss:.4f} "
              f"images={svc.list_checkpoints(cid)}")

    # 4. User-initiated checkpoint + restart from it (paper §5.2/§5.3).
    step = svc.trigger_checkpoint(cid)
    print(f"[quickstart] explicit checkpoint -> image {step}: "
          f"{svc.get_checkpoint(cid, step)}")
    svc.restart_from(cid, step)
    print(f"[quickstart] restarted from image {step}; "
          f"state={svc.get_coordinator(cid)['state']}")

    coord = svc.db.get(cid)
    while not coord.app.is_done():
        time.sleep(1.0)
    print(f"[quickstart] finished at step {coord.app.current_step}, "
          f"final loss {coord.app.last_loss:.4f}")
    svc.shutdown()


if __name__ == "__main__":
    main()
