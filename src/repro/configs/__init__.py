"""Architecture config registry.

``get_config(name)`` resolves an arch id (e.g. ``--arch gemma3-12b``) to its
``ArchConfig``.  ``reduced(cfg)`` derives the small same-family config used by
per-arch CPU smoke tests (full configs are only ever lowered via the dry-run).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, EncoderConfig, MoEConfig,
                                ShapeConfig, SHAPE_GRID, SHAPES, SSMConfig,
                                XLSTMConfig, shape_applicable)

from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.internlm2_1_8b import CONFIG as INTERNLM2_1_8B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.nemotron_4_340b import CONFIG as NEMOTRON_4_340B
from repro.configs.gemma3_12b import CONFIG as GEMMA3_12B
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.internvl2_2b import CONFIG as INTERNVL2_2B
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.jamba_v0_1_52b import CONFIG as JAMBA_V0_1_52B
from repro.configs.repro_100m import CONFIG as REPRO_100M

ARCH_REGISTRY = {
    c.name: c for c in (
        SEAMLESS_M4T_MEDIUM,
        INTERNLM2_1_8B,
        GRANITE_8B,
        NEMOTRON_4_340B,
        GEMMA3_12B,
        XLSTM_125M,
        INTERNVL2_2B,
        LLAMA4_MAVERICK,
        LLAMA4_SCOUT,
        JAMBA_V0_1_52B,
        REPRO_100M,
    )
}

ASSIGNED_ARCHS = tuple(n for n in ARCH_REGISTRY if n != "repro-100m")


def get_config(name: str) -> ArchConfig:
    if name not in ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every == 1 else cfg.attn_every),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
    )
    if cfg.attn_every > 1:
        changes["n_layers"] = cfg.attn_every          # one full hybrid group
        changes["attn_every"] = cfg.attn_every
    if cfg.attn_pattern == "local_global":
        changes["n_layers"] = cfg.local_global_ratio + 1  # one local:global group
        changes["local_window"] = 8
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4), d_ff=256)
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(
            cfg.encoder, n_layers=2, n_heads=4, n_kv_heads=4, d_ff=256)
    if cfg.frontend is not None:
        changes["frontend_len"] = 8
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ArchConfig", "EncoderConfig", "MoEConfig", "SSMConfig", "XLSTMConfig",
    "ShapeConfig", "SHAPE_GRID", "SHAPES", "shape_applicable",
    "ARCH_REGISTRY", "ASSIGNED_ARCHS", "get_config", "reduced",
]
