"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
MoE on every layer + always-on shared expert (scout layout).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    mlp_act="swiglu",
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, every=1,
                  shared_expert=True),
    use_fsdp=True,
    subquadratic=False,
)
