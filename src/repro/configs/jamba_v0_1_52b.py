"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2
[arXiv:2403.19887; hf]
Layout (per the Jamba paper): blocks of 8 layers with 1 attention + 7 Mamba;
MoE replaces the MLP on every other layer.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    mlp_act="swiglu",
    attn_every=8,                # 1 attention layer per 8 (1:7 with Mamba)
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336, every=2),
    use_fsdp=True,
    subquadratic=True,           # Mamba layers O(1)/token; 4 attn layers KV
)
