"""nemotron-4-340b [dense] — GQA + squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000
[arXiv:2402.16819; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    mlp_act="squared_relu",
    use_fsdp=True,               # 340B params cannot fit TP-16 alone
    subquadratic=False,
)
