"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]

5/6 of layers use a 1024-token sliding window, so per-token decode work at
500k context is dominated by the window — we treat the arch as effectively
sub-quadratic and run long_500k (global layers pay full KV; see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    mlp_act="swiglu",
    attn_pattern="local_global",
    local_window=1024,
    local_global_ratio=5,        # 5 local : 1 global
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    use_fsdp=True,
    subquadratic=True,
)
