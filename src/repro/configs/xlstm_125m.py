"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks.

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517; unverified]
d_ff=0: xLSTM blocks carry their own up/down projections; no separate FFN.
"""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_width=4),
    tie_embeddings=True,
    subquadratic=True,           # recurrent: O(1) state per decode step
)
