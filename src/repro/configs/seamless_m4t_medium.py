"""seamless-m4t-medium [audio] — enc-dec multimodal transformer backbone.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596; hf]
The speech frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings consumed by the encoder.
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_act="gelu",
    encoder=EncoderConfig(n_layers=12, n_heads=16, n_kv_heads=16, d_ff=4096),
    frontend="audio_frames",
    frontend_len=4096,           # encoder context length for decode shapes
    subquadratic=False,
)
