"""Architecture config system.

Every assigned architecture is expressed as an ``ArchConfig`` — a purely
declarative description consumed by ``repro.models.model.build_model``.
Configs never touch jax device state at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    every: int = 1               # MoE layer every `every` layers (1 = all)
    shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM block."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """Alternating mLSTM / sLSTM blocks (xLSTM)."""
    slstm_every: int = 2         # 1 sLSTM per `slstm_every` layers; rest mLSTM
    proj_factor: float = 2.0     # mLSTM up-projection factor
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Separate encoder stack for enc-dec (seamless-m4t)."""
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default: d_model // n_heads
    mlp_act: str = "swiglu"                  # swiglu | squared_relu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # Attention pattern: "full" or "local_global".
    attn_pattern: str = "full"
    local_window: int = 1024
    local_global_ratio: int = 0              # e.g. 5 => 5 local : 1 global

    # Hybrid attention:ssm interleave (jamba): 1 attn per `attn_every` layers.
    attn_every: int = 1

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # Modality frontend stub: None | "audio_frames" | "vit_patches".
    frontend: Optional[str] = None
    frontend_len: int = 0                    # tokens contributed by frontend

    # Sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    # FSDP (param/optimizer sharding over the data axis) on by default for
    # archs whose state does not fit tensor parallelism alone.
    use_fsdp: bool = False

    # Compute dtype for activations / params (master + opt state are f32
    # unless overridden by the trainer).
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads {self.n_heads} not divisible by "
            f"n_kv_heads {self.n_kv_heads}")

    # ---- derived sizes ------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        def ffn_params(dff: int) -> int:
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * d * dff

        total = 0
        for i in range(self.n_layers):
            is_attn = (i % self.attn_every) == 0 if self.attn_every > 1 else True
            if self.xlstm is not None:
                dm = int(self.xlstm.proj_factor * d)
                total += 2 * d * dm + dm * d + 4 * d * dm  # rough mLSTM/sLSTM
                continue
            if is_attn:
                total += attn
            elif self.ssm is not None:
                di = self.ssm.expand * d
                total += 2 * d * di + di * d + di * (2 * self.ssm.d_state + 2)
            if self.moe is not None and (i % self.moe.every) == (self.moe.every - 1):
                total += self.moe.num_experts * ffn_params(self.moe.d_ff)
                total += d * self.moe.num_experts  # router
                if self.moe.shared_expert:
                    total += ffn_params(self.d_ff)
            elif self.d_ff > 0:
                total += ffn_params(self.d_ff)
            total += 2 * d  # norms
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            e = self.encoder
            enc_attn = d * (e.n_heads * hd) * 2 + d * (e.n_kv_heads * hd) * 2
            total += e.n_layers * (enc_attn + ffn_params(e.d_ff) + 2 * d)
            # cross attention in every decoder layer
            total += self.n_layers * attn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if (i % self.moe.every) == (self.moe.every - 1))
        mult = 3 if self.mlp_act == "swiglu" else 2
        expert_p = mult * self.d_model * self.moe.d_ff
        inactive = n_moe_layers * (self.moe.num_experts - self.moe.top_k) * expert_p
        return full - inactive


# ---------------------------------------------------------------------------
# Input shape grid (assigned): every LM arch is paired with these four shapes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPE_GRID: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES = {s.name: s for s in SHAPE_GRID}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live cell per DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
