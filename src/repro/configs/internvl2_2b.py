"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2-1.8b backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821; hf]
The ViT frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (256 tokens) prepended to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    frontend="vit_patches",
    frontend_len=256,
    subquadratic=False,
)
