"""repro-100m — the end-to-end driver model (examples/train_e2e.py).

~100M-param dense GQA LM used to demonstrate the full CACS-managed training
loop on real (CPU) devices: periodic checkpoints, failure injection, restart,
migration. Analogue of the paper's NAS-LU / dmtcp1 target applications.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab_size=32768,
    mlp_act="swiglu",
    tie_embeddings=True,
    subquadratic=False,
)
