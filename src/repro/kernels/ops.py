"""Jitted public wrappers around the Pallas kernels.

Layout conventions follow the model code ([B,S,H,hd]); wrappers transpose
to the kernels' [B,H,S,hd], pad sequence dims to block multiples (padding
is masked via ``kv_len``), and select an implementation:

  impl="pallas"    — real kernel (TPU) or interpret mode (CPU tests)
  impl="ref"       — the pure-jnp oracle (used by models on CPU/dry-run)

On a CPU-only host ``default_impl()`` returns "ref"; tests force
impl="pallas", interpret=True to execute the kernel bodies.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.qsnap import qsnap_dequantize, qsnap_quantize

QSNAP_BLOCK = ref.QSNAP_BLOCK


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    impl: Optional[str] = None, interpret: bool = False,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,Hkv,hd] -> [B,S,H,hd]."""
    impl = impl or default_impl()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    S, T = qt.shape[2], kt.shape[2]
    if impl == "ref":
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal,
                                      window=window)
        return jnp.swapaxes(out, 1, 2)
    qt, _ = _pad_to(qt, 2, block_q)
    kt, kv_len = _pad_to(kt, 2, block_k)
    vt, _ = _pad_to(vt, 2, block_k)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               kv_len=kv_len, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out[:, :, :S], 1, 2)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, impl: Optional[str] = None,
                     interpret: bool = False,
                     block_k: int = 512) -> jax.Array:
    """q: [B,1,H,hd]; k,v: [B,T,Hkv,hd]; pos scalar -> [B,1,H,hd]."""
    impl = impl or default_impl()
    qt = q[:, 0].swapaxes(0, 0)                      # [B,H,hd]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "ref":
        out = ref.decode_attention_ref(qt, kt, vt, pos)
        return out[:, None]
    kt, _ = _pad_to(kt, 2, block_k)
    vt, _ = _pad_to(vt, 2, block_k)
    out = decode_attention_bhd(qt, kt, vt, pos, block_k=block_k,
                               interpret=interpret)
    return out[:, None]


def qsnap_compress(x: jax.Array, *, impl: Optional[str] = None,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array, int]:
    """Any-shape float array -> (codes int8 [Npad], scales f32, n_orig)."""
    impl = impl or default_impl()
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % QSNAP_BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    if impl == "ref":
        codes, scales = ref.qsnap_ref(flat)
    else:
        codes, scales = qsnap_quantize(flat, interpret=interpret)
    return codes, scales, n


def qsnap_decompress(codes: jax.Array, scales: jax.Array, n: int,
                     shape, dtype=jnp.float32, *,
                     impl: Optional[str] = None,
                     interpret: bool = False) -> jax.Array:
    impl = impl or default_impl()
    if impl == "ref":
        flat = ref.qsnap_dequant_ref(codes, scales, dtype)
    else:
        flat = qsnap_dequantize(codes, scales, dtype, interpret=interpret)
    return flat[:n].reshape(shape)
