"""qsnap — blockwise int8 quantization kernel for checkpoint images and
gradient compression.

The paper's scaling lever is checkpoint image *size* (Table 2, §5.2). On a
TPU fleet the equivalent hot path is the device->host copy and the
DP-gradient all-reduce: quantizing on device (VMEM-resident, one pass)
cuts both by ~4x for bf16/f32 state. Each 256-element block stores one f32
absmax scale + 256 int8 codes — the exact format ``repro.ckpt.compression``
writes, so device- and host-compressed images are interchangeable.

Tiles: [block_rows, 256] codes with [block_rows, 1] scales; the lane dim
(256) is 2x the 128-lane VPU width — one row = two vector registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QSNAP_BLOCK = 256


def _quant_kernel(x_ref, codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)                 # [rows, 256]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(x / scale), -127, 127)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale


def _dequant_kernel(codes_ref, scales_ref, x_ref):
    codes = codes_ref[...].astype(jnp.float32)
    x_ref[...] = (codes * scales_ref[...]).astype(x_ref.dtype)


def qsnap_quantize(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False):
    """x: [N] float (N % 256 == 0) -> (codes int8 [N], scales f32 [N/256])."""
    n = x.shape[0]
    assert n % QSNAP_BLOCK == 0, n
    rows = n // QSNAP_BLOCK
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    xm = x.reshape(rows, QSNAP_BLOCK)
    codes, scales = pl.pallas_call(
        _quant_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, QSNAP_BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xm)
    return codes.reshape(-1), scales.reshape(-1)


def qsnap_dequantize(codes: jax.Array, scales: jax.Array, dtype=jnp.float32,
                     *, block_rows: int = 256, interpret: bool = False):
    """Inverse of qsnap_quantize -> [N] of ``dtype``."""
    n = codes.shape[0]
    rows = n // QSNAP_BLOCK
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, QSNAP_BLOCK), dtype),
        interpret=interpret,
    )(codes.reshape(rows, QSNAP_BLOCK), scales.reshape(rows, 1))
    return out.reshape(-1)
