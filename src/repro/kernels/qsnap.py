"""qsnap — blockwise int8 quantization kernel for checkpoint images and
gradient compression.

The paper's scaling lever is checkpoint image *size* (Table 2, §5.2). On a
TPU fleet the equivalent hot path is the device->host copy and the
DP-gradient all-reduce: quantizing on device (VMEM-resident, one pass)
cuts both by ~4x for bf16/f32 state. Each 256-element block stores one f32
absmax scale + 256 int8 codes — the exact format ``repro.ckpt.compression``
writes, so device- and host-compressed images are interchangeable.

Tiles: [block_rows, 256] codes with [block_rows, 1] scales; the lane dim
(256) is 2x the 128-lane VPU width — one row = two vector registers.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.ckpt import compression

QSNAP_BLOCK = 256


def _quant_kernel(x_ref, codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)                 # [rows, 256]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # multiply, not /127: bit-identical to the host codec on every backend
    # (XLA lowers x/const to a reciprocal multiply anyway)
    scale = absmax * jnp.float32(1.0 / 127.0)
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(x / scale), -127, 127)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale


def _dequant_kernel(codes_ref, scales_ref, x_ref):
    codes = codes_ref[...].astype(jnp.float32)
    x_ref[...] = (codes * scales_ref[...]).astype(x_ref.dtype)


def _fit_block_rows(rows: int, cap: int) -> int:
    """Largest grid tile height <= cap that divides ``rows`` evenly.

    Leaf sizes are arbitrary (rows=300 is legal after 256-padding of a
    76 800-element leaf), so the tile must be a true divisor — min(cap,
    rows) alone trips the grid-coverage assert for non-power-of-two rows.
    """
    b = min(cap, rows)
    while rows % b:
        b -= 1
    return b


def qsnap_quantize(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False):
    """x: [N] float (N % 256 == 0) -> (codes int8 [N], scales f32 [N/256])."""
    n = x.shape[0]
    assert n % QSNAP_BLOCK == 0, n
    rows = n // QSNAP_BLOCK
    block_rows = _fit_block_rows(rows, block_rows)
    xm = x.reshape(rows, QSNAP_BLOCK)
    codes, scales = pl.pallas_call(
        _quant_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, QSNAP_BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xm)
    return codes.reshape(-1), scales.reshape(-1)


def qsnap_dequantize(codes: jax.Array, scales: jax.Array, dtype=jnp.float32,
                     *, block_rows: int = 256, interpret: bool = False):
    """Inverse of qsnap_quantize -> [N] of ``dtype``."""
    n = codes.shape[0]
    rows = n // QSNAP_BLOCK
    block_rows = _fit_block_rows(rows, block_rows)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, QSNAP_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, QSNAP_BLOCK), dtype),
        interpret=interpret,
    )(codes.reshape(rows, QSNAP_BLOCK), scales.reshape(rows, 1))
    return out.reshape(-1)


def _encode_impl() -> str:
    # mirror of ops.default_impl(); inlined to keep kernels.ops -> qsnap
    # the only import direction between the two modules
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def qsnap_encode_chunks(arrs: Sequence[jax.Array], *,
                        impl: Optional[str] = None,
                        interpret: bool = False) -> List[bytes]:
    """Quantize chunk arrays on device into finished ``QS01`` payloads.

    For each float array this runs the blockwise int8 quantization on the
    *device* (Pallas kernel on TPU, jnp oracle elsewhere) and frames the
    result exactly as ``repro.ckpt.compression.encode(..., "int8")``
    would: the device→host copy carries int8 codes + one f32 scale per
    256 elements (~4x fewer bytes than f32 state), and the payload is
    byte-identical to the host codec's, so CAS digests over encoded bytes
    dedup across device- and host-compressed images.

    Non-float arrays fall back to the host RAWD framing (they are small:
    step counters, rng keys).  All device work is issued before the
    single batched ``jax.device_get``, so transfers overlap.
    """
    impl = impl or _encode_impl()
    staged = []                      # (index, n, device codes, scales)
    payloads: List[Optional[bytes]] = [None] * len(arrs)
    for i, arr in enumerate(arrs):
        if not compression.is_float_dtype(np.dtype(arr.dtype)):
            payloads[i] = compression.frame_raw(
                np.ascontiguousarray(jax.device_get(arr)).tobytes())
            continue
        flat = arr.reshape(-1)
        n = flat.size
        pad = (-n) % QSNAP_BLOCK
        if pad:
            flat = jnp.pad(flat, (0, pad))
        if impl == "ref":
            from repro.kernels import ref
            codes, scales = ref.qsnap_ref(flat)
        else:
            codes, scales = qsnap_quantize(flat.astype(jnp.float32),
                                           interpret=interpret)
        staged.append((i, n, codes, scales))
    if staged:
        fetched = jax.device_get([(c, s) for _, _, c, s in staged])
        for (i, n, _, _), (codes, scales) in zip(staged, fetched):
            payloads[i] = compression.frame_int8(n, scales, codes)
    return payloads  # type: ignore[return-value]
