"""Pallas TPU kernels (+ jnp oracles) for the framework's compute hot-spots.

  flash_attention  — blocked causal GQA attention (training / prefill)
  decode_attention — flash-decode over long KV caches (long_500k path)
  qsnap            — blockwise int8 quantization (checkpoint images /
                     gradient compression; format-compatible with
                     repro.ckpt.compression)

Use via ``repro.kernels.ops`` — wrappers pick pallas on TPU, jnp oracle on
CPU, and support interpret=True for kernel-body validation on CPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
