"""Blocked flash attention (training/prefill) — Pallas TPU kernel.

TPU adaptation of the flash-attention insight (DESIGN.md §5): stream K/V
HBM->VMEM in ``block_k`` tiles against a resident ``block_q`` query tile,
with the online-softmax running (m, l, acc) state held in VMEM scratch
across the innermost grid dimension. Tiles are MXU-aligned (128 lanes);
GQA is expressed in the index map (q-head h reads kv-head h // q_per_kv),
so KV tiles are fetched once per q-head group member without replication
in HBM.

Grid: (B, H, n_q_blocks, n_kv_blocks) — the kv dimension is innermost and
iterated sequentially per TPU core, which is what makes the VMEM scratch
accumulator correct.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  kv_len: int, block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    rel = q_pos - k_pos
    mask = k_pos < kv_len
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # [bq, bk]
    corr = jnp.exp(m_prev - m_new)                 # [bq, 1]
    l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True,
                         window: Optional[int] = None,
                         kv_len: Optional[int] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: [B,H,S,hd]; k,v: [B,Hkv,T,hd]. S % block_q == 0, T % block_k == 0."""
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_kv = S // block_q, T // block_k
    if kv_len is None:
        kv_len = T

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, kv_len=kv_len, block_q=block_q, block_k=block_k,
        n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, g=g: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, g=g: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
