"""Flash-decode — single-token GQA attention over a long KV cache.

The ``long_500k`` serving hot-spot: one query token, KV cache of up to 512k
slots. The kernel streams the cache HBM->VMEM in ``block_k`` tiles with the
online-softmax state in VMEM scratch; the dynamic fill position ``pos``
arrives as a tiny SMEM-resident operand so the same compiled kernel serves
every decode step (no recompilation as the cache fills).

Grid: (B, Hkv, n_kv_blocks) — all q heads of one kv group are processed
together as a [g, hd] tile, which keeps the MXU busy despite the single
token (g = q_per_kv rows instead of 1).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_k: int, n_kv: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)            # [g, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos <= pos, s, NEG_INF)        # attend to 0..pos

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_bhd(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array, *, block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: [B,H,hd]; k,v: [B,Hkv,T,hd]; pos: scalar int32 -> [B,H,hd]."""
    B, H, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    block_k = min(block_k, T)
    assert T % block_k == 0
    n_kv = T // block_k
    qg = q.reshape(B, Hkv, g, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(hd),
                               block_k=block_k, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # pos
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, k, v)
    return out.reshape(B, H, hd)
