"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

All reference functions use f32 accumulation, matching the kernels' VMEM
accumulator dtype, so assert_allclose tolerances stay tight even for bf16
inputs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
QSNAP_BLOCK = 256


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        kv_len: Optional[int] = None) -> jax.Array:
    """q: [B,H,S,hd]; k,v: [B,Hkv,T,hd] (GQA) -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / math.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    rel = qp - kp
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    if kv_len is not None:
        mask &= kp < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array) -> jax.Array:
    """q: [B,H,hd]; k,v: [B,Hkv,T,hd]; pos scalar -> [B,H,hd].

    Attends over cache slots 0..pos (inclusive).
    """
    B, H, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    mask = jnp.arange(T) <= pos
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def qsnap_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise absmax int8 quantization. x: [N] (N % 256 == 0).

    Returns (codes int8 [N], scales f32 [N/256]). Matches
    ``repro.ckpt.compression.quantize_int8`` bit-for-bit (both sides use
    the absmax * (1/127) multiply — see ``compression.INV127``).
    """
    xf = x.astype(jnp.float32).reshape(-1, QSNAP_BLOCK)
    scales = jnp.max(jnp.abs(xf), axis=1) * jnp.float32(1.0 / 127.0)
    scales = jnp.where(scales == 0, 1.0, scales)
    codes = jnp.clip(jnp.round(xf / scales[:, None]), -127, 127)
    return codes.astype(jnp.int8).reshape(-1), scales


def qsnap_dequant_ref(codes: jax.Array, scales: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    blocks = codes.reshape(-1, QSNAP_BLOCK).astype(jnp.float32)
    return (blocks * scales[:, None]).reshape(-1).astype(dtype)
