"""Unified observability layer: metrics registry + span tracing.

See ``telemetry.py`` (counters/gauges/histograms in paper seconds) and
``trace.py`` (trace_id-correlated spans with JSONL / Chrome exporters).
"""
from repro.obs.telemetry import (MetricsRegistry, SampleView,   # noqa: F401
                                 install_registry, registry, use_registry)
from repro.obs.trace import (Tracer, install_tracer, tracer,    # noqa: F401
                             use_tracer)
