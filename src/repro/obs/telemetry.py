"""Unified virtual-time metrics registry (counters / gauges / histograms).

Every layer of the service (ckpt writer and reader, data-plane budget,
scheduler, gang barrier, replication, monitoring, apps) publishes into one
process-wide ``MetricsRegistry`` instead of growing its own ad-hoc stats
dict.  Three properties make it fit this repo:

  * **paper-second stamps** — every update is stamped from
    ``sim.simtime.active_clock()`` and normalized by ``clock.scale``, so a
    snapshot taken under ``SimClock`` reads in paper seconds and is
    bit-for-bit replayable (same seed, same schedule => same snapshot).
  * **deterministic shape** — histograms use *fixed* bucket edges chosen at
    creation (never rebalanced), and ``snapshot()`` emits keys in sorted
    order, so serialized snapshots are stable across runs and
    ``PYTHONHASHSEED`` values.
  * **cheap when off** — every mutator checks ``enabled`` first; the
    disabled path is one attribute load and a branch (guarded by the
    ``obs`` overhead benchmark at < 5% on the ckpt path).

The module-level ``registry()`` / ``install_registry()`` /
``use_registry()`` API mirrors ``sim.simtime.active_clock()`` so tests can
swap in a fresh registry for isolation.
"""
from __future__ import annotations

import itertools
import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.sim.simtime import active_clock

__all__ = [
    "Counter", "Gauge", "Histogram", "SampleView", "MetricsRegistry",
    "registry", "install_registry", "use_registry", "paper_now",
    "DEFAULT_EDGES",
]

# Fixed default bucket edges (paper seconds).  Spanning 100µs..5min covers
# everything we time: per-chunk encode/upload (sub-ms..ms), budget waits,
# snapshot stalls (µs..ms), and whole save/restore cycles (s..min).
DEFAULT_EDGES: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 300.0,
)


def paper_now() -> float:
    """Current time of the installed clock, in paper seconds."""
    clk = active_clock()
    return clk.now() / clk.scale


class Counter:
    """Monotonic-by-convention counter with an optional last-error note.

    ``value`` is settable (``counter.value = 0``) so registry-backed
    attribute views (e.g. ``GlobalScheduler.preemptions``) keep supporting
    plain ``+=`` / ``= 0`` assignment.
    """

    __slots__ = ("name", "_value", "note", "updated_at", "_reg")

    def __init__(self, name: str, reg: "MetricsRegistry"):
        self.name = name
        self._value = 0.0
        self.note = ""                 # last-error string (daemon counters)
        self.updated_at = 0.0
        self._reg = reg

    def inc(self, n: float = 1.0, note: Optional[str] = None) -> None:
        if not self._reg.enabled:
            return
        with self._reg._lock:
            self._value += n
            if note is not None:
                self.note = note
            self.updated_at = paper_now()

    @property
    def value(self) -> float:
        return self._value

    @value.setter
    def value(self, v: float) -> None:
        with self._reg._lock:
            self._value = float(v)
            self.updated_at = paper_now()

    def as_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"type": "counter", "value": self._value,
                             "updated_at": self.updated_at}
        if self.note:
            d["note"] = self.note
        return d


class Gauge:
    """Last-value gauge with an optional high-water mark (``set_max``)."""

    __slots__ = ("name", "value", "high_water", "updated_at", "_reg")

    def __init__(self, name: str, reg: "MetricsRegistry"):
        self.name = name
        self.value = 0.0
        self.high_water = 0.0
        self.updated_at = 0.0
        self._reg = reg

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        with self._reg._lock:
            self.value = v
            if v > self.high_water:
                self.high_water = v
            self.updated_at = paper_now()

    def set_max(self, v: float) -> None:
        """Ratchet the high-water mark without disturbing ``value``."""
        if not self._reg.enabled:
            return
        with self._reg._lock:
            if v > self.high_water:
                self.high_water = v
                self.updated_at = paper_now()

    def as_dict(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value,
                "high_water": self.high_water, "updated_at": self.updated_at}


class Histogram:
    """Fixed-edge histogram that also retains raw samples.

    Edges are frozen at creation (``DEFAULT_EDGES`` unless given), so two
    runs of the same schedule bucket identically — no dynamic rebalancing,
    no run-order dependence.  Raw samples are retained (they are what
    backward-compat views like ``TrainerApp.ckpt_stalls`` expose), capped
    at ``max_samples`` oldest-first so a long-lived daemon cannot grow one
    unboundedly.
    """

    __slots__ = ("name", "edges", "bucket_counts", "count", "sum", "min",
                 "max", "samples", "max_samples", "updated_at", "_reg")

    def __init__(self, name: str, reg: "MetricsRegistry",
                 edges: Optional[Sequence[float]] = None,
                 max_samples: int = 4096):
        self.name = name
        self.edges: Tuple[float, ...] = tuple(edges or DEFAULT_EDGES)
        self.bucket_counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.samples: List[float] = []
        self.max_samples = max_samples
        self.updated_at = 0.0
        self._reg = reg

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        with self._reg._lock:
            i = 0
            for edge in self.edges:
                if v <= edge:
                    break
                i += 1
            self.bucket_counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if len(self.samples) < self.max_samples:
                self.samples.append(v)
            self.updated_at = paper_now()

    def as_dict(self) -> Dict[str, Any]:
        return {
            "type": "histogram", "edges": list(self.edges),
            "bucket_counts": list(self.bucket_counts), "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "updated_at": self.updated_at,
        }


class SampleView(Sequence):
    """Read-only sequence view over a histogram's retained samples.

    Backward-compat shim for attributes that used to be bare lists
    (``TrainerApp.ckpt_stalls``): supports ``len``, indexing, slicing and
    iteration, but not mutation — the histogram is the source of truth.
    """

    __slots__ = ("_hist",)

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __len__(self) -> int:
        return len(self._hist.samples)

    def __getitem__(self, i):
        return self._hist.samples[i]

    def __iter__(self) -> Iterator[float]:
        return iter(list(self._hist.samples))

    def __repr__(self) -> str:
        return f"SampleView({self._hist.samples!r})"

    def __eq__(self, other) -> bool:
        return list(self) == list(other)


class MetricsRegistry:
    """Thread-safe named-metric registry.

    One instance is process-global by default (see ``registry()``); all
    instruments created from it share its ``enabled`` switch and lock.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: Dict[str, Any] = {}

    # -- instrument factories (get-or-create, idempotent) -----------------
    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = Histogram(name, self, edges=edges)
                self._metrics[name] = m
            elif not isinstance(m, Histogram):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}")
            return m

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, self)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}")
            return m

    # -- one-shot conveniences --------------------------------------------
    def inc(self, name: str, n: float = 1.0,
            note: Optional[str] = None) -> None:
        if self.enabled:
            self.counter(name).inc(n, note)

    def set_gauge(self, name: str, v: float) -> None:
        if self.enabled:
            self.gauge(name).set(v)

    def gauge_max(self, name: str, v: float) -> None:
        if self.enabled:
            self.gauge(name).set_max(v)

    def observe(self, name: str, v: float,
                edges: Optional[Sequence[float]] = None) -> None:
        if self.enabled:
            self.histogram(name, edges=edges).observe(v)

    # -- inspection ---------------------------------------------------------
    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def value(self, name: str, default: float = 0.0) -> float:
        m = self.get(name)
        if m is None:
            return default
        return m.value if not isinstance(m, Histogram) else m.count

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self, prefix: str = "") -> Dict[str, Dict[str, Any]]:
        """Deterministic dict of every metric (sorted keys), optionally
        filtered by name prefix.  Timestamps are paper seconds."""
        with self._lock:
            return {name: m.as_dict()
                    for name, m in sorted(self._metrics.items())
                    if name.startswith(prefix)}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# Process-global registry, mirroring sim.simtime's active-clock idiom.
# ---------------------------------------------------------------------------
_REGISTRY = MetricsRegistry()
_REG_LOCK = threading.Lock()

# Monotonic suffix source for per-instance metric names (one histogram per
# TrainerApp etc. — deterministic by construction order, never hash order).
_SEQ = itertools.count(1)


def unique_name(base: str) -> str:
    """``base#N`` with a process-monotonic N — per-instance metric names."""
    return f"{base}#{next(_SEQ)}"


def registry() -> MetricsRegistry:
    return _REGISTRY


def install_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    with _REG_LOCK:
        prev, _REGISTRY = _REGISTRY, reg
    return prev


@contextmanager
def use_registry(reg: Optional[MetricsRegistry] = None):
    """Temporarily install ``reg`` (a fresh registry when None)."""
    reg = reg if reg is not None else MetricsRegistry()
    prev = install_registry(reg)
    try:
        yield reg
    finally:
        install_registry(prev)
