"""Cross-layer span tracing on the virtual clock.

A ``Span`` is a named interval stamped in paper seconds from
``sim.simtime.active_clock()``, carrying the per-job ``trace_id`` (PR 7's
coordinator id-stamp) so one job's checkpoint saves, scheduler decisions,
gang barrier phases, replication ships and monitor detections all
correlate in a single timeline.  ``Tracer.span`` is a context manager;
nesting on one thread is automatic (thread-local stack), and work handed
to pool threads passes ``parent=`` explicitly (the writer/reader pipelines
do this for per-chunk encode/upload/fetch spans).

Exports:

  * ``export_jsonl`` — one JSON object per line, self-contained.
  * ``export_chrome`` — Chrome trace-event JSON; open in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing``.  One ``tid`` per
    ``trace_id`` so each job reads as its own track.

Both exporters are **canonical**: records are sorted by
``(trace_id, t0, t1, cat, name, args)`` and span ids renumbered in that
order, so two runs of the same virtual-time schedule serialize
byte-for-byte identically regardless of thread interleaving or
``PYTHONHASHSEED`` (the same discipline as ``SimEngine`` traces — and with
the same caveat: only schedules whose *timestamps* are deterministic, e.g.
a serial data plane under ``SimClock``, yield identical bytes; parallel
planes replay identical span *sets* with jittered stamps).

The module-level ``tracer()`` / ``install_tracer()`` / ``use_tracer()``
API mirrors ``sim.simtime.active_clock()``.
"""
from __future__ import annotations

import json
import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

from repro.sim.simtime import active_clock

__all__ = ["Span", "Tracer", "tracer", "install_tracer", "use_tracer"]


def _paper_now() -> float:
    clk = active_clock()
    return clk.now() / clk.scale


class Span:
    """One traced interval (``t1 == t0`` for instant events)."""

    __slots__ = ("name", "cat", "trace_id", "t0", "t1", "args", "parent")

    def __init__(self, name: str, cat: str, trace_id: str, t0: float,
                 args: Optional[Dict[str, Any]], parent: Optional["Span"]):
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.t0 = t0
        self.t1 = t0
        self.args: Dict[str, Any] = args if args is not None else {}
        self.parent = parent

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def set(self, key: str, value: Any) -> "Span":
        """Attach/overwrite one arg on an open span."""
        self.args[key] = value
        return self

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, cat={self.cat!r}, "
                f"trace_id={self.trace_id!r}, t0={self.t0:.6f}, "
                f"dur={self.duration:.6f})")


class _NullSpan:
    """Returned by a disabled tracer: absorbs ``set`` calls, records
    nothing."""

    __slots__ = ()
    name = cat = trace_id = ""
    t0 = t1 = duration = 0.0
    args: Dict[str, Any] = {}
    parent = None

    def set(self, key: str, value: Any) -> "_NullSpan":
        return self


_NULL = _NullSpan()


class Tracer:
    """Thread-safe span recorder.

    ``max_records`` bounds memory for long-lived daemon instrumentation;
    past it new records are dropped and counted in ``dropped`` (exports in
    tests/smokes use fresh tracers and never get near the cap).
    """

    def __init__(self, enabled: bool = True, max_records: int = 200_000):
        self.enabled = enabled
        self.max_records = max_records
        self.dropped = 0
        self._lock = threading.Lock()
        self._done: List[Span] = []
        self._tls = threading.local()

    # -- recording ----------------------------------------------------------
    def current(self) -> Optional[Span]:
        """Innermost open span on this thread (None outside any span)."""
        stack = getattr(self._tls, "stack", None)
        return stack[-1] if stack else None

    @contextmanager
    def span(self, name: str, *, cat: str = "", trace_id: str = "",
             parent: Optional[Span] = None,
             args: Optional[Dict[str, Any]] = None):
        if not self.enabled:
            yield _NULL
            return
        if parent is None:
            parent = self.current()
        if not trace_id and parent is not None:
            trace_id = parent.trace_id
        sp = Span(name, cat, trace_id, _paper_now(), args, parent)
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append(sp)
        try:
            yield sp
        except BaseException as exc:
            sp.args.setdefault("error", type(exc).__name__)
            raise
        finally:
            stack.pop()
            sp.t1 = _paper_now()
            self._record(sp)

    def event(self, name: str, *, cat: str = "", trace_id: str = "",
              args: Optional[Dict[str, Any]] = None) -> None:
        """Record an instant event (zero-duration span)."""
        if not self.enabled:
            return
        parent = self.current()
        if not trace_id and parent is not None:
            trace_id = parent.trace_id
        sp = Span(name, cat, trace_id, _paper_now(), args, parent)
        self._record(sp)

    def _record(self, sp: Span) -> None:
        with self._lock:
            if len(self._done) >= self.max_records:
                self.dropped += 1
                return
            self._done.append(sp)

    # -- querying -----------------------------------------------------------
    def spans(self, cat: Optional[str] = None,
              trace_id: Optional[str] = None,
              name: Optional[str] = None) -> List[Span]:
        """Finished spans in record order, optionally filtered."""
        with self._lock:
            out = list(self._done)
        if cat is not None:
            out = [s for s in out if s.cat == cat]
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def reset(self) -> None:
        with self._lock:
            self._done.clear()
            self.dropped = 0

    # -- canonical export ---------------------------------------------------
    def _canonical(self) -> List[Dict[str, Any]]:
        """Sorted, id-renumbered rows — the deterministic export form."""
        with self._lock:
            done = list(self._done)

        def key(s: Span):
            return (s.trace_id, s.t0, s.t1, s.cat, s.name,
                    json.dumps(s.args, sort_keys=True, default=str))

        order = sorted(done, key=key)
        ids = {id(s): f"s{i:06d}" for i, s in enumerate(order)}
        rows = []
        for i, s in enumerate(order):
            rows.append({
                "id": ids[id(s)],
                # a parent still open at export time has no id yet -> None
                "parent": ids.get(id(s.parent)) if s.parent is not None
                else None,
                "trace_id": s.trace_id,
                "cat": s.cat,
                "name": s.name,
                "ts": s.t0,
                "dur": s.t1 - s.t0,
                "args": {k: s.args[k] for k in sorted(s.args)},
            })
        return rows

    def to_jsonl(self) -> str:
        return "".join(
            json.dumps(row, sort_keys=True, default=str) + "\n"
            for row in self._canonical())

    def export_jsonl(self, path: str) -> int:
        """Write one JSON object per line; returns the record count."""
        text = self.to_jsonl()
        with open(path, "w") as f:
            f.write(text)
        return text.count("\n")

    def to_chrome(self) -> str:
        """Chrome trace-event JSON (Perfetto-viewable)."""
        rows = self._canonical()
        # one tid per trace_id, numbered by first appearance in canonical
        # order (i.e. sorted trace_id order) — hash-seed independent
        tids: Dict[str, int] = {}
        for row in rows:
            tids.setdefault(row["trace_id"], len(tids) + 1)
        events: List[Dict[str, Any]] = []
        for tid_name, tid in tids.items():
            events.append({
                "ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
                "args": {"name": tid_name or "(untraced)"},
            })
        for row in rows:
            ev: Dict[str, Any] = {
                "name": row["name"],
                "cat": row["cat"] or "misc",
                "pid": 1,
                "tid": tids[row["trace_id"]],
                "ts": round(row["ts"] * 1e6, 3),   # paper µs
                "args": dict(row["args"], trace_id=row["trace_id"],
                             id=row["id"], parent=row["parent"]),
            }
            if row["dur"] > 0.0:
                ev["ph"] = "X"
                ev["dur"] = round(row["dur"] * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        return json.dumps(doc, sort_keys=True, default=str,
                          separators=(",", ":"))

    def export_chrome(self, path: str) -> int:
        text = self.to_chrome()
        with open(path, "w") as f:
            f.write(text)
        with self._lock:
            return len(self._done)


# ---------------------------------------------------------------------------
# Process-global tracer, mirroring sim.simtime's active-clock idiom.
# ---------------------------------------------------------------------------
_TRACER = Tracer()
_TRACER_LOCK = threading.Lock()


def tracer() -> Tracer:
    return _TRACER


def install_tracer(tr: Tracer) -> Tracer:
    global _TRACER
    with _TRACER_LOCK:
        prev, _TRACER = _TRACER, tr
    return prev


@contextmanager
def use_tracer(tr: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Temporarily install ``tr`` (a fresh tracer when None)."""
    tr = tr if tr is not None else Tracer()
    prev = install_tracer(tr)
    try:
        yield tr
    finally:
        install_tracer(prev)
