"""Request-storm serving fleet on the discrete-event engine.

Extends :class:`~repro.sim.engine.SimEngine` with an always-on serving
tier driven by a seeded :class:`~repro.serve.workload.RequestTrace`
(diurnal + bursty, millions of requests): the *control plane* — replica
boots, suspends (scale-in parks), autoscaler ticks, batch-job arrivals,
host faults — runs as discrete events on the shared queue, while the
*data plane* (per-request routing and latency) is handled arithmetically
between events against per-replica service slots. Requests are never
individual events, so a simulated day of 7-digit request counts costs
seconds of wall time, and the control trace stays byte-identical for a
seed.

Replicas are ordinary :class:`SimJob`s at the top priority
(``_MAX_PRI``): scaling out *preempts* batch work when the cluster is
full (the GlobalScheduler's swap-out applied in reverse), and scaling in
parks a replica — its hosts go back to the free pool for batch jobs,
mirroring ``serve/fleet.py``'s suspend + ``fleet_parked`` path. A cold
start pays ``replica_boot_s`` (VM boot + CAS seed restore via prefix
adoption); a park pays ``suspend_s`` of swap-out before the hosts free.

Mirrors of the real stack, checked by the same benchmark
(`benchmarks/serve_fleet.py`): p99 request latency and
served-QPS-per-replica-host-second for a policy-scaled fleet vs a static
one under the same over-subscribed cloud and the same request bytes.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional

from repro.serve.workload import FleetPolicy, RequestTrace
from repro.sim.engine import (_MAX_PRI, BOOTING, QUEUED, RUNNING,
                              InvariantViolation, SimEngine, SimJob)

#: extra SimJob state for a scale-in'd replica (engine states are 0..3)
PARKED = 4

#: replica work_s sentinel — far past any horizon, so run_done never fires
_FOREVER_S = 1e15


class ServeFleetEngine(SimEngine):
    """SimEngine + serving replicas + arithmetic request data plane."""

    def __init__(self, n_hosts: int, seed: int, *, trace: RequestTrace,
                 policy: FleetPolicy, service_s: float = 0.05,
                 concurrency: int = 4, hosts_per_replica: int = 1,
                 replica_boot_s: float = 20.0, suspend_s: float = 5.0,
                 **kw):
        super().__init__(n_hosts, seed, **kw)
        self.req_trace = trace
        self.policy = policy
        self.service_s = service_s
        self.concurrency = concurrency          # batch slots per replica
        self.hosts_per_replica = hosts_per_replica
        self.replica_boot_s = replica_boot_s
        self.suspend_s = suspend_s
        self._arrivals = iter(trace)
        self._next_arrival: Optional[float] = next(self._arrivals, None)
        self.replica_jids: set = set()
        self.live: List[int] = []               # routing membership, sorted
        self._slots: Dict[int, List[float]] = {}   # jid -> free_at min-heap
        self._busy_until: Dict[int, float] = {}
        self._parking: set = set()              # jids mid-swap-out
        self.parked_jids: List[int] = []
        self.pending: List[float] = []          # arrivals with no live fleet
        self.latencies: List[float] = []
        self.requests = 0
        self.served = 0
        self.coldstarts = 0
        self.parks = 0
        self.unparks = 0
        self.replica_host_s = 0.0
        self._hold_start: Dict[int, float] = {}
        self._window_arrivals = 0
        if policy.eval_period_s > 0:
            self.q.schedule(policy.eval_period_s, "autoscale", None)

    # ------------------------------------------------------------------
    # fleet control
    # ------------------------------------------------------------------
    def start_fleet(self, n: int) -> None:
        """Bring up the initial replicas at t=0 (before run())."""
        for _ in range(n):
            self._new_replica()

    def _new_replica(self) -> int:
        job = SimJob(jid=len(self.jobs), arrival_s=self.now,
                     n_vms=self.hosts_per_replica, priority=_MAX_PRI,
                     work_s=_FOREVER_S, ckpt_period_s=0.0,
                     boot_s=self.replica_boot_s, restore_s=0.0)
        job.remaining_s = job.work_s
        self.jobs.append(job)
        self.replica_jids.add(job.jid)
        self.coldstarts += 1
        self._emit("scale_out", f"j{job.jid} cold")
        self._enqueue(job)
        self._schedule_queue()
        return job.jid

    def _active_replicas(self) -> int:
        """Replicas serving or on their way up (not parked/parking)."""
        return sum(1 for jid in self.replica_jids
                   if self.jobs[jid].state in (QUEUED, BOOTING, RUNNING)
                   and jid not in self._parking)

    def _scale_out(self) -> None:
        if self.parked_jids:
            jid = self.parked_jids.pop(0)
            job = self.jobs[jid]
            job.state = QUEUED
            self.unparks += 1
            self._emit("scale_out", f"j{jid} unpark")
            self._enqueue(job)
            self._schedule_queue()
        else:
            self._new_replica()

    def _scale_in(self, jid: int) -> None:
        """Stop routing to an idle replica and start its swap-out; the
        hosts free (for batch work) when the suspend write completes."""
        self.live.remove(jid)
        del self._slots[jid]
        self._parking.add(jid)
        self.parks += 1
        self._emit("scale_in", f"j{jid}")
        self.q.schedule(self.now + self.suspend_s, "park_done", jid)

    def _on_park_done(self, ev) -> None:
        jid = ev.payload
        self._parking.discard(jid)
        job = self.jobs[jid]
        if job.state != RUNNING:                # faulted mid-swap-out
            return
        self._halt(job)
        job.state = PARKED
        self.parked_jids.append(jid)
        self._emit("parked", f"j{jid}")
        self._schedule_queue()                  # batch takes the hosts

    def _on_autoscale(self, ev) -> None:
        p = self.policy
        qps = self._window_arrivals / max(p.eval_period_s, 1e-9)
        self._window_arrivals = 0
        cap = (self.concurrency / self.service_s) * p.target_util
        desired = max(p.min_replicas,
                      min(p.max_replicas, math.ceil(qps / max(cap, 1e-9))))
        active = self._active_replicas()
        if desired > active:
            for _ in range(desired - active):
                self._scale_out()
        elif desired < active:
            # only genuinely idle replicas park, oldest-id first
            idle = [jid for jid in self.live
                    if self._busy_until.get(jid, 0.0)
                    <= self.now - p.scale_in_idle_s]
            for jid in idle[:active - desired]:
                if self._active_replicas() <= p.min_replicas:
                    break
                self._scale_in(jid)
        self.q.schedule(self.now + p.eval_period_s, "autoscale", None)

    # ------------------------------------------------------------------
    # engine-event overrides (replica bookkeeping rides the host paths)
    # ------------------------------------------------------------------
    def _place(self, job: SimJob, resume: bool) -> None:
        super()._place(job, resume)
        if job.jid in self.replica_jids:
            self._hold_start[job.jid] = self.now

    def _release(self, job: SimJob) -> None:
        if job.jid in self.replica_jids and job.hosts:
            t0 = self._hold_start.pop(job.jid, self.now)
            self.replica_host_s += (self.now - t0) * len(job.hosts)
        super()._release(job)

    def _halt(self, job: SimJob) -> None:
        # a host fault can kill a LIVE replica: drop it from routing
        if job.jid in self.replica_jids:
            if job.jid in self.live:
                self.live.remove(job.jid)
                self._slots.pop(job.jid, None)
        super()._halt(job)

    def _on_fault(self, ev) -> None:
        jid = self.host_job.get(ev.payload)
        super()._on_fault(ev)                   # halts + re-enqueues the job
        if jid is not None and jid in self.replica_jids:
            self._emit("replica_fault", f"j{jid}")

    def _on_boot_done(self, ev) -> None:
        job = self.jobs[ev.payload]
        was_booting = job.state == BOOTING
        super()._on_boot_done(ev)
        if (was_booting and job.state == RUNNING
                and job.jid in self.replica_jids):
            self.live.append(job.jid)
            self.live.sort()
            self._slots[job.jid] = [self.now] * self.concurrency
            self._busy_until[job.jid] = self.now
            self._emit("replica_up", f"j{job.jid}")
            if self.pending:
                backlog, self.pending = self.pending, []
                for t in backlog:
                    self._serve(t)

    # ------------------------------------------------------------------
    # data plane: arithmetic request handling between events
    # ------------------------------------------------------------------
    def _serve(self, t: float) -> None:
        """Route one arrival to the live replica that can start it
        soonest (least-outstanding; lowest jid tie-break — the Router
        discipline, expressed over slot availability)."""
        best_jid = -1
        best_start = 0.0
        for jid in self.live:                   # sorted: ties -> lowest jid
            free = self._slots[jid][0]
            start = free if free > t else t
            if best_jid < 0 or start < best_start:
                best_jid, best_start = jid, start
        if best_jid < 0:
            self.pending.append(t)
            return
        done = best_start + self.service_s
        heapq.heapreplace(self._slots[best_jid], done)
        if done > self._busy_until.get(best_jid, 0.0):
            self._busy_until[best_jid] = done
        self.latencies.append(done - t)
        self.served += 1

    def _consume_arrivals(self, t_limit: float) -> None:
        nxt = self._next_arrival
        while nxt is not None and nxt <= t_limit:
            self.requests += 1
            self._window_arrivals += 1
            self._serve(nxt)
            nxt = next(self._arrivals, None)
        self._next_arrival = nxt

    def run(self, until: Optional[float] = None) -> None:
        end = self.req_trace.horizon_s if until is None else until
        while True:
            ev = self.q.pop()
            if ev is None or ev.time > end:
                break
            self._consume_arrivals(ev.time)
            self.now = ev.time
            self.events_fired += 1
            getattr(self, f"_on_{ev.kind}")(ev)
            if self.used + len(self.free) != self.n_hosts:
                raise InvariantViolation(
                    f"t={self.now}: {self.used} used + {len(self.free)} "
                    f"free != {self.n_hosts} hosts")
            if self.events_fired % self.DEEP_CHECK_EVERY == 0:
                self.check_invariants()
        self._consume_arrivals(end)
        self.now = max(self.now, end)
        self._settle_holds()
        self.check_invariants()

    def _settle_holds(self) -> None:
        """Account host time still held by live/booting replicas up to
        now (idempotent: the hold window restarts at now)."""
        for jid, t0 in list(self._hold_start.items()):
            hosts = len(self.jobs[jid].hosts)
            self.replica_host_s += (self.now - t0) * hosts
            self._hold_start[jid] = self.now

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        super().check_invariants()
        for jid in self.live:
            if self.jobs[jid].state != RUNNING:
                raise InvariantViolation(
                    f"t={self.now}: live replica j{jid} not RUNNING")
        for jid in self.parked_jids:
            if self.jobs[jid].state != PARKED:
                raise InvariantViolation(
                    f"t={self.now}: parked replica j{jid} not PARKED")

    def latency_percentile(self, p: float) -> float:
        if not self.latencies:
            return 0.0
        lat = sorted(self.latencies)
        idx = min(len(lat) - 1, int(p / 100.0 * len(lat)))
        return lat[idx]

    def fleet_stats(self) -> Dict[str, float]:
        batch_done = self.completed
        return {
            "requests": float(self.requests),
            "served": float(self.served),
            "p50_s": self.latency_percentile(50.0),
            "p99_s": self.latency_percentile(99.0),
            "replica_host_s": self.replica_host_s,
            "served_qps_per_host": (self.served / self.replica_host_s
                                    if self.replica_host_s > 0 else 0.0),
            "coldstarts": float(self.coldstarts),
            "parks": float(self.parks),
            "unparks": float(self.unparks),
            "batch_completed": float(batch_done),
        }
