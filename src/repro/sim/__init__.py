"""Virtual-time simulation layer.

* ``Clock`` / ``WallClock`` / ``SimClock`` — the time-source protocol the
  whole control plane sleeps and waits through.  Production installs
  ``WallClock`` (real time, unchanged behavior); tests install a
  ``SimClock`` that jumps straight to the next pending deadline.
* ``EventQueue`` — deterministic ``(time, seq)`` priority queue.
* ``SimEngine`` — pure single-threaded discrete-event cluster simulation
  for large-scale deterministic scenarios (thousands of hosts, simulated
  weeks, byte-identical traces).
* ``sim/serve.py`` — ``ServeFleetEngine``, a SimEngine subclass that adds
  an autoscaled serving tier (replica boots/parks as events, millions of
  requests handled arithmetically between events).  Imported directly as
  ``repro.sim.serve`` — not re-exported here, to keep this package free
  of a dependency on ``repro.serve``.
"""
from repro.sim.engine import InvariantViolation, SimEngine, SimJob
from repro.sim.simtime import (TIME_SCALE, Clock, Event, EventQueue,
                               SimClock, WallClock, active_clock,
                               install_clock, use_clock)

__all__ = [
    "TIME_SCALE", "Clock", "Event", "EventQueue", "SimClock", "WallClock",
    "active_clock", "install_clock", "use_clock",
    "InvariantViolation", "SimEngine", "SimJob",
]
