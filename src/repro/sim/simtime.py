"""Virtual-time layer: the ``Clock`` protocol, the wall-clock production
implementation, and the discrete-event ``SimClock``.

The repo models two kinds of durations:

* **paper seconds** — quantities calibrated against the paper (boot costs,
  iteration times, fault-schedule offsets).  Under the wall clock one paper
  second costs ``TIME_SCALE`` wall seconds (``sim_sleep``'s compression).
* **wall-tuned seconds** — raw operational knobs (monitor poll interval,
  scheduler tick, store latency) that were historically real wall seconds.

``SimClock`` unifies both onto a single virtual axis whose unit is the
paper second: paper durations map 1:1, wall-tuned durations map through
``1/TIME_SCALE`` — so every *relative* timing in the system is identical
to a wall-clock run, only nothing ever actually sleeps.  Virtual time
advances by jumping straight to the earliest pending deadline in one
priority queue of ``(deadline, seq)`` waiters (deterministic FIFO
tie-break), which is what turns a multi-day scenario into milliseconds.

Production code paths never change behavior: the default installed clock
is ``WallClock`` and every method degenerates to ``time.sleep`` /
``Event.wait`` exactly as before.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator, List, Optional, Protocol, Set, Tuple

# One paper (virtual) second costs this many wall seconds under the wall
# clock.  This is the canonical definition; ``repro.clusters.simulator``
# re-exports it for backward compatibility.
TIME_SCALE = 0.01


class Clock(Protocol):
    """What the control plane needs from a time source.

    ``scale`` is *native seconds per paper second* (``TIME_SCALE`` for the
    wall clock, ``1.0`` for ``SimClock``), so ``(t1 - t0) / clock.scale``
    converts any pair of same-clock stamps to paper seconds.
    """

    scale: float

    def now(self) -> float: ...                       # native, monotonic
    def timestamp(self) -> float: ...                 # native, history stamps
    def sleep(self, wall_s: float) -> None: ...       # wall-tuned duration
    def paper_sleep(self, paper_s: float) -> None: ...
    def sleep_until(self, t_native: float) -> None: ...
    def from_wall(self, wall_s: float) -> float: ...  # wall-tuned -> native
    def wait(self, event: threading.Event,
             timeout: Optional[float] = None) -> bool: ...  # wall-tuned


class WallClock:
    """Real time.  Behaviorally identical to the pre-Clock code paths."""

    scale = TIME_SCALE

    def now(self) -> float:
        return time.monotonic()

    def timestamp(self) -> float:
        return time.time()

    def from_wall(self, wall_s: float) -> float:
        return wall_s

    def sleep(self, wall_s: float) -> None:
        if wall_s > 0:
            time.sleep(wall_s)

    def paper_sleep(self, paper_s: float) -> None:
        if paper_s > 0:
            time.sleep(paper_s * TIME_SCALE)

    def sleep_until(self, t_native: float) -> None:
        self.sleep(t_native - self.now())

    def wait(self, event: threading.Event,
             timeout: Optional[float] = None) -> bool:
        return event.wait(timeout)


# ---------------------------------------------------------------------------
# Deterministic event queue (shared by SimClock's waiter heap and the pure
# single-threaded engine in repro.sim.engine).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Event:
    """One scheduled occurrence.  Ordering is ``(time, seq)`` — ``seq`` is
    assignment order, so ties break FIFO and a replay that schedules the
    same events in the same order pops them in the identical order
    regardless of ``PYTHONHASHSEED`` (nothing here hashes anything)."""
    time: float
    seq: int
    kind: str
    payload: Any = None
    cancelled: bool = False


class EventQueue:
    """Priority queue of :class:`Event` with deterministic tie-breaking,
    O(log n) schedule/pop and O(1) cancel (lazy deletion)."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._live = 0

    def __len__(self) -> int:
        return self._live

    def schedule(self, at: float, kind: str, payload: Any = None) -> Event:
        ev = Event(time=float(at), seq=next(self._seq), kind=kind,
                   payload=payload)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._live += 1
        return ev

    def cancel(self, ev: Event) -> bool:
        """Cancel a pending event; returns False if already fired/cancelled."""
        if ev.cancelled:
            return False
        ev.cancelled = True
        self._live -= 1
        return True

    def reschedule(self, ev: Event, at: float) -> Event:
        """Cancel ``ev`` and schedule a fresh event at ``at`` (new seq —
        a rescheduled event loses its place in the FIFO tie-break)."""
        self.cancel(ev)
        return self.schedule(at, ev.kind, ev.payload)

    def _drop_cancelled(self) -> None:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        self._drop_cancelled()
        return self._heap[0][2] if self._heap else None

    def next_time(self) -> Optional[float]:
        ev = self.peek()
        return None if ev is None else ev.time

    def pop(self) -> Optional[Event]:
        self._drop_cancelled()
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)[2]
        self._live -= 1
        return ev

    def drain(self) -> Iterator[Event]:
        while True:
            ev = self.pop()
            if ev is None:
                return
            yield ev


# ---------------------------------------------------------------------------
# SimClock — the discrete-event virtual clock for the threaded stack.
# ---------------------------------------------------------------------------

class SimClock:
    """Auto-advancing virtual clock.

    Every sleeper/waiter registers a ``(deadline, seq)`` entry in one
    priority queue; a background advancer jumps ``now`` to the earliest
    pending deadline whenever waiters exist (after a tiny wall ``grace_s``
    so threads that just woke can reach their next sleep and keep their
    relative pacing).  Deadlines are computed as ``now + dt`` at sleep
    time, so advancing never violates causality.

    Native unit: the paper second.  ``sleep()`` takes historically
    wall-tuned durations and maps them through ``1/TIME_SCALE`` so all
    relative cadences (monitor poll vs. app iteration vs. store latency)
    match a wall-clock run exactly.
    """

    # how long Event.wait-style blocking may go unnoticed after a set()
    # that nobody notifies the clock about (pure wall backstop)
    _POLL_CAP_S = 0.02

    def __init__(self, start: float = 0.0, grace_s: float = 0.0002):
        self.scale = 1.0
        self.grace_s = grace_s
        self._now = float(start)
        self._cond = threading.Condition()
        self._waiters: List[Tuple[float, int]] = []    # (deadline, seq)
        self._seq = itertools.count()
        self._dead: Set[int] = set()                   # abandoned waiters
        self._closed = False
        self.advances = 0                              # observability
        self._thread = threading.Thread(
            target=self._advance_loop, daemon=True, name="simclock-advancer")
        self._thread.start()

    # ---- Clock protocol -------------------------------------------------
    def now(self) -> float:
        return self._now

    def timestamp(self) -> float:
        return self._now

    def from_wall(self, wall_s: float) -> float:
        return wall_s / TIME_SCALE

    def sleep(self, wall_s: float) -> None:
        self.sleep_virtual(self.from_wall(wall_s))

    def paper_sleep(self, paper_s: float) -> None:
        self.sleep_virtual(paper_s)

    def sleep_until(self, t_native: float) -> None:
        self.sleep_virtual(t_native - self._now)

    def wait(self, event: threading.Event,
             timeout: Optional[float] = None) -> bool:
        """Virtual-deadline Event.wait.  A set() is noticed within
        ``_POLL_CAP_S`` wall seconds; the timeout elapses in virtual time
        (instantly, when the system is otherwise idle)."""
        if event.is_set():
            return True
        if self._closed:
            return event.is_set()
        if timeout is None:
            while not self._closed and not event.wait(self._POLL_CAP_S):
                pass
            return event.is_set()
        with self._cond:
            deadline = self._now + self.from_wall(timeout)
            seq = next(self._seq)
            heapq.heappush(self._waiters, (deadline, seq))
            self._cond.notify_all()
            try:
                while not self._closed and self._now < deadline:
                    if event.is_set():
                        return True
                    self._cond.wait(self._POLL_CAP_S)
            finally:
                if self._now < deadline:        # early exit: drop the entry
                    self._dead.add(seq)
        return event.is_set()

    # ---- internals -------------------------------------------------------
    def sleep_virtual(self, dt: float) -> None:
        if dt <= 0 or self._closed:
            return
        with self._cond:
            deadline = self._now + dt
            heapq.heappush(self._waiters, (deadline, next(self._seq)))
            self._cond.notify_all()
            while not self._closed and self._now < deadline:
                self._cond.wait(self._POLL_CAP_S)

    def pending_deadlines(self) -> Tuple[float, ...]:
        """Sorted snapshot of the live waiter deadlines. Introspection
        for tests: "is some thread pinned in a long virtual sleep?" can
        be answered directly instead of being inferred from wall-time
        thread scheduling (which is racy on a loaded machine)."""
        with self._cond:
            return tuple(sorted(d for d, seq in self._waiters
                                if seq not in self._dead))

    def _prune(self) -> None:
        while self._waiters and self._waiters[0][1] in self._dead:
            self._dead.discard(heapq.heappop(self._waiters)[1])

    def _advance_loop(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    return
                self._prune()
                if not self._waiters:
                    self._cond.wait(0.05)
                    continue
            # grace outside the lock: threads that just woke get a moment
            # to register their next sleep before we pick the earliest
            # deadline — this is what preserves relative pacing
            if self.grace_s > 0:
                time.sleep(self.grace_s)
            with self._cond:
                if self._closed:
                    return
                self._prune()
                if not self._waiters:
                    continue
                deadline = self._waiters[0][0]
                if deadline > self._now:
                    self._now = deadline
                    self.advances += 1
                while self._waiters and self._waiters[0][0] <= self._now:
                    self._dead.discard(heapq.heappop(self._waiters)[1])
                self._cond.notify_all()

    def close(self) -> None:
        """Wake every sleeper immediately and stop advancing.  Idempotent;
        called by the test fixture before tearing services down so no
        daemon blocks teardown on a virtual deadline."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._thread is not threading.current_thread():
            self._thread.join(timeout=2)


# ---------------------------------------------------------------------------
# The installed clock.  Module-level so deep call sites (sim_sleep, store
# latency, daemon loops) need no signature changes; tests swap it with
# use_clock()/install_clock().
# ---------------------------------------------------------------------------

_WALL = WallClock()
_active: Clock = _WALL


def active_clock() -> Clock:
    return _active


def install_clock(clock: Optional[Clock]) -> Clock:
    """Install ``clock`` (None restores the wall clock); returns the
    previously installed clock."""
    global _active
    prev = _active
    _active = clock if clock is not None else _WALL
    return prev


@contextmanager
def use_clock(clock: Clock):
    prev = install_clock(clock)
    try:
        yield clock
    finally:
        install_clock(prev)
