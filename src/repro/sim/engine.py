"""Pure single-threaded discrete-event cluster simulation.

``SimClock`` (repro.sim.simtime) makes the *threaded* control plane run on
virtual time; this module is the complementary piece for scale: a
deterministic engine that replays the paper's scheduling story — arrivals,
boot costs, periodic checkpoints, host faults with checkpoint-bounded
rollback, priority preemption with aging — over thousands of hosts and a
simulated week in seconds of wall time, with a byte-identical event trace
for a given seed.

Everything is driven off one :class:`~repro.sim.simtime.EventQueue`
(``(time, seq)`` ordering, FIFO tie-break); the only randomness is a
``random.Random(seed)`` stream; no dict/set iteration order reaches the
trace — so two fresh processes with different ``PYTHONHASHSEED`` produce
the same bytes.

Scheduler semantics deliberately mirror ``core/scheduler.py``'s
GlobalScheduler invariants (capacity safety, priority + aging, preempt
only strictly-lower priority and only when it actually makes the job fit,
FIFO among equals), so the soak test exercises the same policy shape the
property suite checks on the real implementation.

Because aging is uniform (``eff = pri + rate * (now - queued_at)``), the
*relative* order of two waiters never changes while both wait — the
``rate * now`` term is common to both.  The wait queue is therefore kept
as a bisect-maintained sorted list keyed by ``rate * queued_at - pri``
that never needs re-sorting, which is what keeps a congested week-long
trace near-linear in the number of events.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import heapq
import random
from typing import Dict, List, Optional, Tuple

from repro.sim.simtime import Event, EventQueue

QUEUED, BOOTING, RUNNING, DONE = range(4)

_MAX_PRI = 9
_MAX_VMS = 8


@dataclasses.dataclass
class SimJob:
    jid: int
    arrival_s: float
    n_vms: int
    priority: int
    work_s: float                       # total compute to finish
    ckpt_period_s: float
    boot_s: float                       # allocate + provision cost
    restore_s: float                    # checkpoint restore cost
    state: int = QUEUED
    remaining_s: float = 0.0            # work left at last (re)start
    saved_s: float = 0.0                # progress protected by a checkpoint
    started_at: float = 0.0             # virtual time the current run began
    queued_at: float = 0.0
    hosts: Tuple[int, ...] = ()
    boot_ev: Optional[Event] = None
    run_ev: Optional[Event] = None
    ckpt_ev: Optional[Event] = None
    preemptions: int = 0
    recoveries: int = 0
    finished_at: float = -1.0

    def progress_now(self, now: float) -> float:
        done = self.work_s - self.remaining_s
        if self.state == RUNNING:
            done += now - self.started_at
        return min(done, self.work_s)


class InvariantViolation(AssertionError):
    pass


class SimEngine:
    """Seeded cluster + workload + fault process over an EventQueue.

    Usage::

        eng = SimEngine(n_hosts=1000, seed=7)
        eng.load(n_jobs=10_000, horizon_s=7 * 86400.0)
        eng.run()
        eng.trace_digest()   # byte-identical for identical (args, seed)
    """

    #: run the full O(jobs) cross-check every this many events (the O(1)
    #: counter check runs on every single event)
    DEEP_CHECK_EVERY = 1000

    def __init__(self, n_hosts: int, seed: int, *,
                 aging_rate: float = 1.0 / 600.0,
                 host_mtbf_s: float = 0.0):
        self.n_hosts = n_hosts
        self.seed = seed
        self.aging_rate = aging_rate
        self.host_mtbf_s = host_mtbf_s
        self.rng = random.Random(seed)
        self.q = EventQueue()
        self.now = 0.0
        self.jobs: List[SimJob] = []
        self.free: List[int] = list(range(n_hosts))     # min-heap
        self.used = 0
        self.host_job: Dict[int, int] = {}              # host -> jid
        # wait queue: sorted (age_key, jid); age_key = rate*queued_at - pri,
        # ascending == highest effective priority first (see module doc)
        self.waiting: List[Tuple[float, int]] = []
        self.wait_pri_count = [0] * (_MAX_PRI + 1)      # by raw priority
        self.wait_vms_count = [0] * (_MAX_VMS + 1)      # by VM ask
        self.running: List[int] = []                    # jids, unordered
        self.trace: List[str] = []
        self.completed = 0
        self.preemptions = 0
        self.recoveries = 0
        self.max_wait_s = 0.0
        self.events_fired = 0
        self.sched_scans = 0                            # observability

    # ---- workload generation -------------------------------------------
    def load(self, n_jobs: int, horizon_s: float, *,
             arrival_horizon_s: Optional[float] = None,
             max_vms: int = _MAX_VMS, mean_work_s: float = 3600.0,
             ckpt_period_s: float = 900.0,
             boot_s: float = 30.0, restore_s: float = 60.0,
             max_priority: int = _MAX_PRI) -> None:
        """Seeded open arrivals (uniform order statistics — deterministic
        for the seed).  ``arrival_horizon_s`` (default: ``horizon_s``)
        bounds *arrivals*; host faults span the full ``horizon_s`` — pack
        arrivals into a shorter window to create over-subscription.
        ``max_priority`` caps the drawn priorities — a workload sharing
        the cluster with always-on serving replicas (sim/serve.py pins
        those at ``_MAX_PRI``) draws batch jobs strictly below them."""
        if not 1 <= max_priority <= _MAX_PRI:
            raise ValueError(f"max_priority must be in [1, {_MAX_PRI}]")
        span = arrival_horizon_s or horizon_s
        arrivals = sorted(self.rng.uniform(0.0, span) for _ in range(n_jobs))
        base = len(self.jobs)
        for i, at in enumerate(arrivals):
            job = SimJob(
                jid=base + i, arrival_s=at,
                n_vms=self.rng.randint(1, max_vms),
                priority=self.rng.randint(1, max_priority),
                work_s=self.rng.expovariate(1.0 / mean_work_s) + 60.0,
                ckpt_period_s=ckpt_period_s,
                boot_s=boot_s, restore_s=restore_s)
            job.remaining_s = job.work_s
            self.jobs.append(job)
            self.q.schedule(at, "arrive", job.jid)
        if self.host_mtbf_s > 0:
            # one Poisson fault process for the whole fleet
            rate = self.n_hosts / self.host_mtbf_s
            t = self.rng.expovariate(rate)
            while t < horizon_s:
                self.q.schedule(t, "fault", self.rng.randrange(self.n_hosts))
                t += self.rng.expovariate(rate)

    # ---- event loop -----------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        while True:
            ev = self.q.pop()
            if ev is None:
                break
            if until is not None and ev.time > until:
                break
            self.now = ev.time
            self.events_fired += 1
            getattr(self, f"_on_{ev.kind}")(ev)
            if self.used + len(self.free) != self.n_hosts:
                raise InvariantViolation(
                    f"t={self.now}: {self.used} used + {len(self.free)} "
                    f"free != {self.n_hosts} hosts")
            if self.events_fired % self.DEEP_CHECK_EVERY == 0:
                self.check_invariants()
        self.check_invariants()

    def _emit(self, kind: str, detail: str) -> None:
        self.trace.append(f"{self.now:.6f} {kind} {detail}")

    # ---- wait-queue bookkeeping -----------------------------------------
    def _enqueue(self, job: SimJob) -> None:
        job.state = QUEUED
        job.queued_at = self.now
        key = self.aging_rate * job.queued_at - job.priority
        bisect.insort(self.waiting, (key, job.jid))
        self.wait_pri_count[job.priority] += 1
        self.wait_vms_count[job.n_vms] += 1

    def _min_wait_vms(self) -> int:
        for vms in range(1, _MAX_VMS + 1):
            if self.wait_vms_count[vms]:
                return vms
        return _MAX_VMS + 1

    # ---- handlers -------------------------------------------------------
    def _on_arrive(self, ev: Event) -> None:
        job = self.jobs[ev.payload]
        self._enqueue(job)
        self._emit("arrive", f"j{job.jid} vms={job.n_vms} pri={job.priority}")
        self._schedule_queue()

    def _on_boot_done(self, ev: Event) -> None:
        job = self.jobs[ev.payload]
        if job.state != BOOTING:
            return
        job.boot_ev = None
        job.state = RUNNING
        job.started_at = self.now
        self.running.append(job.jid)
        job.run_ev = self.q.schedule(self.now + job.remaining_s,
                                     "run_done", job.jid)
        if job.ckpt_period_s > 0:
            job.ckpt_ev = self.q.schedule(self.now + job.ckpt_period_s,
                                          "ckpt", job.jid)
        self._emit("start", f"j{job.jid} hosts={len(job.hosts)}")

    def _on_ckpt(self, ev: Event) -> None:
        job = self.jobs[ev.payload]
        if job.state != RUNNING:
            return
        job.saved_s = job.progress_now(self.now)
        job.ckpt_ev = self.q.schedule(self.now + job.ckpt_period_s,
                                      "ckpt", job.jid)
        self._emit("ckpt", f"j{job.jid} saved={job.saved_s:.3f}")

    def _on_run_done(self, ev: Event) -> None:
        job = self.jobs[ev.payload]
        if job.state != RUNNING:
            return
        job.run_ev = None
        job.remaining_s = 0.0
        self.running.remove(job.jid)
        self._release(job)
        job.state = DONE
        job.finished_at = self.now
        self.completed += 1
        wait = max(0.0, (self.now - job.arrival_s) - job.work_s - job.boot_s)
        self.max_wait_s = max(self.max_wait_s, wait)
        self._emit("done", f"j{job.jid}")
        self._schedule_queue()

    def _on_fault(self, ev: Event) -> None:
        host = ev.payload
        jid = self.host_job.get(host)
        if jid is None:
            self._emit("fault", f"h{host} idle")
            return
        job = self.jobs[jid]
        lost = job.progress_now(self.now) - job.saved_s
        self._halt(job)
        # roll back to the last checkpoint: progress past saved_s is lost
        job.remaining_s = job.work_s - job.saved_s
        job.recoveries += 1
        self.recoveries += 1
        self._enqueue(job)
        self._emit("fault", f"h{host} j{job.jid} lost={lost:.3f}")
        self._schedule_queue()

    # ---- allocation -----------------------------------------------------
    def _halt(self, job: SimJob) -> None:
        """Stop a running/booting job, cancelling its pending events."""
        if job.boot_ev is not None:
            self.q.cancel(job.boot_ev)
            job.boot_ev = None
        if job.run_ev is not None:
            self.q.cancel(job.run_ev)
            job.run_ev = None
        if job.ckpt_ev is not None:
            self.q.cancel(job.ckpt_ev)
            job.ckpt_ev = None
        if job.state == RUNNING:
            job.remaining_s = job.work_s - job.progress_now(self.now)
            self.running.remove(job.jid)
        self._release(job)

    def _release(self, job: SimJob) -> None:
        for h in job.hosts:
            del self.host_job[h]
            heapq.heappush(self.free, h)
        self.used -= len(job.hosts)
        job.hosts = ()

    def _place(self, job: SimJob, resume: bool) -> None:
        hosts = tuple(heapq.heappop(self.free) for _ in range(job.n_vms))
        for h in hosts:
            self.host_job[h] = job.jid
        self.used += len(hosts)
        job.hosts = hosts
        job.state = BOOTING
        cost = job.boot_s + (job.restore_s if resume else 0.0)
        job.boot_ev = self.q.schedule(self.now + cost, "boot_done", job.jid)

    # ---- scheduling ------------------------------------------------------
    def _schedule_queue(self) -> None:
        # victim preemptions re-enqueue mid-pass; iterate to fixpoint
        while self._schedule_pass():
            pass

    def _schedule_pass(self) -> bool:
        if not self.waiting:
            return False
        run_sorted: Optional[List[int]] = None   # (pri, jid)-ordered, lazy
        low_pri = (min(self.jobs[v].priority for v in self.running)
                   if self.running else _MAX_PRI + 1)
        placed: List[Tuple[float, int]] = []
        for entry in list(self.waiting):         # snapshot: pass may insort
            _, jid = entry
            job = self.jobs[jid]
            if job.state != QUEUED:              # placed earlier this pass
                continue
            self.sched_scans += 1
            if job.n_vms <= len(self.free):
                self._admit(job, entry, placed)
                continue
            # nothing left that could fit outright or preempt?  both
            # checks are O(priorities)/O(vm sizes) over count arrays
            if not any(self.wait_pri_count[p]
                       for p in range(low_pri + 1, _MAX_PRI + 1)):
                if len(self.free) < self._min_wait_vms():
                    break
                continue
            if job.priority <= low_pri:
                continue                         # cannot preempt anyone
            # victims: strictly lower *raw* priority, lowest (pri, jid)
            # first, and only if the sum actually makes the job fit
            if run_sorted is None:
                run_sorted = sorted(
                    self.running,
                    key=lambda v: (self.jobs[v].priority, v))
            victims: List[SimJob] = []
            freed = len(self.free)
            for vjid in run_sorted:
                v = self.jobs[vjid]
                if v.state != RUNNING:           # preempted earlier in pass
                    continue
                if v.priority >= job.priority:
                    break
                victims.append(v)
                freed += len(v.hosts)
                if freed >= job.n_vms:
                    break
            if freed < job.n_vms or not victims:
                continue                         # a smaller job may still fit
            for v in victims:
                # swap-out: progress up to now is checkpointed
                v.saved_s = v.progress_now(self.now)
                self._halt(v)
                v.preemptions += 1
                self.preemptions += 1
                self._enqueue(v)
                self._emit("preempt", f"j{v.jid} by=j{jid}")
            low_pri = (min(self.jobs[v].priority for v in self.running)
                       if self.running else _MAX_PRI + 1)
            self._admit(job, entry, placed)
        if not placed:
            return False
        gone = set(placed)
        self.waiting = [e for e in self.waiting if e not in gone]
        return True

    def _admit(self, job: SimJob, entry: Tuple[float, int],
               placed: List[Tuple[float, int]]) -> None:
        self.wait_pri_count[job.priority] -= 1
        self.wait_vms_count[job.n_vms] -= 1
        resume = job.recoveries > 0 or job.preemptions > 0
        self._place(job, resume)
        placed.append(entry)
        self._emit("place", f"j{job.jid}")

    # ---- invariants ------------------------------------------------------
    def check_invariants(self) -> None:
        """Full O(jobs) capacity-safety cross-check."""
        used = sum(len(j.hosts) for j in self.jobs if j.hosts)
        if used != self.used:
            raise InvariantViolation(
                f"t={self.now}: used counter {self.used} != actual {used}")
        if used + len(self.free) != self.n_hosts:
            raise InvariantViolation(
                f"t={self.now}: {used} used + {len(self.free)} free "
                f"!= {self.n_hosts} hosts")
        if len(set(self.free)) != len(self.free):
            raise InvariantViolation(f"t={self.now}: double-freed host")
        pri_counts = [0] * (_MAX_PRI + 1)
        vms_counts = [0] * (_MAX_VMS + 1)
        for _, jid in self.waiting:
            j = self.jobs[jid]
            if j.state != QUEUED:
                raise InvariantViolation(
                    f"t={self.now}: j{jid} in waiting but not QUEUED")
            pri_counts[j.priority] += 1
            vms_counts[j.n_vms] += 1
        if pri_counts != self.wait_pri_count:
            raise InvariantViolation(
                f"t={self.now}: waiting priority counts drifted")
        if vms_counts != self.wait_vms_count:
            raise InvariantViolation(
                f"t={self.now}: waiting VM-size counts drifted")

    def assert_work_conserving(self) -> None:
        """No schedulable waiter may be left behind at quiescence."""
        for _, jid in self.waiting:
            j = self.jobs[jid]
            if j.n_vms <= len(self.free):
                raise InvariantViolation(
                    f"j{j.jid} waits ({j.n_vms} vms) with "
                    f"{len(self.free)} hosts free")

    # ---- trace -----------------------------------------------------------
    def trace_bytes(self) -> bytes:
        return "\n".join(self.trace).encode()

    def trace_digest(self) -> str:
        return hashlib.sha256(self.trace_bytes()).hexdigest()
