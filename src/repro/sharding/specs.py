"""Logical-dims → mesh-axes mapping (DP / FSDP / TP / EP / SP).

Every parameter leaf is created with a tuple of *logical dim names*
(``repro.models.layers.ParamBuilder``). This module maps those names onto
mesh axes, with divisibility-checked fallbacks, producing ``PartitionSpec``
trees for ``jax.jit`` in/out shardings.

Activation sharding inside model code goes through ``constrain(x, dims)``,
which is a no-op unless an ``activation_sharding(axes)`` context is active
(set by the launcher while tracing).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Which mesh axes implement each parallelism flavour."""
    dp: Tuple[str, ...]              # batch axes (("pod","data") or ("data",))
    fsdp: Optional[str]              # param-shard axis (subset of dp) or None
    tp: Optional[str]                # tensor-parallel axis
    ep: Optional[str]                # expert-parallel axis
    sp: Optional[str]                # sequence-shard axis (long prefill)
    sizes: Mapping[str, int]         # axis name -> size

    def size(self, ax: Optional[str]) -> int:
        return 1 if ax is None else self.sizes[ax]


def make_axes(mesh: jax.sharding.Mesh, *, use_fsdp: bool = False,
              seq_shard: bool = False) -> MeshAxes:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    dp = tuple(n for n in names if n in ("pod", "data"))
    tp = "model" if "model" in names else None
    return MeshAxes(
        dp=dp,
        fsdp="data" if (use_fsdp and "data" in names) else None,
        tp=tp,
        ep=tp,
        sp=tp if seq_shard else None,
        sizes=sizes,
    )


# Logical param-dim name -> which MeshAxes field shards it. Names ending in
# "_nt" are never sharded (small / replicated tensors).
_PARAM_RULES = {
    "vocab": "tp",
    "embed": "fsdp",
    "heads": "tp",
    "kv_heads": "tp",
    "head_dim": None,        # fallback target — see combined rule below
    "ff": "tp",
    "experts": "ep",
    "moe_embed": "fsdp",
    "moe_ff": None,
    "ssm_inner": "tp",
    "xl_inner": "tp",
    "xl_inner2": None,
    "layers": None,
    # activation/cache dims (serve-state leaves)
    "batch": "dp",
    "kvseq": "dp",     # context-parallel KV when batch can't shard (long_500k)
}


def _axis_for(name: Optional[str], axes: MeshAxes) -> Optional[str]:
    if name is None or name.endswith("_nt"):
        return None
    field = _PARAM_RULES.get(name)
    if field is None:
        return None
    return getattr(axes, field)


def leaf_spec(dims: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              axes: MeshAxes) -> P:
    """PartitionSpec for one leaf, with divisibility fallbacks.

    Combined rule: if a ``heads``/``kv_heads`` dim is not divisible by the tp
    axis, tp falls back to that leaf's ``head_dim`` dim (if divisible) — the
    standard GQA layout escape when head counts don't divide TP.
    """
    assignment: list = [None] * len(dims)
    used: set = set()

    def try_assign(i: int, ax: Optional[str]) -> bool:
        if ax is None:
            return False
        ax_t = ax if isinstance(ax, tuple) else (ax,)
        total = math.prod(axes.size(a) for a in ax_t)
        if any(a in used for a in ax_t):
            return False
        if shape[i] % total != 0 or total == 1:
            return False
        assignment[i] = ax if not isinstance(ax, tuple) else ax_t
        used.update(ax_t)
        return True

    head_fallback_needed = False
    for i, name in enumerate(dims):
        ax = _axis_for(name, axes)
        ok = try_assign(i, ax)
        # Q heads fall back to head_dim sharding. KV *projection weights*
        # whose head count doesn't divide TP are REPLICATED (hd-sharding
        # them forces replicate-then-reshard copies at the GQA einsum —
        # §Perf iteration A). KV *caches* ("kvseq" present) keep the
        # head_dim fallback: replicating a 32k-half-MB-per-token cache
        # would be catastrophic (§Perf decode iterations).
        if not ok and axes.tp and (
                name == "heads"
                or (name == "kv_heads" and "kvseq" in dims)):
            head_fallback_needed = True
    if head_fallback_needed and axes.tp not in used:
        for i, name in enumerate(dims):
            if name == "head_dim" and try_assign(i, axes.tp):
                break
    return P(*assignment)


def param_specs(dims_tree: Any, shapes_tree: Any, axes: MeshAxes) -> Any:
    """Map matching (dims, shape-struct) pytrees to a PartitionSpec pytree."""
    def one(dims, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        return leaf_spec(tuple(dims), tuple(shape), axes)
    return jax.tree.map(one, dims_tree, shapes_tree,
                        is_leaf=lambda d: isinstance(d, tuple))


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------

_ACTIVE: Optional[MeshAxes] = None


def active_axis_size(kind: str) -> int:
    """Size of the active context's axis ("tp"/"dp"/...), 1 if no context."""
    if _ACTIVE is None:
        return 1
    ax = getattr(_ACTIVE, kind, None)
    if ax is None:
        return 1
    ax_t = ax if isinstance(ax, tuple) else (ax,)
    return math.prod(_ACTIVE.size(a) for a in ax_t)


@contextlib.contextmanager
def activation_sharding(axes: Optional[MeshAxes]):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, axes
    try:
        yield
    finally:
        _ACTIVE = prev


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding. dims entries: "dp"|"sp"|"tp"|None."""
    axes = _ACTIVE
    if axes is None:
        return x
    spec: list = []
    used: set = set()
    for i, d in enumerate(dims):
        ax = {"dp": axes.dp, "sp": axes.sp, "tp": axes.tp, "ep": axes.ep,
              None: None}[d]
        if ax is None:
            spec.append(None)
            continue
        ax_t = ax if isinstance(ax, tuple) else (ax,)
        total = math.prod(axes.size(a) for a in ax_t)
        if total == 1 or any(a in used for a in ax_t) or x.shape[i] % total:
            spec.append(None)
        else:
            spec.append(ax if not isinstance(ax, tuple) else ax_t)
            used.update(ax_t)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Gang rank regions (reshard-on-restore)
# ---------------------------------------------------------------------------
# A gang job's global state is partitioned over its ranks along one axis
# (rows of the lead dimension, like a 1-D data-parallel mesh). These
# helpers are the single source of truth for that partition on BOTH sides:
# the gang writer stamps each rank's chunk at its region's global offset,
# and the gang restore recomputes regions for a *different* rank count —
# the reader's region-overlap assembly then reshards for free.

def even_regions(dim: int, n: int) -> List[Tuple[int, int]]:
    """Split ``dim`` rows over ``n`` ranks: [(offset, length)] per rank.

    The remainder spreads over the leading ranks (lengths differ by at
    most 1), every row is owned by exactly one rank, and the split is a
    pure function of (dim, n) — deterministic across save and restore.
    """
    if n <= 0:
        raise ValueError(f"need at least one rank, got {n}")
    base, rem = divmod(dim, n)
    regions, off = [], 0
    for r in range(n):
        length = base + (1 if r < rem else 0)
        regions.append((off, length))
        off += length
    return regions


def rank_region(shape: Tuple[int, ...], n_ranks: int, rank: int,
                axis: int = 0) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """One rank's (offset, shape) of a global array sharded along ``axis``."""
    off, length = even_regions(shape[axis], n_ranks)[rank]
    offset = tuple(off if i == axis else 0 for i in range(len(shape)))
    shp = tuple(length if i == axis else d for i, d in enumerate(shape))
    return offset, shp


def owner_of_row(dim: int, n_ranks: int, row: int) -> int:
    """Which rank owns ``row`` under ``even_regions(dim, n_ranks)`` —
    used to re-route drained in-flight messages after a reshard."""
    for r, (off, length) in enumerate(even_regions(dim, n_ranks)):
        if off <= row < off + length:
            return r
    raise ValueError(f"row {row} outside [0, {dim})")
