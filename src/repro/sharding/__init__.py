from repro.sharding.specs import (MeshAxes, activation_sharding, constrain,
                                  leaf_spec, make_axes, param_specs)

__all__ = ["MeshAxes", "activation_sharding", "constrain", "leaf_spec",
           "make_axes", "param_specs"]
