"""Deterministic, checkpointable synthetic data pipeline.

The iterator state is part of every checkpoint ({"seed", "step"}), so a
restarted/migrated job consumes *exactly* the byte stream it would have seen
without the failure — batch k is a pure function of (seed, k). On restore
under a different data-parallel degree (elastic migration), the same global
batch is simply re-sharded — determinism is topology-independent.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig


class TokenPipeline:
    def __init__(self, cfg: ArchConfig, global_batch: int, seq_len: int,
                 seed: int = 0, dtype=np.float32):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0
        self.dtype = dtype

    # ---- checkpointable state ------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"seed": self.seed, "step": self.step,
                "global_batch": self.global_batch, "seq_len": self.seq_len}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    # ---- batches ---------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.PCG64([self.seed, step]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step) — the determinism contract."""
        cfg = self.cfg
        rng = self._rng(step)
        B, S = self.global_batch, self.seq_len
        V = cfg.vocab_size

        def toks(b, s):
            # learnable structure: per-row arithmetic progressions with a
            # small random stride — next-token entropy << log(V), so test
            # runs can verify the loss actually falls
            start = rng.integers(0, V, size=(b, 1), dtype=np.int64)
            stride = rng.integers(1, 8, size=(b, 1), dtype=np.int64)
            seq = (start + stride * np.arange(s, dtype=np.int64)) % V
            return seq.astype(np.int32)

        if cfg.family == "encdec":
            tokens = toks(B, S)
            targets = np.roll(tokens, -1, axis=1)
            targets[:, -1] = -1
            frames = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(self.dtype) * 0.02
            return {"frames": frames, "tokens": tokens, "targets": targets}
        if cfg.frontend is not None:
            F = cfg.frontend_len
            tokens = toks(B, S - F)
            targets = np.full((B, S), -1, np.int32)
            targets[:, F:-1] = tokens[:, 1:]
            patches = rng.standard_normal(
                (B, F, cfg.d_model)).astype(self.dtype) * 0.02
            return {"patch_embeds": patches, "tokens": tokens,
                    "targets": targets}
        tokens = toks(B, S)
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = -1
        return {"tokens": tokens, "targets": targets}

    def next(self, sharding_tree: Optional[Any] = None) -> Dict[str, Any]:
        batch = self.batch_at(self.step)
        self.step += 1
        if sharding_tree is not None:
            batch = {k: jax.device_put(v, sharding_tree[k])
                     for k, v in batch.items()}
        return batch
