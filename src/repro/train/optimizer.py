"""AdamW + LR schedules + global-norm clipping (built from scratch in JAX).

State layout mirrors the param pytree ({"m": ..., "v": ...} + scalar count)
so the checkpoint substrate shards/saves it with the same logical dims as
the params (moments inherit each param's PartitionSpec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, grads: Params, opt_state: Dict[str, Any],
                 params: Params) -> Tuple[Params, Dict[str, Any],
                                          Dict[str, jax.Array]]:
    """-> (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9),
                      1.0) if cfg.grad_clip > 0 else 1.0
    lr = lr_at(cfg, opt_state["count"])
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (step + decay)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics


def opt_state_dims(param_dims: Any) -> Any:
    """Logical dims for the optimizer state (moments mirror params)."""
    return {"m": param_dims, "v": param_dims, "count": ()}
