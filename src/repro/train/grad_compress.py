"""Cross-pod gradient compression (beyond-paper distributed-optimization).

Within a pod, DP gradient reduction rides the fast ICI links; *between*
pods it crosses the much slower DCI. This module halves (bf16) or
quarters (int8 qsnap) the inter-pod bytes.

Mechanism: the train step runs inside ``jax.shard_map`` with ONLY the
``pod`` axis manual (data/model stay auto/GSPMD). Each pod computes the
loss over its own batch shard, autodiff reduces grads over data/model as
usual, and the pod-mean — the only inter-pod transfer — is done
explicitly on quantized payloads:

    codes, scales = qsnap_int8(grad)          # 4x fewer bytes
    all = all_gather((codes, scales), 'pod')  # int8 (+1/256 f32) on DCI
    grad = mean(dequant(all))

Exact for equal-sized pod shards; quantization error bounded per
256-block by absmax/127/2 (the checkpoint-image codec,
``repro.kernels.qsnap`` — on TPU the quantize/dequant run as the Pallas
kernel).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as kref

QSNAP_BLOCK = kref.QSNAP_BLOCK


def pod_mean_compressed(g: jax.Array, codec: str) -> jax.Array:
    """Mean over the manual 'pod' axis with compressed transfer.

    Quantization blocks run along the LAST dim only — flattening the whole
    tensor would merge (data/model)-sharded dims and force GSPMD to gather
    the full gradient per device first (measured: 2x total link bytes).
    Leading-dim shardings survive; the inter-pod all-gather moves each
    device's local shard in int8.
    """
    orig_dtype, orig_shape = g.dtype, g.shape
    if codec == "none":
        return jax.lax.pmean(g, "pod")
    if codec == "bf16":
        h_all = jax.lax.all_gather(g.astype(jnp.bfloat16), "pod")
        return jnp.mean(h_all.astype(jnp.float32),
                        axis=0).astype(orig_dtype)
    # int8: pad last dim to a 256-block multiple
    last = orig_shape[-1] if g.ndim else 1
    x = g.astype(jnp.float32)
    if g.ndim == 0:
        x = x.reshape(1)
        last = 1
    pad = (-last) % QSNAP_BLOCK
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    nb = x.shape[-1] // QSNAP_BLOCK
    blocks = x.reshape(*x.shape[:-1], nb, QSNAP_BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scales = jnp.where(scales == 0, 1.0, scales)
    codes = jnp.clip(jnp.round(blocks / scales), -127, 127).astype(jnp.int8)
    codes_all = jax.lax.all_gather(codes, "pod")          # int8 over DCI
    scales_all = jax.lax.all_gather(scales.astype(jnp.float32), "pod")
    deq = codes_all.astype(jnp.float32) * scales_all
    out = jnp.mean(deq, axis=0).reshape(*x.shape)
    out = out[..., :last] if pad else out
    return out.reshape(orig_shape).astype(orig_dtype)


def make_compressed_train_step(model, opt_cfg, mesh, *, axes=None,
                               remat=True, codec: str = "int8",
                               grad_specs=None):
    """Train step with compressed cross-pod gradient reduction.

    Requires a mesh with a 'pod' axis. The returned function has the same
    (state, batch) -> (state, metrics) signature as
    ``trainer.make_train_step``; batch leaves are pod-sharded on dim 0.
    """
    assert "pod" in mesh.axis_names, "needs a multi-pod mesh"
    import dataclasses as _dc
    from repro.sharding.specs import activation_sharding
    from repro.train.optimizer import adamw_update

    # inside the pod-manual region, activation specs must not mention the
    # (now-manual) pod axis — dp becomes ("data",) only
    inner_axes = axes
    if axes is not None and "pod" in axes.dp:
        inner_axes = _dc.replace(
            axes, dp=tuple(a for a in axes.dp if a != "pod"))

    def local_step(state, batch):
        # runs with 'pod' manual: batch is this pod's shard; params are
        # pod-replicated; data/model sharding is still GSPMD-auto.
        with activation_sharding(inner_axes):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat),
                has_aux=True)(state["params"])
            if grad_specs is not None:
                # pin grads to param shardings on the AUTO axes before the
                # pod transfer — the embedding-grad scatter otherwise loses
                # its sharding inside the partial-manual region (measured:
                # a 4.3GB full-gather per device)
                grads = jax.lax.with_sharding_constraint(grads, grad_specs)
            grads = jax.tree.map(
                lambda g: pod_mean_compressed(g, codec), grads)
            params, opt_state, om = adamw_update(
                opt_cfg, grads, state["opt_state"], state["params"])
        loss = jax.lax.pmean(loss, "pod")
        metrics = {"loss": loss, **aux, **om}
        return ({"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}, metrics)

    batch_spec = P("pod")               # shard batch dim over pods
    state_spec = P()                    # params/opt replicated over pods

    return jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(state_spec, batch_spec),
        out_specs=(state_spec, state_spec),
        axis_names={"pod"},
        check_vma=False,
    )
