from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   global_norm, lr_at, opt_state_dims)
from repro.train.trainer import (TrainerApp, init_state, make_train_step,
                                 state_dims)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "lr_at", "opt_state_dims", "TrainerApp", "init_state",
           "make_train_step", "state_dims"]
