"""Training loop + the CACS-hosted TrainerApp.

``make_train_step`` builds the jitted (and, under a mesh, fully sharded)
train step used by both the real trainer and the multi-pod dry-run.

``TrainerApp`` adapts a JAX training job to the CACS Application protocol —
the 2026 analogue of the paper's long-running MPI application: it is
checkpointed/suspended/migrated by the service without knowing how, and its
health hook reports NaN losses and stalls (paper §6.3: only the application
knows what "healthy" means).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.layout import PreEncodedLeaf
from repro.ckpt.plane import PreEncodedChunk
from repro.ckpt.snapshot import DeferredSnapshot, SnapshotHandle
from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.obs.telemetry import SampleView, registry, unique_name
from repro.kernels.qsnap import qsnap_encode_chunks
from repro.models.model import Model, build_model
from repro.sharding.specs import MeshAxes, activation_sharding
from repro.sim.simtime import active_clock
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   opt_state_dims)


def init_state(model: Model, key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt_state": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_dims(model: Model) -> Dict[str, Any]:
    pd = model.param_dims()
    return {"params": pd, "opt_state": opt_state_dims(pd), "step": ()}


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    axes: Optional[MeshAxes] = None, remat: bool = True,
                    grad_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_specs``: optional PartitionSpec tree for the gradients. Pinning
    grads to the param sharding right at the autodiff boundary lets SPMD
    emit reduce-scatters instead of full all-reduces for FSDP-sharded
    weight grads (§Perf MoE iteration: 2.7GB AR -> 170MB RS per layer).
    """

    def train_step(state, batch):
        with activation_sharding(axes):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat),
                has_aux=True)(state["params"])
            if grad_specs is not None:
                grads = jax.lax.with_sharding_constraint(grads, grad_specs)
            params, opt_state, om = adamw_update(
                opt_cfg, grads, state["opt_state"], state["params"])
        metrics = {"loss": loss, **aux, **om}
        return ({"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}, metrics)

    return train_step


def _device_encodable(x: Any) -> bool:
    """Leaves the device encode stage can handle: single-shard jax arrays
    (a sharded leaf would need per-shard chunk framing — those fall back
    to the host path, which handles shards natively)."""
    if not isinstance(x, jax.Array):
        return False
    try:
        return len(x.sharding.device_set) == 1
    except Exception:                              # noqa: BLE001
        return False


def encode_state_on_device(tree: Any, *, impl: Optional[str] = None,
                           interpret: bool = False) -> Any:
    """Replace array leaves with device-encoded ``QS01`` payloads.

    Runs ``kernels.qsnap.qsnap_encode_chunks`` over every single-shard
    jax.Array leaf: quantization happens on the accelerator, the D2H
    copy carries int8 codes + scales (~4x fewer bytes than f32), and the
    resulting ``PreEncodedLeaf``s flow through the writer's pass-through
    encode stage. Payloads are byte-identical to the host "int8" codec,
    so the image dedups and restores exactly like a host-compressed one.
    Non-array leaves (python scalars in iterator state) pass through and
    are framed losslessly by the host codec.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree)
    idx = [i for i, x in enumerate(flat) if _device_encodable(x)]
    payloads = qsnap_encode_chunks([flat[i] for i in idx], impl=impl,
                                   interpret=interpret)
    for i, payload in zip(idx, payloads):
        x = flat[i]
        chunk = PreEncodedChunk(payload, "int8")
        flat[i] = PreEncodedLeaf(
            shape=tuple(x.shape), dtype=str(x.dtype),
            chunks=[((0,) * x.ndim, tuple(x.shape), chunk)])
    return jax.tree_util.tree_unflatten(treedef, flat)


class TrainerApp:
    """A real JAX training job hosted by CACS.

    Checkpoint state is {"state": {params, opt_state, step}, "data": iterator
    state} — restoring it resumes the exact token stream and optimizer
    trajectory (verified bit-exact in tests).
    """

    def __init__(self, cfg: ArchConfig, *, global_batch: int = 4,
                 seq_len: int = 64, n_steps: int = 50,
                 opt: Optional[AdamWConfig] = None, seed: int = 0,
                 remat: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.opt_cfg = opt or AdamWConfig(warmup_steps=5, total_steps=n_steps)
        self.n_steps = n_steps
        self.seed = seed
        self.pipeline = TokenPipeline(cfg, global_batch, seq_len, seed=seed)
        self._train_step = jax.jit(
            make_train_step(self.model, self.opt_cfg, remat=remat))
        self._state: Optional[Dict[str, Any]] = None
        self._state_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last_loss: float = float("nan")
        self.losses: list = []
        self.step_times: list = []
        # seconds the loop was blocked per snapshot pin: the registry
        # histogram is the store; ckpt_stalls (below) is a read-only view
        self._stall_hist = registry().histogram(
            unique_name("trainer.ckpt_stall_s"))
        self._host_step = 0                  # mirrors state["step"] host-side
        self.restarts = 0
        self._started = False

    # ---- Application protocol ------------------------------------------
    def start(self, ctx, restore_state: Optional[Any]) -> None:
        if restore_state is not None:
            with self._state_lock:
                self._state = restore_state["state"]
                self.pipeline.load_state_dict(restore_state["data"])
                self._host_step = int(restore_state["data"]["step"])
            self.restarts += 1
        elif self._state is None:
            self._state = init_state(self.model, jax.random.PRNGKey(self.seed))
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._started = True

    def _run(self) -> None:
        clock = active_clock()
        while not self._stop.is_set() and self._host_step < self.n_steps:
            t0 = clock.now()
            batch = self.pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            new_state, metrics = self._train_step(self._state, batch)
            loss = float(metrics["loss"])
            # join the step OUTSIDE the lock — a concurrent snapshot
            # capture must never wait on device work
            new_state = jax.block_until_ready(new_state)
            with self._state_lock:
                self._state = new_state
                self._host_step += 1         # swap + count: one atomic unit
            self.last_loss = loss
            self.losses.append(loss)
            self.step_times.append(clock.now() - t0)

    @property
    def ckpt_stalls(self) -> "SampleView":
        """Per-snapshot pin stalls, as a list-like view over the registry
        histogram (len()/indexing kept for existing tests and examples)."""
        return SampleView(self._stall_hist)

    @property
    def current_step(self) -> int:
        # host-side mirror: reading it never forces a device sync (the
        # old int(state["step"]) stalled callers on the in-flight step)
        return self._host_step

    def checkpoint_state(self) -> Dict[str, Any]:
        with self._state_lock:
            state = self._state
            data = dict(self.pipeline.state_dict())
            data["step"] = self._host_step    # align stream with params
        return {"state": state, "data": data}

    def snapshot_async(self, *, step: Optional[int] = None,
                       codec: Optional[str] = None) -> SnapshotHandle:
        """Staged snapshot (Application protocol extension).

        Capture = pin the current state dict + iterator state under the
        lock (microseconds; jax arrays are immutable and ``_run`` swaps
        whole dicts, so references ARE a consistent snapshot). The
        device→host copy — or, when ``codec`` selects int8, the on-device
        qsnap encode — happens in ``resolve()`` on the checkpoint writer
        thread, overlapped with the next jitted step.
        """
        clock = active_clock()
        t0 = clock.now()
        with self._state_lock:
            state = self._state
            data = dict(self.pipeline.state_dict())
            data["step"] = host_step = self._host_step
        self._stall_hist.observe(clock.now() - t0)
        device_encode = codec in ("int8", "int8+zlib")

        def materialize():
            if device_encode:
                return {"state": encode_state_on_device(state), "data": data}
            return {"state": state, "data": data}

        return DeferredSnapshot(
            materialize, step=host_step if step is None else step)

    def healthy(self) -> bool:
        if not self.losses:
            return True
        return bool(np.isfinite(self.last_loss))

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=60)

    def is_done(self) -> bool:
        return self.current_step >= self.n_steps

    def progress(self) -> float:
        return self.current_step / max(self.n_steps, 1)
