"""Training loop + the CACS-hosted TrainerApp.

``make_train_step`` builds the jitted (and, under a mesh, fully sharded)
train step used by both the real trainer and the multi-pod dry-run.

``TrainerApp`` adapts a JAX training job to the CACS Application protocol —
the 2026 analogue of the paper's long-running MPI application: it is
checkpointed/suspended/migrated by the service without knowing how, and its
health hook reports NaN losses and stalls (paper §6.3: only the application
knows what "healthy" means).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.models.model import Model, build_model
from repro.sharding.specs import MeshAxes, activation_sharding
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   opt_state_dims)


def init_state(model: Model, key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt_state": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_dims(model: Model) -> Dict[str, Any]:
    pd = model.param_dims()
    return {"params": pd, "opt_state": opt_state_dims(pd), "step": ()}


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    axes: Optional[MeshAxes] = None, remat: bool = True,
                    grad_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_specs``: optional PartitionSpec tree for the gradients. Pinning
    grads to the param sharding right at the autodiff boundary lets SPMD
    emit reduce-scatters instead of full all-reduces for FSDP-sharded
    weight grads (§Perf MoE iteration: 2.7GB AR -> 170MB RS per layer).
    """

    def train_step(state, batch):
        with activation_sharding(axes):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat),
                has_aux=True)(state["params"])
            if grad_specs is not None:
                grads = jax.lax.with_sharding_constraint(grads, grad_specs)
            params, opt_state, om = adamw_update(
                opt_cfg, grads, state["opt_state"], state["params"])
        metrics = {"loss": loss, **aux, **om}
        return ({"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}, metrics)

    return train_step


class TrainerApp:
    """A real JAX training job hosted by CACS.

    Checkpoint state is {"state": {params, opt_state, step}, "data": iterator
    state} — restoring it resumes the exact token stream and optimizer
    trajectory (verified bit-exact in tests).
    """

    def __init__(self, cfg: ArchConfig, *, global_batch: int = 4,
                 seq_len: int = 64, n_steps: int = 50,
                 opt: Optional[AdamWConfig] = None, seed: int = 0,
                 remat: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.opt_cfg = opt or AdamWConfig(warmup_steps=5, total_steps=n_steps)
        self.n_steps = n_steps
        self.seed = seed
        self.pipeline = TokenPipeline(cfg, global_batch, seq_len, seed=seed)
        self._train_step = jax.jit(
            make_train_step(self.model, self.opt_cfg, remat=remat))
        self._state: Optional[Dict[str, Any]] = None
        self._state_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last_loss: float = float("nan")
        self.losses: list = []
        self.step_times: list = []
        self.restarts = 0
        self._started = False

    # ---- Application protocol ------------------------------------------
    def start(self, ctx, restore_state: Optional[Any]) -> None:
        if restore_state is not None:
            with self._state_lock:
                self._state = restore_state["state"]
                self.pipeline.load_state_dict(restore_state["data"])
            self.restarts += 1
        elif self._state is None:
            self._state = init_state(self.model, jax.random.PRNGKey(self.seed))
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._started = True

    def _run(self) -> None:
        while not self._stop.is_set() and self.current_step < self.n_steps:
            t0 = time.monotonic()
            batch = self.pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            new_state, metrics = self._train_step(self._state, batch)
            loss = float(metrics["loss"])
            with self._state_lock:
                self._state = jax.block_until_ready(new_state)
            self.last_loss = loss
            self.losses.append(loss)
            self.step_times.append(time.monotonic() - t0)

    @property
    def current_step(self) -> int:
        st = self._state
        return int(st["step"]) if st is not None else 0

    def checkpoint_state(self) -> Dict[str, Any]:
        with self._state_lock:
            state = self._state
            data = dict(self.pipeline.state_dict())
            data["step"] = int(state["step"])     # align stream with params
        return {"state": state, "data": data}

    def healthy(self) -> bool:
        if not self.losses:
            return True
        return bool(np.isfinite(self.last_loss))

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=60)

    def is_done(self) -> bool:
        return self.current_step >= self.n_steps

    def progress(self) -> float:
        return self.current_step / max(self.n_steps, 1)
