"""Parallel checkpoint data-plane primitives.

The checkpoint hot path (writer.py save, reader.py restore, storage.py
two-tier replication) is embarrassingly parallel per content-addressed
chunk, and on any store with latency or bandwidth cost (the paper's
NFS/S3/Ceph roles) a serial loop pays ~sum-of-chunks when the hardware
allows ~max-of-chunks. This module holds the pieces every stage shares:

  * ``DataPlaneConfig`` — the user-facing knobs (encode workers, upload
    workers, fetch workers, max in-flight bytes) plumbed through
    ``save_checkpoint`` / ``AsyncCheckpointer`` / ``restore`` /
    ``CheckpointManager``;
  * ``ByteBudget``    — condition-variable backpressure bounding the bytes
    held between pipeline stages (a save of a model larger than host RAM
    headroom must not buffer every encoded chunk at once);
  * ``SingleFlight``  — per-key deduplication of concurrent work: the
    first worker to claim a key does the work, everyone else blocks until
    the result lands. This is what keeps dedup counters and
    bytes-written *identical* to the serial plane no matter how the
    scheduler interleaves workers.

Crash safety is unaffected by any of this: the commit protocol (all chunk
puts durable -> manifest -> flush -> COMMITTED) only requires that the
writer join every upload before putting the manifest, which the pipeline
does by construction (see writer._write_staged).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.obs.telemetry import registry
from repro.sim.simtime import active_clock


@dataclasses.dataclass(frozen=True)
class PreEncodedChunk:
    """A chunk that enters the save pipeline already encoded.

    Produced by device-side encode (``kernels.qsnap.qsnap_encode_chunks``
    quantizes on the accelerator so the D2H copy carries int8+scales, not
    f32). The writer's encode stage becomes pass-through: the payload is
    digested as-is, so a device-encoded chunk and a host-encoded chunk of
    the same content share one CAS entry bit-for-bit.

    ``codec`` names the codec this payload already satisfies ("int8");
    the save's image codec must equal it or be a zlib-refinement of it
    (writer._adapt_pre_encoded). ``nbytes`` feeds the ByteBudget exactly
    like a host ndarray would.
    """
    data: bytes
    codec: str

    @property
    def nbytes(self) -> int:
        return len(self.data)


@dataclasses.dataclass(frozen=True)
class DataPlaneConfig:
    """Knobs for the parallel checkpoint data plane.

    encode_workers:    threads running codec + digest on the save path.
    upload_workers:    threads running store puts on the save path (also
                       the stream count for parallel image ingest in
                       CheckpointManager.upload_image).
    fetch_workers:     threads running store gets + decode on restore.
    max_inflight_bytes: cap on raw bytes admitted into the save pipeline
                       but not yet durable (backpressure; <=0 = unbounded).

    ``workers=1`` everywhere reproduces the serial plane exactly — same
    puts, same counters, same ordering — so correctness never depends on
    parallelism being enabled.
    """
    encode_workers: int = 2
    upload_workers: int = 4
    fetch_workers: int = 4
    max_inflight_bytes: int = 256 << 20

    @classmethod
    def serial(cls) -> "DataPlaneConfig":
        return cls(encode_workers=1, upload_workers=1, fetch_workers=1,
                   max_inflight_bytes=0)

    @classmethod
    def with_workers(cls, n: int) -> "DataPlaneConfig":
        """Uniform worker count across all three stages (benchmarks)."""
        return cls(encode_workers=n, upload_workers=n, fetch_workers=n)

    @property
    def serial_save(self) -> bool:
        return self.encode_workers <= 1 and self.upload_workers <= 1


# Process-wide executor cache. A training job checkpoints every few
# seconds/minutes; spawning (encode+upload+fetch) thread pools per save
# costs more wall time than the chunk work it parallelizes (thread-spawn
# storm + GIL convoy measured at ~15ms for 16 threads). Pools are keyed by
# (stage, workers) — a handful of configs exist per process — and shared
# by all concurrent saves/restores: jobs interleave in the queue and each
# caller joins only its own futures, so sharing cannot deadlock (claims
# are only ever held by *running* jobs; see SingleFlight).
_POOLS: Dict[Tuple[str, int], cf.ThreadPoolExecutor] = {}
_POOLS_LOCK = threading.Lock()


def shared_executor(stage: str, workers: int) -> cf.ThreadPoolExecutor:
    with _POOLS_LOCK:
        ex = _POOLS.get((stage, workers))
        if ex is None:
            ex = cf.ThreadPoolExecutor(
                workers, thread_name_prefix=f"ckpt-{stage}{workers}")
            _POOLS[(stage, workers)] = ex
        return ex


class ByteBudget:
    """Bounded admission of bytes into the pipeline (backpressure).

    ``acquire`` blocks while the budget is exhausted — except that a
    single item larger than the whole budget is always admitted when the
    pipeline is empty, so an oversized chunk can never deadlock the save.

    ``name`` prefixes the telemetry this budget publishes (the save path
    and replication each own a budget): ``<name>.budget_wait_s`` histogram
    of admission stalls and a ``<name>.inflight_bytes`` high-water gauge.
    """

    def __init__(self, limit: int, name: str = "plane"):
        self._limit = limit
        self._used = 0
        self._cv = threading.Condition()
        self._metric = name

    def acquire(self, nbytes: int) -> None:
        if self._limit <= 0:
            return
        reg = registry()
        with self._cv:
            if reg.enabled and self._used > 0 \
                    and self._used + nbytes > self._limit:
                clk = active_clock()
                t0 = clk.now()
                while self._used > 0 and self._used + nbytes > self._limit:
                    self._cv.wait()
                reg.observe(f"{self._metric}.budget_wait_s",
                            (clk.now() - t0) / clk.scale)
            else:
                while self._used > 0 and self._used + nbytes > self._limit:
                    self._cv.wait()
            self._used += nbytes
            if reg.enabled:
                reg.gauge_max(f"{self._metric}.inflight_bytes",
                              float(self._used))

    def release(self, nbytes: int) -> None:
        if self._limit <= 0:
            return
        with self._cv:
            self._used -= nbytes
            self._cv.notify_all()


class SingleFlight:
    """Per-key collapse of concurrent duplicate work.

    ``claim(key)`` returns True for exactly one caller per key *lifetime*;
    everyone else blocks until the claimant calls ``done(key)`` and then
    returns False (the result is expected in a caller-owned table, e.g.
    the writer's ``known`` digest map). If the claimant fails, ``abort``
    wakes the waiters and lets the next one claim — work is retried, not
    lost.
    """

    def __init__(self, lock: Optional[threading.Lock] = None):
        self._lock = lock or threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight: Dict[str, bool] = {}

    def claim(self, key: str, have) -> bool:
        """have() is evaluated under the lock: return True when the
        result already exists and no work (or wait) is needed."""
        with self._cv:
            while True:
                if have():
                    return False
                if key not in self._inflight:
                    self._inflight[key] = True
                    return True
                self._cv.wait()

    def done(self, key: str) -> None:
        with self._cv:
            self._inflight.pop(key, None)
            self._cv.notify_all()

    abort = done
