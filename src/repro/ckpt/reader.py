"""Checkpoint restore with cross-mesh resharding.

``restore`` reads a checkpoint written under *any* topology and materializes
it under *any* target sharding, reading only the chunks that overlap each
local shard. This is the mechanism behind the paper's cross-cloud migration
(§5.3/§7.3): the image format is topology-agnostic, so "migrating" a job to
a differently-shaped cluster is just a restore under new shardings.

The read path is a prefetching parallel plane (plane.DataPlaneConfig):
restore first walks every leaf's target regions to enumerate the chunks it
will need, fans the fetch+decode of those chunks out across
``fetch_workers`` threads (bounded by ``max_inflight_bytes``), then
assembles shards in deterministic manifest order from the results. A
single-flight cache keyed by (store key, dtype, shape) guarantees a chunk
shared by many shards — or many leaves, as after resharding — is fetched
exactly once per distinct decode no matter how many workers race for it,
and each decoded chunk is evicted right after its last assembly use. With
``fetch_workers=1`` fetches happen inline, serially, in assembly order.
"""
from __future__ import annotations

import concurrent.futures as cf
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import compression
from repro.ckpt.layout import (COMMITTED, MANIFEST, LeafInfo, Manifest,
                               build_from_skeleton, cas_key, chunk_digest,
                               leaf_items, np_dtype, step_prefix)
from repro.ckpt.plane import DataPlaneConfig, shared_executor
from repro.ckpt.storage import ObjectStore
from repro.obs.trace import tracer

_STEP_RE = re.compile(r"step_(\d+)/COMMITTED$")


def list_steps(store: ObjectStore, prefix: str) -> List[int]:
    steps = []
    for key in store.list(prefix):
        m = _STEP_RE.search(key)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(store: ObjectStore, prefix: str) -> Optional[int]:
    steps = list_steps(store, prefix)
    return steps[-1] if steps else None


def load_manifest(store: ObjectStore, prefix: str, step: int) -> Manifest:
    sp = step_prefix(prefix, step)
    if not store.exists(f"{sp}/{COMMITTED}"):
        raise FileNotFoundError(f"step {step} not committed under {prefix}")
    return Manifest.from_json(store.get(f"{sp}/{MANIFEST}").decode())


# ---------------------------------------------------------------------------
# Chunk assembly
# ---------------------------------------------------------------------------

def _overlap(dst_off: Tuple[int, ...], dst_shape: Tuple[int, ...],
             src_off: Tuple[int, ...], src_shape: Tuple[int, ...]
             ) -> Optional[Tuple[Tuple[slice, ...], Tuple[slice, ...]]]:
    """Slices (into dst, into src) of the overlapping region, or None."""
    dst_sl, src_sl = [], []
    for do, ds, so, ss in zip(dst_off, dst_shape, src_off, src_shape):
        lo = max(do, so)
        hi = min(do + ds, so + ss)
        if hi <= lo:
            return None
        dst_sl.append(slice(lo - do, hi - do))
        src_sl.append(slice(lo - so, hi - so))
    return tuple(dst_sl), tuple(src_sl)


def _read_chunk(store: ObjectStore, li: LeafInfo, chunk, codec: str,
                prefix: Optional[str] = None) -> np.ndarray:
    """Fetch + decode one chunk, resolving by content hash when possible.

    v2 chunks carry a digest: if the manifest's key is missing (e.g. an
    image cloned under a different prefix) the chunk is re-resolved from
    the local CAS namespace, and fetched bytes are verified against the
    digest before decode — end-to-end integrity on the restore path.
    """
    key = chunk.key
    try:
        data = store.get(key)
    except (KeyError, FileNotFoundError):
        if not (chunk.hash and prefix is not None):
            raise
        key = cas_key(prefix, chunk.hash)
        data = store.get(key)
    if chunk.hash is not None and chunk_digest(data) != chunk.hash:
        raise ValueError(
            f"leaf {li.name}: chunk {key} content digest mismatch "
            f"(corrupt object or hash collision)")
    raw = compression.decode(data, np_dtype(li.dtype), codec)
    return np.frombuffer(raw, dtype=np_dtype(li.dtype)).reshape(chunk.shape)


class _ChunkSource:
    """Single-flight fetch+decode cache shared by every leaf of one restore.

    ``register`` (planning pass) counts one future assembly use of a chunk
    and queues its fetch; fetches are admitted onto the worker pool while
    under ``max_inflight_bytes`` of encoded bytes (prefetch window — the
    read-path analogue of the writer's ByteBudget, so restoring an image
    near host-RAM size cannot buffer every decoded chunk at once).
    ``get`` blocks for the result, force-submitting on demand if assembly
    runs ahead of the window (which makes the budget deadlock-free);
    ``release`` drops the decoded array after its last registered use and
    admits the next queued fetch.

    The cache key is (store key, dtype, shape): the CAS key alone is not
    enough — two leaves with byte-identical encoded chunks but different
    shape or dtype share a store key while decoding differently. A chunk
    reused across shards or leaves (common after resharding) is still
    fetched exactly once per distinct decode. Without a pool
    (fetch_workers<=1) fetches run inline at first ``get`` — serial
    behavior, same cache and eviction.
    """

    def __init__(self, store: ObjectStore, codec: str,
                 prefix: Optional[str], pool: Optional[cf.Executor],
                 max_inflight_bytes: int = 0, trace_id: str = ""):
        self._store = store
        self._codec = codec
        self._prefix = prefix
        self._pool = pool
        # per-chunk spans on pool threads parent explicitly on the restore
        # root span open on the constructing thread
        self._trace_id = trace_id
        self._span = tracer().current()
        self._budget = max_inflight_bytes
        self._lock = threading.Lock()
        self._futs: Dict[tuple, cf.Future] = {}
        self._cache: Dict[tuple, np.ndarray] = {}
        self._uses: Dict[tuple, int] = {}
        self._queue: List[tuple] = []        # (ckey, li, chunk) to submit
        self._queued: set = set()
        self._inflight = 0                   # encoded bytes admitted

    @staticmethod
    def _ckey(li: LeafInfo, chunk) -> tuple:
        return (chunk.key, li.dtype, tuple(chunk.shape))

    def register(self, li: LeafInfo, chunk) -> None:
        ck = self._ckey(li, chunk)
        with self._lock:
            self._uses[ck] = self._uses.get(ck, 0) + 1
            if self._pool is not None and ck not in self._queued:
                self._queued.add(ck)
                self._queue.append((ck, li, chunk))
        self._pump()

    def _read_traced(self, li: LeafInfo, chunk) -> np.ndarray:
        with tracer().span("restore/fetch_decode", cat="ckpt",
                           trace_id=self._trace_id, parent=self._span,
                           args={"leaf": li.name}):
            return _read_chunk(self._store, li, chunk, self._codec,
                               self._prefix)

    def _submit_locked(self, ck, li, chunk) -> cf.Future:
        self._inflight += max(1, chunk.nbytes)
        fut = self._pool.submit(self._read_traced, li, chunk)
        self._futs[ck] = fut
        return fut

    def _pump(self) -> None:
        if self._pool is None:
            return
        with self._lock:
            while self._queue and (self._budget <= 0 or self._inflight == 0
                                   or self._inflight < self._budget):
                ck, li, chunk = self._queue.pop(0)
                # skip stale entries: already admitted (force-submitted by
                # get() overtaking the window) or fully released — a
                # resubmit would double-fetch and leak _inflight forever
                if ck in self._uses and ck not in self._futs \
                        and ck not in self._cache:
                    self._submit_locked(ck, li, chunk)

    def get(self, li: LeafInfo, chunk) -> np.ndarray:
        ck = self._ckey(li, chunk)
        with self._lock:
            fut = self._futs.get(ck)
            if fut is None:
                if ck in self._cache:
                    return self._cache[ck]
                if self._pool is not None:   # ahead of the prefetch window
                    fut = self._submit_locked(ck, li, chunk)
        if fut is not None:
            return fut.result()
        arr = self._read_traced(li, chunk)
        with self._lock:
            self._cache[ck] = arr
        return arr

    def release(self, li: LeafInfo, chunk) -> None:
        """Called once per registered use; evicts after the last one."""
        ck = self._ckey(li, chunk)
        with self._lock:
            left = self._uses.get(ck, 0) - 1
            if left > 0:
                self._uses[ck] = left
                return
            self._uses.pop(ck, None)
            if self._futs.pop(ck, None) is not None:
                self._inflight -= max(1, chunk.nbytes)
            self._cache.pop(ck, None)
        self._pump()

    def cancel_pending(self) -> None:
        """Best-effort cancel of queued fetches (aborted restore); fetches
        already running on the shared pool finish and are discarded."""
        with self._lock:
            self._queue.clear()
            for fut in self._futs.values():
                fut.cancel()


def _assemble_region(source: _ChunkSource, li: LeafInfo,
                     offset: Tuple[int, ...], shape: Tuple[int, ...]
                     ) -> np.ndarray:
    """Materialize leaf[offset : offset+shape] from overlapping chunks."""
    out = np.zeros(shape, dtype=np_dtype(li.dtype))
    covered = 0
    for chunk in li.chunks:
        ov = _overlap(offset, shape, chunk.offset, chunk.shape)
        if ov is None:
            continue
        dst_sl, src_sl = ov
        out[dst_sl] = source.get(li, chunk)[src_sl]
        source.release(li, chunk)            # evicted after its last use
        covered += int(np.prod([s.stop - s.start for s in dst_sl])) \
            if shape else 1
    want = int(np.prod(shape)) if shape else 1
    if covered != want:
        raise ValueError(
            f"leaf {li.name}: region {offset}+{shape} only {covered}/{want} "
            f"elements covered by checkpoint chunks (corrupt or partial image)")
    return out


def _leaf_regions(li: LeafInfo,
                  sharding: Optional[jax.sharding.Sharding]
                  ) -> List[Tuple[Optional[Any], Tuple[int, ...],
                                  Tuple[int, ...]]]:
    """Target regions [(device_or_None, offset, shape)] this process needs.

    Computed up front (before any fetch) so the restore plane can prefetch
    exactly the overlapping chunks for every leaf in one pass.
    """
    shape = tuple(li.shape)
    if li.kind == "scalar" or sharding is None:
        return [(None, (0,) * len(shape), shape)]
    dim = sharding.devices_indices_map(shape)
    regions = []
    for dev in sharding.addressable_devices:
        off, shp = [], []
        for sl, d in zip(dim[dev], shape):
            start = 0 if sl.start is None else int(sl.start)
            stop = d if sl.stop is None else int(sl.stop)
            off.append(start)
            shp.append(stop - start)
        regions.append((dev, tuple(off), tuple(shp)))
    return regions


def _restore_leaf(source: _ChunkSource, li: LeafInfo,
                  sharding: Optional[jax.sharding.Sharding],
                  regions, dtype_override=None) -> Any:
    shape = tuple(li.shape)
    if li.kind == "scalar":
        arr = _assemble_region(source, li, *regions[0][1:])
        return arr.item() if arr.ndim == 0 else arr
    if sharding is None:
        full = _assemble_region(source, li, *regions[0][1:])
        if dtype_override is not None:
            full = full.astype(dtype_override)
        return jax.device_put(full)
    # per-device assembly: read only the chunks each local shard overlaps
    target_dtype = dtype_override or np_dtype(li.dtype)
    arrays = []
    for dev, off, shp in regions:
        local = _assemble_region(source, li, off, shp).astype(target_dtype)
        arrays.append(jax.device_put(local, dev))
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def restore(store: ObjectStore, prefix: str, step: Optional[int] = None, *,
            target: Any = None,
            shardings: Any = None,
            plane: Optional[DataPlaneConfig] = None,
            trace_id: str = ""
            ) -> Tuple[Any, Manifest]:
    """Restore a checkpoint.

    target:    optional pytree (of arrays / ShapeDtypeStructs) fixing the
               structure and dtypes; None = rebuild from the manifest
               skeleton with stored dtypes.
    shardings: optional pytree of ``jax.sharding.Sharding`` (matching target
               structure or the skeleton) — THE cross-mesh migration hook.
    plane:     parallel data-plane knobs; fetch_workers concurrent chunk
               fetch+decodes (None = DataPlaneConfig()).
    trace_id:  correlates the emitted restore spans with the owning job.
    """
    if step is None:
        step = latest_step(store, prefix)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {prefix}")
    with tracer().span("ckpt/restore", cat="ckpt", trace_id=trace_id,
                       args={"step": step}):
        manifest = load_manifest(store, prefix, step)
        plane = plane or DataPlaneConfig()

        shard_by_name: Dict[str, Any] = {}
        if shardings is not None:
            shard_by_name = dict(leaf_items(shardings))
        dtype_by_name: Dict[str, Any] = {}
        if target is not None:
            for name, leaf in leaf_items(target):
                if hasattr(leaf, "dtype"):
                    dtype_by_name[name] = leaf.dtype

        pool = None
        if plane.fetch_workers > 1:
            pool = shared_executor("fetch", plane.fetch_workers)
        source = _ChunkSource(store, manifest.codec, prefix, pool,
                              plane.max_inflight_bytes, trace_id=trace_id)
        try:
            # plan all leaves first, registering every (region, chunk) use
            # so the source can prefetch each distinct decode exactly once
            # and evict it after its last assembly …
            plans: Dict[str, tuple] = {}
            with tracer().span("restore/plan", cat="ckpt"):
                for name, li in manifest.leaves.items():
                    regions = _leaf_regions(li, shard_by_name.get(name))
                    plans[name] = regions
                    for chunk in li.chunks:
                        for _, off, shp in regions:
                            if _overlap(off, shp, chunk.offset, chunk.shape):
                                source.register(li, chunk)
            # … then assemble in deterministic manifest order
            leaves: Dict[str, Any] = {}
            with tracer().span("restore/assemble", cat="ckpt"):
                for name, li in manifest.leaves.items():
                    leaves[name] = _restore_leaf(
                        source, li, shard_by_name.get(name), plans[name],
                        dtype_by_name.get(name))
        except BaseException:
            source.cancel_pending()  # don't leave queued fetches running
            raise
        tree = build_from_skeleton(manifest.skeleton, leaves)
        return tree, manifest
