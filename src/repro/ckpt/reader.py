"""Checkpoint restore with cross-mesh resharding.

``restore`` reads a checkpoint written under *any* topology and materializes
it under *any* target sharding, reading only the chunks that overlap each
local shard. This is the mechanism behind the paper's cross-cloud migration
(§5.3/§7.3): the image format is topology-agnostic, so "migrating" a job to
a differently-shaped cluster is just a restore under new shardings.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import compression
from repro.ckpt.layout import (COMMITTED, MANIFEST, LeafInfo, Manifest,
                               build_from_skeleton, cas_key, chunk_digest,
                               leaf_items, np_dtype, step_prefix)
from repro.ckpt.storage import ObjectStore

_STEP_RE = re.compile(r"step_(\d+)/COMMITTED$")


def list_steps(store: ObjectStore, prefix: str) -> List[int]:
    steps = []
    for key in store.list(prefix):
        m = _STEP_RE.search(key)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(store: ObjectStore, prefix: str) -> Optional[int]:
    steps = list_steps(store, prefix)
    return steps[-1] if steps else None


def load_manifest(store: ObjectStore, prefix: str, step: int) -> Manifest:
    sp = step_prefix(prefix, step)
    if not store.exists(f"{sp}/{COMMITTED}"):
        raise FileNotFoundError(f"step {step} not committed under {prefix}")
    return Manifest.from_json(store.get(f"{sp}/{MANIFEST}").decode())


# ---------------------------------------------------------------------------
# Chunk assembly
# ---------------------------------------------------------------------------

def _overlap(dst_off: Tuple[int, ...], dst_shape: Tuple[int, ...],
             src_off: Tuple[int, ...], src_shape: Tuple[int, ...]
             ) -> Optional[Tuple[Tuple[slice, ...], Tuple[slice, ...]]]:
    """Slices (into dst, into src) of the overlapping region, or None."""
    dst_sl, src_sl = [], []
    for do, ds, so, ss in zip(dst_off, dst_shape, src_off, src_shape):
        lo = max(do, so)
        hi = min(do + ds, so + ss)
        if hi <= lo:
            return None
        dst_sl.append(slice(lo - do, hi - do))
        src_sl.append(slice(lo - so, hi - so))
    return tuple(dst_sl), tuple(src_sl)


def _read_chunk(store: ObjectStore, li: LeafInfo, chunk, codec: str,
                prefix: Optional[str] = None) -> np.ndarray:
    """Fetch + decode one chunk, resolving by content hash when possible.

    v2 chunks carry a digest: if the manifest's key is missing (e.g. an
    image cloned under a different prefix) the chunk is re-resolved from
    the local CAS namespace, and fetched bytes are verified against the
    digest before decode — end-to-end integrity on the restore path.
    """
    key = chunk.key
    try:
        data = store.get(key)
    except (KeyError, FileNotFoundError):
        if not (chunk.hash and prefix is not None):
            raise
        key = cas_key(prefix, chunk.hash)
        data = store.get(key)
    if chunk.hash is not None and chunk_digest(data) != chunk.hash:
        raise ValueError(
            f"leaf {li.name}: chunk {key} content digest mismatch "
            f"(corrupt object or hash collision)")
    raw = compression.decode(data, np_dtype(li.dtype), codec)
    return np.frombuffer(raw, dtype=np_dtype(li.dtype)).reshape(chunk.shape)


def _assemble_region(store: ObjectStore, li: LeafInfo, codec: str,
                     offset: Tuple[int, ...], shape: Tuple[int, ...],
                     cache: Dict[str, np.ndarray],
                     prefix: Optional[str] = None) -> np.ndarray:
    """Materialize leaf[offset : offset+shape] from overlapping chunks."""
    out = np.zeros(shape, dtype=np_dtype(li.dtype))
    covered = 0
    for chunk in li.chunks:
        ov = _overlap(offset, shape, chunk.offset, chunk.shape)
        if ov is None:
            continue
        dst_sl, src_sl = ov
        if chunk.key not in cache:
            cache[chunk.key] = _read_chunk(store, li, chunk, codec, prefix)
        out[dst_sl] = cache[chunk.key][src_sl]
        covered += int(np.prod([s.stop - s.start for s in dst_sl])) \
            if shape else 1
    want = int(np.prod(shape)) if shape else 1
    if covered != want:
        raise ValueError(
            f"leaf {li.name}: region {offset}+{shape} only {covered}/{want} "
            f"elements covered by checkpoint chunks (corrupt or partial image)")
    return out


def _restore_leaf(store: ObjectStore, li: LeafInfo, codec: str,
                  sharding: Optional[jax.sharding.Sharding],
                  dtype_override=None, prefix: Optional[str] = None) -> Any:
    shape = tuple(li.shape)
    cache: Dict[str, np.ndarray] = {}
    if li.kind == "scalar":
        arr = _assemble_region(store, li, codec, (0,) * len(shape), shape,
                               cache, prefix)
        return arr.item() if arr.ndim == 0 else arr
    if sharding is None:
        full = _assemble_region(store, li, codec, (0,) * len(shape), shape,
                                cache, prefix)
        if dtype_override is not None:
            full = full.astype(dtype_override)
        return jax.device_put(full)
    # per-device assembly: read only the chunks each local shard overlaps
    target_dtype = dtype_override or np_dtype(li.dtype)
    dim = sharding.devices_indices_map(shape)
    arrays = []
    devices = []
    for dev in sharding.addressable_devices:
        index = dim[dev]
        off, shp = [], []
        for sl, d in zip(index, shape):
            start = 0 if sl.start is None else int(sl.start)
            stop = d if sl.stop is None else int(sl.stop)
            off.append(start)
            shp.append(stop - start)
        local = _assemble_region(store, li, codec, tuple(off), tuple(shp),
                                 cache, prefix).astype(target_dtype)
        arrays.append(jax.device_put(local, dev))
        devices.append(dev)
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def restore(store: ObjectStore, prefix: str, step: Optional[int] = None, *,
            target: Any = None,
            shardings: Any = None) -> Tuple[Any, Manifest]:
    """Restore a checkpoint.

    target:    optional pytree (of arrays / ShapeDtypeStructs) fixing the
               structure and dtypes; None = rebuild from the manifest
               skeleton with stored dtypes.
    shardings: optional pytree of ``jax.sharding.Sharding`` (matching target
               structure or the skeleton) — THE cross-mesh migration hook.
    """
    if step is None:
        step = latest_step(store, prefix)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {prefix}")
    manifest = load_manifest(store, prefix, step)

    shard_by_name: Dict[str, Any] = {}
    if shardings is not None:
        shard_by_name = dict(leaf_items(shardings))
    dtype_by_name: Dict[str, Any] = {}
    if target is not None:
        for name, leaf in leaf_items(target):
            if hasattr(leaf, "dtype"):
                dtype_by_name[name] = leaf.dtype

    leaves: Dict[str, Any] = {}
    for name, li in manifest.leaves.items():
        leaves[name] = _restore_leaf(
            store, li, manifest.codec,
            shard_by_name.get(name),
            dtype_by_name.get(name), prefix)
    tree = build_from_skeleton(manifest.skeleton, leaves)
    return tree, manifest
