"""Gang checkpoint images: one atomic manifest for an N-rank job.

A *gang image* stores the globally-consistent cut of a multi-VM job that
the barrier protocol (core/gang.py) produced. It is deliberately a plain
format-v2 checkpoint — ONE ``MANIFEST.json`` + ONE ``COMMITTED`` marker
under the job's normal step directory — so every existing consumer
(``latest_step``, GC mark-and-sweep, image replication, warm-image checks)
handles gang images without knowing they are gangs:

  * each *sharded* leaf appears once with its GLOBAL shape; every rank's
    shard is a chunk stamped at its global offset (the reader's
    region-overlap assembly reshards to any rank count for free);
  * drained in-flight messages are *routed* leaves — a (K, C) row matrix
    whose ``col`` column is a global row index; restore re-routes each row
    to the rank owning that row under the NEW partition;
  * everything else is replicated (every rank receives a copy);
  * per-rank sub-manifests land at ``<step>/rank_<r>.json`` — the
    manifest-of-manifests that records exactly which chunks each rank
    contributed (debugging / per-rank audit; restore never needs them).

Rank uploads run through per-rank ``_SaveContext``s whose CAS keys carry a
``r<rank>-`` scope (writer.py): a fault injected on one rank's key prefix
hits only that rank, and per-rank dedup tables never assume another
rank's chunk exists. The commit marker is written only after EVERY rank's
puts durably joined — abort anywhere earlier leaves nothing but orphan
CAS chunks (reaped by the normal sweep) and the previous committed gang
image untouched.
"""
from __future__ import annotations

import concurrent.futures as cf
import re
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ckpt.layout import (COMMITTED, MANIFEST, LeafInfo, Manifest,
                               step_prefix, structure_skeleton)
from repro.ckpt.plane import DataPlaneConfig, shared_executor
from repro.ckpt.reader import (_ChunkSource, _assemble_region, _overlap,
                               latest_step, load_manifest, list_steps)
from repro.ckpt.storage import ObjectStore
from repro.ckpt.writer import _SaveContext, upload_staged
from repro.sharding.specs import owner_of_row, rank_region

# CAS basename of a rank-scoped chunk: "r<rank>-<digest>".
_RANK_SCOPE_RE = re.compile(r"^r(\d+)-")


def rank_scope(rank: int) -> str:
    """The CAS namespace tag one rank's uploads carry."""
    return f"r{rank}-"


def rank_manifest_key(prefix: str, step: int, rank: int) -> str:
    return f"{step_prefix(prefix, step)}/rank_{rank}.json"


def scope_of_key(key: str) -> Tuple[Optional[int], str]:
    """(rank, digest) of a CAS key; rank is None for unscoped keys."""
    base = key.rsplit("/", 1)[-1]
    m = _RANK_SCOPE_RE.match(base)
    if m is None:
        return None, base
    return int(m.group(1)), base[m.end():]


def scoped_known_digests(store: ObjectStore, prefix: str,
                         before_step: Optional[int] = None
                         ) -> Dict[int, Dict[str, int]]:
    """Per-rank dedup tables {rank: {digest: nbytes}} from the newest
    committed manifest. A digest known under one rank's scope says nothing
    about another rank's key, so the tables are NEVER merged."""
    steps = [s for s in list_steps(store, prefix)
             if before_step is None or s < before_step]
    if not steps:
        return {}
    out: Dict[int, Dict[str, int]] = {}
    for li in load_manifest(store, prefix, steps[-1]).leaves.values():
        for c in li.chunks:
            if c.hash is None:
                continue
            rank, _ = scope_of_key(c.key)
            if rank is not None:
                out.setdefault(rank, {})[c.hash] = c.nbytes
    return out


def _stage_ranks(rank_trees: Sequence[Dict[str, Any]],
                 sharded: Dict[str, int],
                 routed: Dict[str, Dict[str, Any]]):
    """Split per-rank trees into per-rank writer-staged lists + the global
    leaf table (name -> (kind, global_shape, dtype)).

    Sharded leaves concatenate along their axis in rank order (offsets are
    cumulative — no assumption the split is even). Routed leaves
    concatenate rows. Everything else must be identical in type/shape
    across ranks and is uploaded once, by rank 0.
    """
    n = len(rank_trees)
    names = list(rank_trees[0].keys())
    for r, t in enumerate(rank_trees):
        if list(t.keys()) != names:
            raise ValueError(f"rank {r} leaf names {list(t.keys())} != "
                             f"rank 0 names {names}")
    staged: List[List[tuple]] = [[] for _ in range(n)]
    for name in names:
        if name in sharded:
            axis = sharded[name]
            parts = [np.asarray(rank_trees[r][name]) for r in range(n)]
            base = parts[0]
            for p in parts[1:]:
                if (p.ndim != base.ndim or p.dtype != base.dtype or any(
                        i != axis and p.shape[i] != base.shape[i]
                        for i in range(p.ndim))):
                    raise ValueError(f"sharded leaf {name}: incompatible "
                                     f"rank shards {p.shape} vs {base.shape}")
            dim = sum(p.shape[axis] for p in parts)
            gshape = tuple(dim if i == axis else d
                           for i, d in enumerate(base.shape))
            off = 0
            for r, p in enumerate(parts):
                offset = tuple(off if i == axis else 0
                               for i in range(p.ndim))
                if p.size:
                    staged[r].append((name, "array", gshape, str(p.dtype),
                                      [(offset, p.shape, p)]))
                off += p.shape[axis]
        elif name in routed:
            parts = [np.atleast_2d(np.asarray(rank_trees[r][name],
                                              dtype=np.float64))
                     if np.asarray(rank_trees[r][name]).size else
                     np.zeros((0, int(routed[name]["cols"])), np.float64)
                     for r in range(n)]
            cols = parts[0].shape[1] if parts[0].ndim == 2 else \
                int(routed[name]["cols"])
            gshape = (sum(p.shape[0] for p in parts), cols)
            off = 0
            for r, p in enumerate(parts):
                if p.size:
                    staged[r].append((name, "array", gshape, "float64",
                                      [((off, 0), p.shape, p)]))
                off += p.shape[0]
        else:
            v = rank_trees[0][name]
            host = np.asarray(v)
            kind = "array" if isinstance(v, np.ndarray) else "scalar"
            staged[0].append((name, kind, tuple(host.shape), str(host.dtype),
                              [((0,) * host.ndim, host.shape, host)]))
    return staged, names


def save_gang_image(store: ObjectStore, prefix: str, step: int,
                    rank_trees: Sequence[Dict[str, Any]], *,
                    sharded: Dict[str, int],
                    routed: Optional[Dict[str, Dict[str, Any]]] = None,
                    codec: str = "raw",
                    metadata: Optional[Dict[str, Any]] = None,
                    plane: Optional[DataPlaneConfig] = None,
                    knowns: Optional[List[Dict[str, int]]] = None
                    ) -> Manifest:
    """Upload every rank's shards, then atomically commit ONE gang image.

    rank_trees: per-rank {leaf name: array/scalar} snapshots (all ranks
                quiesced at the same cut — the barrier's job, not ours).
    sharded:    leaf name -> axis it is partitioned on across ranks.
    routed:     leaf name -> {"by": <sharded leaf>, "col": <column holding
                the global row index>, "cols": <row width>} for drained
                in-flight message matrices.
    knowns:     optional per-rank dedup tables (GangCheckpointer threads
                these across epochs); None primes from the previous
                committed manifest, per scope.

    Any rank upload failing (crash, injected store fault) raises WITHOUT
    writing MANIFEST/COMMITTED: the epoch aborts all-or-nothing and only
    orphan CAS chunks remain for the sweeper.
    """
    routed = routed or {}
    plane = plane or DataPlaneConfig()
    n = len(rank_trees)
    if knowns is None:
        prev = scoped_known_digests(store, prefix, before_step=step)
        knowns = [dict(prev.get(r, {})) for r in range(n)]
    staged, names = _stage_ranks(rank_trees, sharded, routed)
    ctxs = [_SaveContext(store, prefix, codec, True, knowns[r], None, plane,
                         cas_scope=rank_scope(r)) for r in range(n)]
    if plane.serial_save:
        rank_leaves = [upload_staged(ctxs[r], plane, step, staged[r])
                       for r in range(n)]
    else:
        pool = shared_executor("gangrank", 8)
        futs = [pool.submit(upload_staged, ctxs[r], plane, step, staged[r])
                for r in range(n)]
        cf.wait(futs)           # every rank settles before any raise: an
        rank_leaves = [f.result() for f in futs]   # abort must not race
                                                   # in-flight sibling puts
    # merge: one leaf table with global shapes, chunks in rank order
    merged: Dict[str, LeafInfo] = {}
    for name in names:
        chunks: List[Any] = []
        proto: Optional[LeafInfo] = None
        for leaves in rank_leaves:
            li = leaves.get(name)
            if li is not None:
                proto = proto or li
                chunks.extend(li.chunks)
        if proto is None:       # routed leaf with zero messages anywhere
            spec = routed[name]
            merged[name] = LeafInfo(name, (0, int(spec["cols"])), "float64",
                                    "array", [])
        else:
            merged[name] = LeafInfo(name, proto.shape, proto.dtype,
                                    proto.kind, chunks)
    dedup = {k: sum(c.stats[k] for c in ctxs)
             for k in ctxs[0].stats} if ctxs else {}
    gang_meta = {"ranks": n, "sharded": dict(sharded),
                 "routed": {k: dict(v) for k, v in routed.items()},
                 "epoch": step}
    manifest = Manifest(
        step=step, codec=codec, leaves=merged,
        skeleton=structure_skeleton({name: None for name in names}),
        metadata={**(metadata or {}), "time": time.time(), "dedup": dedup,
                  "gang": gang_meta})
    sp = step_prefix(prefix, step)
    for r, leaves in enumerate(rank_leaves):
        sub = Manifest(step=step, codec=codec, leaves=leaves,
                       skeleton=structure_skeleton(
                           {name: None for name in leaves}),
                       metadata={"gang_rank": r, "ranks": n})
        store.put(rank_manifest_key(prefix, step, r), sub.to_json().encode())
    store.put(f"{sp}/{MANIFEST}", manifest.to_json().encode())
    store.flush()                                  # durable before commit
    store.put(f"{sp}/{COMMITTED}", b"1")
    store.flush()
    return manifest


def is_gang_manifest(manifest: Manifest) -> bool:
    return bool(manifest.metadata.get("gang"))


class _CountingStore:
    """Thin ``get``-counting wrapper proving each shared chunk is fetched
    exactly once by the single-flight restore source (acceptance metric for
    shrink-restore). Everything else delegates to the wrapped store."""

    def __init__(self, inner: ObjectStore):
        self._inner = inner
        self._lock = threading.Lock()
        self.fetches: Dict[str, int] = {}
        self.bytes_fetched = 0

    def get(self, key: str) -> bytes:
        data = self._inner.get(key)
        with self._lock:
            self.fetches[key] = self.fetches.get(key, 0) + 1
            self.bytes_fetched += len(data)
        return data

    def __getattr__(self, name):
        return getattr(self._inner, name)


def load_gang_ranks(store: ObjectStore, prefix: str,
                    step: Optional[int] = None,
                    n_ranks: Optional[int] = None, *,
                    plane: Optional[DataPlaneConfig] = None
                    ) -> Tuple[List[Dict[str, Any]], Manifest,
                               Dict[str, int]]:
    """Restore a gang image resharded onto ``n_ranks`` ranks.

    ``n_ranks`` may differ from the save-time gang size (elastic shrink /
    grow): sharded leaves are re-split by ``even_regions`` for the new
    count, routed message rows are re-routed to the rank now owning their
    target row, replicated leaves go to everyone. Returns
    ``(per-rank trees, manifest, fetch stats)`` where the stats prove the
    dedup claim: ``chunk_fetches == unique_chunks`` means no chunk shared
    between old and new shard boundaries was fetched twice.
    """
    if step is None:
        step = latest_step(store, prefix)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {prefix}")
    manifest = load_manifest(store, prefix, step)
    g = manifest.metadata.get("gang")
    if not g:
        raise ValueError(f"step {step} under {prefix} is not a gang image")
    if n_ranks is None:
        n_ranks = int(g["ranks"])
    sharded = {k: int(v) for k, v in g.get("sharded", {}).items()}
    routed = g.get("routed", {})
    plane = plane or DataPlaneConfig()
    cstore = _CountingStore(store)
    pool = shared_executor("fetch", plane.fetch_workers) \
        if plane.fetch_workers > 1 else None
    source = _ChunkSource(cstore, manifest.codec, prefix, pool,
                          plane.max_inflight_bytes)
    # plan every (region, chunk) use up front so the single-flight source
    # prefetches each distinct decode once and evicts after its last use
    plans: List[tuple] = []
    for name, li in manifest.leaves.items():
        shape = tuple(li.shape)
        if name in sharded:
            regs = [rank_region(shape, n_ranks, r, sharded[name])
                    for r in range(n_ranks)]
        else:
            regs = [((0,) * len(shape), shape)]
        plans.append((name, li, regs))
        for chunk in li.chunks:
            for off, shp in regs:
                if _overlap(off, shp, tuple(chunk.offset),
                            tuple(chunk.shape)):
                    source.register(li, chunk)
    parts: Dict[str, List[np.ndarray]] = {}
    full: Dict[str, np.ndarray] = {}
    try:
        for name, li, regs in plans:
            if name in sharded:
                parts[name] = [_assemble_region(source, li, off, shp)
                               for off, shp in regs]
            else:
                full[name] = _assemble_region(source, li, *regs[0])
    except BaseException:
        source.cancel_pending()
        raise
    trees: List[Dict[str, Any]] = []
    for r in range(n_ranks):
        tree: Dict[str, Any] = {}
        for name, li, _ in plans:
            if name in sharded:
                tree[name] = parts[name][r]
            elif name in routed:
                spec = routed[name]
                by = manifest.leaves[spec["by"]]
                dim = int(by.shape[sharded.get(spec["by"], 0)])
                col = int(spec["col"])
                msgs = full[name]
                rows = [i for i in range(msgs.shape[0])
                        if owner_of_row(dim, n_ranks,
                                        int(msgs[i, col])) == r]
                tree[name] = msgs[rows] if rows else \
                    np.zeros((0, msgs.shape[1]), msgs.dtype)
            elif li.kind == "scalar":
                tree[name] = full[name].item()
            else:
                tree[name] = full[name].copy()
        trees.append(tree)
    counts = list(cstore.fetches.values())
    stats = {"chunk_fetches": sum(counts), "unique_chunks": len(counts),
             "max_fetches_per_chunk": max(counts) if counts else 0,
             "bytes_fetched": cstore.bytes_fetched}
    return trees, manifest, stats


class GangCheckpointer:
    """Per-rank incremental dedup threaded across gang epochs.

    Holds one digest table per rank scope so repeat content skips its put
    (same contract as ``AsyncCheckpointer._known``, per rank). The tables
    survive aborted epochs — an aborted epoch's chunks stay in the store
    until a sweep, at which point ``invalidate`` drops exactly the swept
    scopes' digests (checkpoint_manager wires GC's ``on_swept`` here)."""

    def __init__(self, store: ObjectStore, prefix: str, *,
                 codec: str = "raw",
                 plane: Optional[DataPlaneConfig] = None):
        self.store = store
        self.prefix = prefix
        self.codec = codec
        self.plane = plane or DataPlaneConfig()
        self._lock = threading.Lock()
        self._knowns: Optional[List[Dict[str, int]]] = None

    def save(self, step: int, rank_trees: Sequence[Dict[str, Any]], *,
             sharded: Dict[str, int],
             routed: Optional[Dict[str, Dict[str, Any]]] = None,
             metadata: Optional[Dict[str, Any]] = None) -> Manifest:
        n = len(rank_trees)
        with self._lock:
            if self._knowns is None or len(self._knowns) != n:
                prev = scoped_known_digests(self.store, self.prefix,
                                            before_step=step)
                self._knowns = [dict(prev.get(r, {})) for r in range(n)]
            knowns = self._knowns
        return save_gang_image(self.store, self.prefix, step, rank_trees,
                               sharded=sharded, routed=routed,
                               codec=self.codec, metadata=metadata,
                               plane=self.plane, knowns=knowns)

    def invalidate(self, keys: Sequence[str]) -> None:
        with self._lock:
            if not self._knowns:
                return
            for key in keys:
                rank, digest = scope_of_key(key)
                if rank is not None and rank < len(self._knowns):
                    self._knowns[rank].pop(digest, None)

    def reset(self) -> None:
        with self._lock:
            self._knowns = None
