"""Pluggable checkpoint object stores (paper §6.2: NFS / S3 / Ceph).

Three backends mirror the paper's storage design:
  * ``InMemoryStore`` — dict-backed; optional simulated latency/bandwidth so
    the paper's figures (upload/download time vs size) are reproducible on a
    single host.
  * ``LocalFSStore``  — directory-backed (the paper's "NFS" role).
  * ``TwoTierStore``  — fast local tier + lazy async upload to a remote tier
    (paper §5.2: "written first to local storage, copied later to remote
    storage on a lazy basis").
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Dict, Iterable, List, Optional


class ObjectStore:
    """Abstract flat key/value object store (S3-shaped).

    Content-addressed (dedup) traffic goes through ``put_if_absent`` /
    ``delete_unreferenced`` so every backend uniformly tracks dedup
    hit/miss counters and never deletes a chunk that a live manifest still
    references (see ckpt/gc.py for how refcounts are derived).
    """

    # dedup counters (class defaults; first increment creates instance attrs)
    dedup_hits = 0                    # puts skipped: content already stored
    dedup_misses = 0                  # puts that actually wrote
    dedup_bytes_skipped = 0           # encoded bytes NOT rewritten
    gc_deleted = 0                    # chunks removed by refcount-aware delete

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str) -> List[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def delete_prefix(self, prefix: str) -> int:
        n = 0
        for k in list(self.list(prefix)):
            self.delete(k)
            n += 1
        return n

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Content-addressed put: skip (and count a dedup hit) when the key
        already holds this content. Returns True iff data was written."""
        if self.exists(key):
            self.dedup_hits += 1
            self.dedup_bytes_skipped += len(data)
            return False
        self.dedup_misses += 1
        self.put(key, data)
        return True

    def delete_unreferenced(self, key: str, refcount: int) -> bool:
        """Refcount-aware delete for shared chunks: remove ``key`` only when
        no live manifest references it. Returns True iff deleted."""
        if refcount > 0:
            return False
        self.delete(key)
        self.gc_deleted += 1
        return True

    def dedup_stats(self) -> Dict[str, int]:
        return {"dedup_hits": self.dedup_hits,
                "dedup_misses": self.dedup_misses,
                "dedup_bytes_skipped": self.dedup_bytes_skipped,
                "gc_deleted": self.gc_deleted}

    # Stores that upload lazily override this to block until durable.
    def flush(self) -> None:
        pass

    def total_bytes(self, prefix: str = "") -> int:
        return sum(len(self.get(k)) for k in self.list(prefix))


class InMemoryStore(ObjectStore):
    """Dict-backed store with an optional simulated network cost model.

    ``latency_s`` + len/``bandwidth_bps`` of wall-clock sleep per op lets the
    cluster simulator reproduce the paper's network-bound checkpoint/restart
    curves (Fig 3b/3c) deterministically.
    """

    def __init__(self, latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 shared_link: bool = False):
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self._link_lock = threading.Lock()
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        # shared_link=True serializes bandwidth cost across threads —
        # models a shared NFS/Ceph ingress (paper Fig 3c's restart jitter
        # comes exactly from this contention).
        self.shared_link = shared_link
        self.put_count = 0
        self.get_count = 0
        self.bytes_in = 0
        self.bytes_out = 0

    def _cost(self, nbytes: int) -> None:
        if self.latency_s > 0:
            time.sleep(self.latency_s)
        if self.bandwidth_bps:
            t = nbytes / self.bandwidth_bps
            if self.shared_link:
                with self._link_lock:
                    time.sleep(t)
            elif t > 0:
                time.sleep(t)

    def put(self, key: str, data: bytes) -> None:
        self._cost(len(data))
        with self._lock:
            self._data[key] = bytes(data)
            self.put_count += 1
            self.bytes_in += len(data)

    def get(self, key: str) -> bytes:
        with self._lock:
            data = self._data[key]
            self.get_count += 1
            self.bytes_out += len(data)
        self._cost(len(data))
        return data

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def list(self, prefix: str) -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)


class LocalFSStore(ObjectStore):
    """Directory-backed store. Keys map to files (``/`` allowed in keys).

    Writes are atomic (tmp + rename) so a crashed writer never leaves a
    half-written object visible.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        p = os.path.join(self.root, key)
        assert os.path.abspath(p).startswith(os.path.abspath(self.root))
        return p

    def put(self, key: str, data: bytes) -> None:
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + f".tmp.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def list(self, prefix: str) -> List[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp") or ".tmp." in fn:
                    continue
                key = os.path.relpath(os.path.join(dirpath, fn), self.root)
                key = key.replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass


class TwoTierStore(ObjectStore):
    """Local tier for writes, lazy background replication to remote tier.

    Reads prefer local, falling back to remote (so a restarted host that
    lost its local tier still restores). ``flush()`` blocks until all
    pending uploads are durable in the remote tier — the commit marker is
    only written after flush (see writer.py), preserving atomicity.
    """

    def __init__(self, local: ObjectStore, remote: ObjectStore):
        self.local = local
        self.remote = remote
        self._q: "queue.Queue[Optional[str]]" = queue.Queue()
        self._pending: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._uploader, daemon=True)
        self._thread.start()

    def _uploader(self) -> None:
        while True:
            key = self._q.get()
            if key is None:
                return
            try:
                self.remote.put(key, self.local.get(key))
            except BaseException as e:        # surfaced at flush()
                self._err = e
            finally:
                with self._lock:
                    self._pending[key] -= 1
                    if self._pending[key] == 0:
                        del self._pending[key]

    def put(self, key: str, data: bytes) -> None:
        self.local.put(key, data)
        with self._lock:
            self._pending[key] = self._pending.get(key, 0) + 1
        self._q.put(key)

    def get(self, key: str) -> bytes:
        try:
            return self.local.get(key)
        except (KeyError, FileNotFoundError):
            return self.remote.get(key)

    def exists(self, key: str) -> bool:
        return self.local.exists(key) or self.remote.exists(key)

    def list(self, prefix: str) -> List[str]:
        return sorted(set(self.local.list(prefix)) |
                      set(self.remote.list(prefix)))

    def delete(self, key: str) -> None:
        self.local.delete(key)
        self.remote.delete(key)

    def flush(self) -> None:
        while True:
            with self._lock:
                if not self._pending:
                    break
            time.sleep(0.001)
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def drop_local(self) -> None:
        """Simulate losing the fast tier (host failure)."""
        for k in list(self.local.list("")):
            self.local.delete(k)

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=5)
