"""Pluggable checkpoint object stores (paper §6.2: NFS / S3 / Ceph).

Three backends mirror the paper's storage design:
  * ``InMemoryStore`` — dict-backed; optional simulated latency/bandwidth so
    the paper's figures (upload/download time vs size) are reproducible on a
    single host.
  * ``LocalFSStore``  — directory-backed (the paper's "NFS" role).
  * ``TwoTierStore``  — fast local tier + lazy async upload to a remote tier
    (paper §5.2: "written first to local storage, copied later to remote
    storage on a lazy basis"), replicated over N concurrent uploader streams.

All stores are safe under the parallel data plane (ckpt/plane.py):
``put_if_absent`` is atomic per key (an exists+put race between two workers
can neither double-write nor double-count), and the dedup/GC counters are
instance-level and lock-protected.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Dict, Iterable, List, Optional, Set

from repro.sim.simtime import active_clock


class ObjectStore:
    """Abstract flat key/value object store (S3-shaped).

    Content-addressed (dedup) traffic goes through ``put_if_absent`` /
    ``delete_unreferenced`` so every backend uniformly tracks dedup
    hit/miss counters and never deletes a chunk that a live manifest still
    references (see ckpt/gc.py for how refcounts are derived).

    Subclasses must call ``super().__init__()`` (counter + lock setup).
    """

    def __init__(self):
        # dedup counters — instance-level and guarded by _meta_lock so
        # concurrent writers can't lose updates (the old class-level
        # defaults made `self.x += 1` a read-copy-update race).
        self.dedup_hits = 0               # puts skipped: content already stored
        self.dedup_misses = 0             # puts that actually wrote
        self.dedup_bytes_skipped = 0      # encoded bytes NOT rewritten
        self.gc_deleted = 0               # chunks removed by refcount-aware delete
        # replica-aware transfer counters: chunks sourced from a local
        # replica (shipped earlier by core/replication.py) instead of being
        # re-transferred cross-cloud — the warm-migration savings
        self.replica_hits = 0
        self.replica_bytes_local = 0
        self._meta_lock = threading.Lock()
        self._inflight_cv = threading.Condition(self._meta_lock)
        self._inflight_puts: Set[str] = set()

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str) -> List[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def delete_prefix(self, prefix: str) -> int:
        n = 0
        for k in list(self.list(prefix)):
            self.delete(k)
            n += 1
        return n

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Content-addressed put: skip (and count a dedup hit) when the key
        already holds this content. Returns True iff data was written.

        Atomic per key: a concurrent put_if_absent of the same key waits
        for the in-flight put instead of racing it, so exactly one caller
        writes (a miss) and the rest count hits — without serializing puts
        of *different* keys through one lock (store latency would otherwise
        flatten the parallel plane back to serial).
        """
        with self._inflight_cv:
            while key in self._inflight_puts:
                self._inflight_cv.wait()
            if self.exists(key):
                self.dedup_hits += 1
                self.dedup_bytes_skipped += len(data)
                return False
            self._inflight_puts.add(key)
        try:
            self.put(key, data)
        finally:
            with self._inflight_cv:
                self._inflight_puts.discard(key)
                self._inflight_cv.notify_all()
        with self._meta_lock:
            self.dedup_misses += 1
        return True

    def delete_unreferenced(self, key: str, refcount: int) -> bool:
        """Refcount-aware delete for shared chunks: remove ``key`` only when
        no live manifest references it. Returns True iff deleted."""
        if refcount > 0:
            return False
        self.delete(key)
        with self._meta_lock:
            self.gc_deleted += 1
        return True

    def dedup_stats(self) -> Dict[str, int]:
        with self._meta_lock:
            return {"dedup_hits": self.dedup_hits,
                    "dedup_misses": self.dedup_misses,
                    "dedup_bytes_skipped": self.dedup_bytes_skipped,
                    "gc_deleted": self.gc_deleted,
                    "replica_hits": self.replica_hits,
                    "replica_bytes_local": self.replica_bytes_local}

    def count_ingest_hit(self, nbytes: int) -> None:
        """Record an ingest-side dedup hit (upload_image skipping a chunk
        the destination already holds) without racing other counters."""
        with self._meta_lock:
            self.dedup_hits += 1
            self.dedup_bytes_skipped += nbytes

    def count_replica_hit(self, nbytes: int) -> None:
        """Record a warm-transfer hit: a chunk that would have crossed the
        inter-cloud link was found in a local replica instead (shipped
        earlier by the ImageReplicator) and copied store-locally."""
        with self._meta_lock:
            self.replica_hits += 1
            self.replica_bytes_local += nbytes

    # Stores that upload lazily override this to block until durable.
    def flush(self) -> None:
        pass

    def total_bytes(self, prefix: str = "") -> int:
        return sum(len(self.get(k)) for k in self.list(prefix))


class InMemoryStore(ObjectStore):
    """Dict-backed store with an optional simulated network cost model.

    ``latency_s`` + len/``bandwidth_bps`` of wall-clock sleep per op lets the
    cluster simulator reproduce the paper's network-bound checkpoint/restart
    curves (Fig 3b/3c) deterministically. Latency is paid concurrently
    (per-op sleep outside any lock — parallel requests overlap it, like
    independent RTTs); bandwidth with ``shared_link=True`` is paid under a
    link lock (parallel requests contend, like one NFS/Ceph ingress pipe).
    """

    def __init__(self, latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 shared_link: bool = False):
        super().__init__()
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self._link_lock = threading.Lock()
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        # shared_link=True serializes bandwidth cost across threads —
        # models a shared NFS/Ceph ingress (paper Fig 3c's restart jitter
        # comes exactly from this contention).
        self.shared_link = shared_link
        self.put_count = 0
        self.get_count = 0
        self.bytes_in = 0
        self.bytes_out = 0

    def _cost(self, nbytes: int) -> None:
        # paid through the installed clock: real sleeps in production,
        # instant virtual advances under a SimClock (repro.sim)
        clk = active_clock()
        if self.latency_s > 0:
            clk.sleep(self.latency_s)
        if self.bandwidth_bps:
            t = nbytes / self.bandwidth_bps
            if self.shared_link:
                with self._link_lock:
                    clk.sleep(t)
            elif t > 0:
                clk.sleep(t)

    def put(self, key: str, data: bytes) -> None:
        self._cost(len(data))
        with self._lock:
            self._data[key] = bytes(data)
            self.put_count += 1
            self.bytes_in += len(data)

    def get(self, key: str) -> bytes:
        with self._lock:
            data = self._data[key]
            self.get_count += 1
            self.bytes_out += len(data)
        self._cost(len(data))
        return data

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def list(self, prefix: str) -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)


class LocalFSStore(ObjectStore):
    """Directory-backed store. Keys map to files (``/`` allowed in keys).

    Writes are atomic (tmp + rename) so a crashed writer never leaves a
    half-written object visible; concurrent writers use per-thread tmp
    names, so parallel puts of different keys need no extra locking.
    """

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        p = os.path.join(self.root, key)
        assert os.path.abspath(p).startswith(os.path.abspath(self.root))
        return p

    def put(self, key: str, data: bytes) -> None:
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + f".tmp.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def list(self, prefix: str) -> List[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp") or ".tmp." in fn:
                    continue
                key = os.path.relpath(os.path.join(dirpath, fn), self.root)
                key = key.replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass


class ChaosStorageError(IOError):
    """Raised by FaultyStore for a deterministically injected op failure."""


class FaultyStore(ObjectStore):
    """Fault-injecting wrapper around any ObjectStore (chaos harness).

    ``arm_put_errors(n)`` / ``arm_get_errors(n)`` make the next *n* put/get
    calls raise :class:`ChaosStorageError` — deterministic (a counter, not a
    probability), so a seeded chaos scenario replays exactly. Because the
    writer's commit protocol puts data chunks before MANIFEST before
    COMMITTED, a put fault injected mid-save must leave the previous
    COMMITTED image fully loadable and the torn step invisible; the chaos
    suite (`tests/test_chaos.py`) holds the store to exactly that.

    Arming takes an optional ``key_prefix``: only ops whose key starts
    with it are faulted. Gang checkpointing writes each rank's chunks
    under a rank-scoped CAS prefix (``<prefix>/cas/r<rank>-``), so a
    prefix-armed fault hits exactly one rank's uploads mid-barrier —
    the single-rank store-fault scenario of `tests/test_gang_chaos.py`.

    The wrapper *is* the store as far as the service is concerned: the
    inherited ``put_if_absent``/``delete_unreferenced`` run against the
    wrapper's counters, and every other op delegates to ``inner``.
    """

    def __init__(self, inner: ObjectStore):
        super().__init__()
        self.inner = inner
        self._fault_lock = threading.Lock()
        self._put_faults = 0
        self._get_faults = 0
        # key_prefix -> remaining faults, for per-rank (scoped) arming
        self._put_prefix_faults: Dict[str, int] = {}
        self._get_prefix_faults: Dict[str, int] = {}
        self.faults_injected = 0

    def arm_put_errors(self, n: int, key_prefix: Optional[str] = None) -> None:
        with self._fault_lock:
            if key_prefix is None:
                self._put_faults = max(0, int(n))
            else:
                self._put_prefix_faults[key_prefix] = max(0, int(n))

    def arm_get_errors(self, n: int, key_prefix: Optional[str] = None) -> None:
        with self._fault_lock:
            if key_prefix is None:
                self._get_faults = max(0, int(n))
            else:
                self._get_prefix_faults[key_prefix] = max(0, int(n))

    def disarm(self) -> None:
        with self._fault_lock:
            self._put_faults = 0
            self._get_faults = 0
            self._put_prefix_faults.clear()
            self._get_prefix_faults.clear()

    def armed(self) -> int:
        with self._fault_lock:
            return (self._put_faults + self._get_faults
                    + sum(self._put_prefix_faults.values())
                    + sum(self._get_prefix_faults.values()))

    def _maybe_fault(self, op: str, key: str) -> None:
        attr = f"_{op}_faults"
        scoped = getattr(self, f"_{op}_prefix_faults")
        with self._fault_lock:
            for pfx, left in scoped.items():
                if left > 0 and key.startswith(pfx):
                    scoped[pfx] = left - 1
                    self.faults_injected += 1
                    raise ChaosStorageError(
                        f"injected {op} fault on {key!r} (scope {pfx!r})")
            if getattr(self, attr) > 0:
                setattr(self, attr, getattr(self, attr) - 1)
                self.faults_injected += 1
                raise ChaosStorageError(f"injected {op} fault on {key!r}")

    def put(self, key: str, data: bytes) -> None:
        self._maybe_fault("put", key)
        self.inner.put(key, data)

    def get(self, key: str) -> bytes:
        self._maybe_fault("get", key)
        return self.inner.get(key)

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def list(self, prefix: str) -> List[str]:
        return self.inner.list(prefix)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def flush(self) -> None:
        self.inner.flush()


class TwoTierStore(ObjectStore):
    """Local tier for writes, lazy background replication to remote tier.

    Reads prefer local, falling back to remote (so a restarted host that
    lost its local tier still restores). Replication runs over
    ``upload_streams`` concurrent uploader threads — on a latency- or
    bandwidth-bound remote (the paper's S3/Ceph roles) the backlog drains
    ~streams× faster, which directly shortens ``flush()``. ``flush()``
    blocks on a condition variable until all pending uploads are durable
    in the remote tier (no polling); the commit marker is only written
    after flush (see writer.py), preserving atomicity.
    """

    def __init__(self, local: ObjectStore, remote: ObjectStore, *,
                 upload_streams: int = 4):
        super().__init__()
        self.local = local
        self.remote = remote
        self.upload_streams = max(1, int(upload_streams))
        self._q: "queue.Queue[Optional[str]]" = queue.Queue()
        self._pending: Dict[str, int] = {}
        self._drained = threading.Condition()
        self._err: Optional[BaseException] = None
        self._failed: Set[str] = set()        # replications to retry
        self._threads = [
            threading.Thread(target=self._uploader, daemon=True,
                             name=f"tt-upload-{i}")
            for i in range(self.upload_streams)]
        for t in self._threads:
            t.start()

    def _uploader(self) -> None:
        while True:
            key = self._q.get()
            if key is None:
                return
            try:
                self.remote.put(key, self.local.get(key))
            except BaseException as e:        # surfaced at flush(), which
                with self._drained:           # re-queues the key: a failed
                    self._err = e             # upload stays owed, or a later
                    self._failed.add(key)     # save could commit while the
            finally:                          # remote misses this chunk
                with self._drained:
                    self._pending[key] -= 1
                    if self._pending[key] == 0:
                        del self._pending[key]
                    if not self._pending:
                        self._drained.notify_all()

    def put(self, key: str, data: bytes) -> None:
        self.local.put(key, data)
        with self._drained:
            self._pending[key] = self._pending.get(key, 0) + 1
        self._q.put(key)

    def get(self, key: str) -> bytes:
        try:
            return self.local.get(key)
        except (KeyError, FileNotFoundError):
            return self.remote.get(key)

    def exists(self, key: str) -> bool:
        return self.local.exists(key) or self.remote.exists(key)

    def list(self, prefix: str) -> List[str]:
        return sorted(set(self.local.list(prefix)) |
                      set(self.remote.list(prefix)))

    def delete(self, key: str) -> None:
        self.local.delete(key)
        self.remote.delete(key)

    def flush(self) -> None:
        # Re-queue failed replications first: until every one of them lands
        # remotely, no flush() may return cleanly — otherwise a later save
        # could dedup against the local copy and commit a checkpoint whose
        # chunk exists in no durable tier. Transient remote errors heal on
        # a later flush; persistent ones keep every flush (and therefore
        # every commit) failing.
        with self._drained:
            retry, self._failed = self._failed, set()
            for key in retry:
                self._pending[key] = self._pending.get(key, 0) + 1
        for key in retry:
            self._q.put(key)
        with self._drained:
            while self._pending:
                self._drained.wait()
            if self._err is not None:
                err, self._err = self._err, None
                raise err

    def pending_uploads(self) -> int:
        with self._drained:
            return sum(self._pending.values())

    def drop_local(self) -> None:
        """Simulate losing the fast tier (host failure)."""
        for k in list(self.local.list("")):
            self.local.delete(k)

    def close(self) -> None:
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=5)
