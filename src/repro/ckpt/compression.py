"""Checkpoint image codecs (paper Table 2: image size is the scaling lever).

Codecs operate on raw little-endian chunk bytes:
  * ``raw``       — identity.
  * ``zlib``      — lossless deflate (cheap CPU, good on low-entropy state).
  * ``int8``      — blockwise absmax int8 quantization of float leaves
                    (lossy; used for *swap-out* images of preempted jobs and
                    for gradient compression — not for exact restarts).
  * ``int8+zlib`` — both.

The int8 codec's math mirrors ``repro.kernels.ref.qsnap_ref`` exactly — the
Pallas kernel (device-side compression before D2H copy) and this host codec
are interchangeable, and tests assert bit-identical round-trips between them.
"""
from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

BLOCK = 256
_MAGIC = b"QS01"

# Scales are computed as absmax * (1/127) — an IEEE f32 multiply — rather
# than absmax / 127.  XLA rewrites division by a constant into a
# reciprocal multiply, so the multiply formulation is the only one that is
# bit-identical between this host codec, the jnp oracle, and the Pallas
# kernel (device-side encode).  Interchange tests depend on this.
INV127 = np.float32(1.0 / 127.0)

try:                                  # bf16 registers as kind='V', not 'f'
    import ml_dtypes
    _EXTRA_FLOATS = {np.dtype(ml_dtypes.bfloat16)}
except ImportError:                   # pragma: no cover
    _EXTRA_FLOATS = set()


def is_float_dtype(dt: np.dtype) -> bool:
    """Quantizable-float predicate shared with the device encode path.

    Host and device encoders must agree on which leaves quantize, or the
    same pytree produces different images on the two paths.  bf16 is the
    training dtype and must count even though numpy reports kind='V'.
    """
    dt = np.dtype(dt)
    return dt.kind == "f" or dt in _EXTRA_FLOATS


def quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """x: float array -> (int8 codes [n_pad], f32 scales [n_blocks])."""
    flat = np.asarray(x, dtype=np.float32).reshape(-1)
    n = flat.size
    n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    buf = np.zeros(n_pad, np.float32)
    buf[:n] = flat
    blocks = buf.reshape(-1, BLOCK)
    scales = np.max(np.abs(blocks), axis=1) * INV127
    scales = np.where(scales == 0, 1.0, scales).astype(np.float32)
    codes = np.clip(np.rint(blocks / scales[:, None]), -127, 127).astype(np.int8)
    return codes.reshape(-1), scales


def dequantize_int8(codes: np.ndarray, scales: np.ndarray,
                    n: int) -> np.ndarray:
    blocks = codes.reshape(-1, BLOCK).astype(np.float32) * scales[:, None]
    return blocks.reshape(-1)[:n]


def frame_int8(n: int, scales: np.ndarray, codes: np.ndarray) -> bytes:
    """Frame (codes, scales) of an n-element float chunk as a QS01 payload.

    Shared by the host codec and the device encode path
    (``repro.kernels.qsnap.qsnap_encode_chunks``) so both emit the exact
    same bytes — CAS digests over encoded bytes then dedup across the two.
    """
    return (_MAGIC + b"INT8"
            + struct.pack("<qq", n, scales.size)
            + scales.tobytes() + codes.tobytes())


def frame_raw(data: bytes) -> bytes:
    """Frame a non-float chunk's raw bytes as a QS01 passthrough payload."""
    return _MAGIC + b"RAWD" + data


def encode(data: bytes, dtype: np.dtype, codec: str) -> bytes:
    """Encode one chunk's raw bytes."""
    if codec == "raw":
        return data
    if codec == "zlib":
        return zlib.compress(data, level=1)
    if codec in ("int8", "int8+zlib"):
        dt = np.dtype(dtype)
        if not is_float_dtype(dt):
            payload = frame_raw(data)             # non-float: store raw
        else:
            arr = np.frombuffer(data, dtype=dt)
            codes, scales = quantize_int8(arr.astype(np.float32))
            payload = frame_int8(arr.size, scales, codes)
        if codec == "int8+zlib":
            return zlib.compress(payload, level=1)
        return payload
    raise ValueError(f"unknown codec {codec!r}")


def decode(data: bytes, dtype: np.dtype, codec: str) -> bytes:
    if codec == "raw":
        return data
    if codec == "zlib":
        return zlib.decompress(data)
    if codec in ("int8", "int8+zlib"):
        if codec == "int8+zlib":
            data = zlib.decompress(data)
        assert data[:4] == _MAGIC, "corrupt int8 chunk"
        kind = data[4:8]
        if kind == b"RAWD":
            return data[8:]
        n, n_scales = struct.unpack("<qq", data[8:24])
        off = 24
        scales = np.frombuffer(data[off:off + 4 * n_scales], np.float32)
        off += 4 * n_scales
        codes = np.frombuffer(data[off:], np.int8)
        out = dequantize_int8(codes, scales, n)
        return out.astype(np.dtype(dtype)).tobytes()
    raise ValueError(f"unknown codec {codec!r}")
