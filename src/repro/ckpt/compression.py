"""Checkpoint image codecs (paper Table 2: image size is the scaling lever).

Codecs operate on raw little-endian chunk bytes:
  * ``raw``       — identity.
  * ``zlib``      — lossless deflate (cheap CPU, good on low-entropy state).
  * ``int8``      — blockwise absmax int8 quantization of float leaves
                    (lossy; used for *swap-out* images of preempted jobs and
                    for gradient compression — not for exact restarts).
  * ``int8+zlib`` — both.

The int8 codec's math mirrors ``repro.kernels.ref.qsnap_ref`` exactly — the
Pallas kernel (device-side compression before D2H copy) and this host codec
are interchangeable, and tests assert bit-identical round-trips between them.
"""
from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

BLOCK = 256
_MAGIC = b"QS01"


def quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """x: float array -> (int8 codes [n_pad], f32 scales [n_blocks])."""
    flat = np.asarray(x, dtype=np.float32).reshape(-1)
    n = flat.size
    n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    buf = np.zeros(n_pad, np.float32)
    buf[:n] = flat
    blocks = buf.reshape(-1, BLOCK)
    scales = np.max(np.abs(blocks), axis=1) / 127.0
    scales = np.where(scales == 0, 1.0, scales).astype(np.float32)
    codes = np.clip(np.rint(blocks / scales[:, None]), -127, 127).astype(np.int8)
    return codes.reshape(-1), scales


def dequantize_int8(codes: np.ndarray, scales: np.ndarray,
                    n: int) -> np.ndarray:
    blocks = codes.reshape(-1, BLOCK).astype(np.float32) * scales[:, None]
    return blocks.reshape(-1)[:n]


def encode(data: bytes, dtype: np.dtype, codec: str) -> bytes:
    """Encode one chunk's raw bytes."""
    if codec == "raw":
        return data
    if codec == "zlib":
        return zlib.compress(data, level=1)
    if codec in ("int8", "int8+zlib"):
        dt = np.dtype(dtype)
        if dt.kind != "f":
            payload = _MAGIC + b"RAWD" + data     # non-float: store raw
        else:
            arr = np.frombuffer(data, dtype=dt)
            codes, scales = quantize_int8(arr.astype(np.float32))
            payload = (_MAGIC + b"INT8"
                       + struct.pack("<qq", arr.size, scales.size)
                       + scales.tobytes() + codes.tobytes())
        if codec == "int8+zlib":
            return zlib.compress(payload, level=1)
        return payload
    raise ValueError(f"unknown codec {codec!r}")


def decode(data: bytes, dtype: np.dtype, codec: str) -> bytes:
    if codec == "raw":
        return data
    if codec == "zlib":
        return zlib.decompress(data)
    if codec in ("int8", "int8+zlib"):
        if codec == "int8+zlib":
            data = zlib.decompress(data)
        assert data[:4] == _MAGIC, "corrupt int8 chunk"
        kind = data[4:8]
        if kind == b"RAWD":
            return data[8:]
        n, n_scales = struct.unpack("<qq", data[8:24])
        off = 24
        scales = np.frombuffer(data[off:off + 4 * n_scales], np.float32)
        off += 4 * n_scales
        codes = np.frombuffer(data[off:], np.int8)
        out = dequantize_int8(codes, scales, n)
        return out.astype(np.dtype(dtype)).tobytes()
    raise ValueError(f"unknown codec {codec!r}")
