"""Checkpoint retention / garbage collection.

With content-addressed chunks (layout format v2) a chunk may be shared by
any number of committed steps, so deleting a step can no longer delete its
chunks by prefix. ``collect`` is therefore mark-and-sweep:

  1. drop the *step directories* (manifest + COMMITTED + any legacy v1
     chunks, which are step-private) of expired steps;
  2. mark: union the chunk refcounts of every surviving committed manifest;
  3. sweep: delete CAS chunks whose refcount is zero
     (storage.delete_unreferenced — the refcount-aware delete).

Sweep runs only after the step deletions commit, so a crash mid-collect can
strand orphan chunks but never break a live checkpoint; a later collect or
``sweep_orphans`` reclaims them.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.ckpt.layout import cas_prefix, step_prefix
from repro.ckpt.reader import list_steps, load_manifest
from repro.ckpt.storage import ObjectStore


def live_chunk_refs(store: ObjectStore, prefix: str,
                    steps: Optional[List[int]] = None) -> Dict[str, int]:
    """chunk store key -> number of committed manifests referencing it."""
    refs: Dict[str, int] = {}
    for s in (list_steps(store, prefix) if steps is None else steps):
        for key, n in load_manifest(store, prefix, s).chunk_refs().items():
            refs[key] = refs.get(key, 0) + n
    return refs


def sweep_orphans(store: ObjectStore, prefix: str) -> List[str]:
    """Delete CAS chunks referenced by no committed manifest.

    Returns the deleted keys so callers (checkpoint_manager) can invalidate
    any writer-side dedup caches.
    """
    refs = live_chunk_refs(store, prefix)
    deleted = []
    for key in store.list(cas_prefix(prefix)):
        if store.delete_unreferenced(key, refs.get(key, 0)):
            deleted.append(key)
    return deleted


def collect(store: ObjectStore, prefix: str, *, keep_last: int = 3,
            keep_every: int = 0, on_swept=None) -> List[int]:
    """Delete old committed checkpoints (mark-and-sweep).

    keep_last:  always retain the newest k steps.
    keep_every: additionally retain steps divisible by this (milestones).
    on_swept:   optional callback receiving the swept CAS keys — writers
                holding dedup caches use it to invalidate entries whose
                chunks just disappeared.
    Returns the deleted step numbers.
    """
    steps = list_steps(store, prefix)
    keep = set(steps[-keep_last:]) if keep_last else set()
    if keep_every:
        keep |= {s for s in steps if s % keep_every == 0}
    deleted = []
    for s in steps:
        if s in keep:
            continue
        store.delete_prefix(step_prefix(prefix, s))
        deleted.append(s)
    if deleted:
        swept = sweep_orphans(store, prefix)
        if on_swept is not None and swept:
            on_swept(swept)
    return deleted
