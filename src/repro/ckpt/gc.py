"""Checkpoint retention / garbage collection."""
from __future__ import annotations

from typing import List

from repro.ckpt.layout import step_prefix
from repro.ckpt.reader import list_steps
from repro.ckpt.storage import ObjectStore


def collect(store: ObjectStore, prefix: str, *, keep_last: int = 3,
            keep_every: int = 0) -> List[int]:
    """Delete old committed checkpoints.

    keep_last:  always retain the newest k steps.
    keep_every: additionally retain steps divisible by this (milestones).
    Returns the deleted step numbers.
    """
    steps = list_steps(store, prefix)
    keep = set(steps[-keep_last:]) if keep_last else set()
    if keep_every:
        keep |= {s for s in steps if s % keep_every == 0}
    deleted = []
    for s in steps:
        if s in keep:
            continue
        store.delete_prefix(step_prefix(prefix, s))
        deleted.append(s)
    return deleted
