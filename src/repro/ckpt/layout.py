"""Topology-agnostic checkpoint layout: pytree <-> named chunks + manifest.

The manifest records, per leaf: global shape, dtype, and a list of chunks
addressed by *global offsets* — never mesh coordinates. Any process on any
mesh can therefore restore any leaf under any sharding by reading the
overlapping chunks (reader.py). This is the paper's "compile for the common
denominator" portability rule applied to device topologies (DESIGN.md §2).

Two chunk layouts coexist (see docs/architecture.md):
  * format v1 (legacy): chunks live under their step directory
    (``<prefix>/step_<n>/chunks/<leaf>::o<off>``) and are private to one step.
  * format v2 (content-addressed): chunks live in a shared namespace keyed by
    the blake2b digest of their *encoded* bytes
    (``<prefix>/cas/<digest>``) and may be shared by any number of steps —
    the substrate for incremental checkpointing (writer.py skips the put for
    any chunk whose digest is already stored).
``Manifest.from_json`` loads both; v1 manifests simply carry ``hash=None``
chunks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

try:
    import ml_dtypes
except ImportError:                               # pragma: no cover
    ml_dtypes = None

MANIFEST = "MANIFEST.json"
COMMITTED = "COMMITTED"
CAS_DIR = "cas"
FORMAT_VERSION = 2                    # content-addressed chunks


def np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        if ml_dtypes is not None:
            return np.dtype(getattr(ml_dtypes, name))
        raise


# ---------------------------------------------------------------------------
# Paths
# ---------------------------------------------------------------------------

def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def leaf_items(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [("/".join(_key_str(k) for k in path), leaf) for path, leaf in flat]


def structure_skeleton(tree: Any) -> Any:
    """JSON-serializable skeleton for target-free restores."""
    if isinstance(tree, dict):
        return {"!kind": "dict",
                "items": {k: structure_skeleton(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"!kind": "tuple" if isinstance(tree, tuple) else "list",
                "items": [structure_skeleton(v) for v in tree]}
    return {"!kind": "leaf"}


def build_from_skeleton(skel: Any, leaves: Dict[str, Any], path: str = "") -> Any:
    kind = skel["!kind"]
    if kind == "dict":
        return {k: build_from_skeleton(v, leaves, f"{path}{k}/")
                for k, v in skel["items"].items()}
    if kind in ("tuple", "list"):
        seq = [build_from_skeleton(v, leaves, f"{path}{i}/")
               for i, v in enumerate(skel["items"])]
        return tuple(seq) if kind == "tuple" else seq
    return leaves[path[:-1]]


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChunkInfo:
    offset: Tuple[int, ...]
    shape: Tuple[int, ...]
    key: str                          # store key of the chunk object
    nbytes: int                       # encoded size
    hash: Optional[str] = None        # blake2b digest of encoded bytes (v2)


@dataclasses.dataclass
class PreEncodedLeaf:
    """Staging-form leaf whose shards were already encoded on device.

    Appears as an (unregistered, hence atomic) pytree leaf inside a
    snapshot produced by ``TrainerApp.snapshot_async`` with a lossy swap
    codec: ``chunks`` carries ``(offset, shape, PreEncodedChunk)`` triples
    in place of host ndarrays. ``writer._stage`` passes these straight to
    the upload pipeline; the manifest entry (shape/dtype/kind) is
    indistinguishable from a host-encoded leaf, so restore needs no new
    code path.
    """
    shape: Tuple[int, ...]
    dtype: str
    chunks: List[Tuple[Tuple[int, ...], Tuple[int, ...], Any]]
    kind: str = "array"


@dataclasses.dataclass
class LeafInfo:
    name: str
    shape: Tuple[int, ...]
    dtype: str
    kind: str                         # "array" | "scalar"
    chunks: List[ChunkInfo]


@dataclasses.dataclass
class Manifest:
    step: int
    codec: str
    leaves: Dict[str, LeafInfo]
    skeleton: Any
    metadata: Dict[str, Any]
    version: int = FORMAT_VERSION

    def to_json(self) -> str:
        def enc(o):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            raise TypeError(o)
        return json.dumps(dataclasses.asdict(self), default=enc)

    def chunk_refs(self) -> Dict[str, int]:
        """store key -> number of references from this manifest."""
        refs: Dict[str, int] = {}
        for li in self.leaves.values():
            for c in li.chunks:
                refs[c.key] = refs.get(c.key, 0) + 1
        return refs

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        leaves = {
            name: LeafInfo(
                name=li["name"], shape=tuple(li["shape"]), dtype=li["dtype"],
                kind=li["kind"],
                chunks=[ChunkInfo(tuple(c["offset"]), tuple(c["shape"]),
                                  c["key"], c["nbytes"], c.get("hash"))
                        for c in li["chunks"]])
            for name, li in d["leaves"].items()
        }
        return Manifest(step=d["step"], codec=d["codec"], leaves=leaves,
                        skeleton=d["skeleton"], metadata=d["metadata"],
                        version=d.get("version", 1))


def step_prefix(prefix: str, step: int) -> str:
    return f"{prefix}/step_{step:010d}"


def chunk_key(prefix: str, step: int, leaf: str,
              offset: Sequence[int]) -> str:
    """Format-v1 (step-private) chunk key; kept for full / legacy saves."""
    off = "o" + "_".join(str(int(o)) for o in offset) if offset else "o0"
    return f"{step_prefix(prefix, step)}/chunks/{leaf}::{off}"


def chunk_digest(data: bytes) -> str:
    """Content address of an encoded chunk (hex blake2b-160)."""
    return hashlib.blake2b(data, digest_size=20).hexdigest()


def cas_prefix(prefix: str) -> str:
    return f"{prefix}/{CAS_DIR}/"


def cas_key(prefix: str, digest: str) -> str:
    """Format-v2 content-addressed chunk key (shared across steps)."""
    return f"{cas_prefix(prefix)}{digest}"


# ---------------------------------------------------------------------------
# Shard enumeration
# ---------------------------------------------------------------------------

def _index_to_offset_shape(index: Tuple[slice, ...],
                           shape: Tuple[int, ...]) -> Tuple[Tuple[int, ...],
                                                            Tuple[int, ...]]:
    offs, shp = [], []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        offs.append(start)
        shp.append(stop - start)
    return tuple(offs), tuple(shp)


def local_shards(arr) -> List[Tuple[Tuple[int, ...], Tuple[int, ...],
                                    np.ndarray]]:
    """Unique addressable shards of a jax.Array (replicas deduped).

    Returns [(offset, shape, host_ndarray)].
    """
    if not isinstance(arr, jax.Array):
        a = np.asarray(arr)
        return [((0,) * a.ndim, a.shape, a)]
    out = []
    seen = set()
    for sh in arr.addressable_shards:
        off, shp = _index_to_offset_shape(
            tuple(sh.index) if sh.index else (slice(None),) * arr.ndim,
            arr.shape)
        if off in seen:
            continue
        seen.add(off)
        out.append((off, shp, np.asarray(sh.data)))
    return out
