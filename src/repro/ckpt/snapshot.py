"""Staged-snapshot handles: capture now, materialize off the hot path.

The synchronous contract (``Application.checkpoint_state`` returning a
fully materialized pytree) forces the device→host copy *under the app's
state lock*, stalling the train loop for the whole transfer. The staged
contract splits a snapshot into two phases:

  1. **capture** (microseconds, under the lock): pin an immutable
     *reference* to the state. JAX arrays are immutable and the train
     loop swaps whole state dicts, so holding references IS a consistent
     snapshot — no copy needed.
  2. **resolve** (milliseconds→seconds, off the lock): materialize the
     pytree — ``jax.device_get`` for lossless images, or device-side
     int8 encode (``kernels.qsnap.qsnap_encode_chunks``) that leaves the
     accelerator at ~1/4 the bytes.

``SnapshotHandle.resolve()`` runs at most once and caches its result, so
the control plane (CheckpointManager / AppManager) can hand the same
handle to a blocking save, an async writer thread, or a retried save
without re-materializing — and a resolve error surfaces identically on
every path.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional


class SnapshotHandle:
    """A checkpoint snapshot captured but not necessarily materialized.

    ``resolve()`` returns the checkpoint pytree; it is thread-safe and
    idempotent (the materialization function runs exactly once, failures
    are cached and re-raised so every consumer sees the same outcome).
    """

    def __init__(self, fn: Callable[[], Any], *,
                 step: Optional[int] = None):
        self._fn: Optional[Callable[[], Any]] = fn
        self._lock = threading.Lock()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._done = False
        self.step = step

    def resolve(self) -> Any:
        with self._lock:
            if not self._done:
                try:
                    self._value = self._fn()
                except BaseException as e:         # noqa: BLE001
                    self._error = e
                finally:
                    self._fn = None               # drop captured refs
                    self._done = True
            if self._error is not None:
                raise self._error
            return self._value


class ReadySnapshot(SnapshotHandle):
    """A handle over an already-materialized pytree (legacy adapter)."""

    def __init__(self, state: Any, *, step: Optional[int] = None):
        super().__init__(lambda: state, step=step)


class DeferredSnapshot(SnapshotHandle):
    """A handle whose pytree is built lazily by ``fn`` (the common case:
    ``fn`` closes over device-array references captured under the app's
    state lock and does the D2H copy / device encode when called)."""


def resolve_state(obj: Any) -> Any:
    """Materialize ``obj`` if it is a handle; pass pytrees through."""
    if isinstance(obj, SnapshotHandle):
        return obj.resolve()
    return obj
