from repro.ckpt.plane import DataPlaneConfig, PreEncodedChunk
from repro.ckpt.layout import PreEncodedLeaf
from repro.ckpt.reader import latest_step, list_steps, load_manifest, restore
from repro.ckpt.snapshot import (DeferredSnapshot, ReadySnapshot,
                                 SnapshotHandle, resolve_state)
from repro.ckpt.storage import (ChaosStorageError, FaultyStore, InMemoryStore,
                                LocalFSStore, ObjectStore, TwoTierStore)
from repro.ckpt.writer import AsyncCheckpointer, save_checkpoint
from repro.ckpt import gc

__all__ = [
    "latest_step", "list_steps", "load_manifest", "restore",
    "ChaosStorageError", "FaultyStore",
    "InMemoryStore", "LocalFSStore", "ObjectStore", "TwoTierStore",
    "AsyncCheckpointer", "save_checkpoint", "gc", "DataPlaneConfig",
    "PreEncodedChunk", "PreEncodedLeaf",
    "SnapshotHandle", "ReadySnapshot", "DeferredSnapshot", "resolve_state",
]
