"""Async sharded checkpoint writer with atomic commit.

Protocol (crash-safe at every point):
  1. every host serializes + puts its *local* shards (parallel data plane);
  2. the coordinator puts the manifest (global offsets only);
  3. the store is flushed (two-tier: remote replication durable);
  4. the coordinator puts the COMMITTED marker.
A reader only trusts steps with a COMMITTED marker, so partially-written
checkpoints are invisible. The async writer stages device->host copies
synchronously (consistent snapshot at a step boundary — the JAX analogue of
DMTCP's coordinated checkpoint) and does encode+upload off the critical path
(paper §5.2's lazy local->remote copy).
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import compression
from repro.ckpt.layout import (COMMITTED, MANIFEST, ChunkInfo, LeafInfo,
                               Manifest, chunk_key, leaf_items, local_shards,
                               np_dtype, step_prefix, structure_skeleton)
from repro.ckpt.storage import ObjectStore


def _stage(tree: Any) -> List[Tuple[str, str, Tuple[int, ...], str,
                                    List[Tuple[Tuple[int, ...],
                                               Tuple[int, ...], np.ndarray]]]]:
    """Synchronous device->host staging: [(name, kind, shape, dtype, shards)]."""
    staged = []
    for name, leaf in leaf_items(tree):
        kind = "array" if isinstance(leaf, (jax.Array, np.ndarray)) else "scalar"
        shards = local_shards(leaf)
        shape = np.asarray(leaf).shape if kind == "scalar" else tuple(leaf.shape)
        dtype = str(shards[0][2].dtype) if kind == "scalar" else str(leaf.dtype)
        staged.append((name, kind, tuple(shape), dtype, shards))
    return staged


def save_checkpoint(store: ObjectStore, prefix: str, step: int, tree: Any, *,
                    codec: str = "raw",
                    metadata: Optional[Dict[str, Any]] = None) -> Manifest:
    """Blocking save. Returns the committed manifest."""
    staged = _stage(tree)
    skeleton = structure_skeleton(tree)
    return _write_staged(store, prefix, step, staged, skeleton, codec,
                         metadata or {})


def _write_staged(store: ObjectStore, prefix: str, step: int, staged,
                  skeleton, codec: str, metadata: Dict[str, Any]) -> Manifest:
    leaves: Dict[str, LeafInfo] = {}
    for name, kind, shape, dtype, shards in staged:
        chunks = []
        for off, shp, host in shards:
            key = chunk_key(prefix, step, name, off)
            data = compression.encode(
                np.ascontiguousarray(host).tobytes(), host.dtype, codec)
            store.put(key, data)
            chunks.append(ChunkInfo(off, shp, key, len(data)))
        leaves[name] = LeafInfo(name, shape, dtype, kind, chunks)
    manifest = Manifest(step=step, codec=codec, leaves=leaves,
                        skeleton=skeleton,
                        metadata={**metadata, "time": time.time()})
    sp = step_prefix(prefix, step)
    store.put(f"{sp}/{MANIFEST}", manifest.to_json().encode())
    store.flush()                                  # durable before commit
    store.put(f"{sp}/{COMMITTED}", b"1")
    return manifest


class AsyncCheckpointer:
    """Double-buffered async checkpointing.

    ``save()`` blocks only for the device->host copy; serialization, codec
    and store puts run on a background thread. At most one snapshot is in
    flight — a second ``save()`` first waits for the previous one (double
    buffering), bounding host memory at 2x model state.
    """

    def __init__(self, store: ObjectStore, prefix: str, *,
                 codec: str = "raw"):
        self.store = store
        self.prefix = prefix
        self.codec = codec
        self._pool = cf.ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ckpt")
        self._inflight: Optional[cf.Future] = None
        self._lock = threading.Lock()
        self.last_committed: Optional[int] = None
        self.save_count = 0
        self.staging_time = 0.0

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict[str, Any]] = None,
             on_commit=None) -> None:
        self.wait()
        t0 = time.monotonic()
        staged = _stage(tree)                      # sync: consistent snapshot
        skeleton = structure_skeleton(tree)
        self.staging_time += time.monotonic() - t0

        def job():
            _write_staged(self.store, self.prefix, step, staged, skeleton,
                          self.codec, metadata or {})
            with self._lock:
                self.last_committed = step
            if on_commit is not None:
                on_commit(step)
        with self._lock:
            self._inflight = self._pool.submit(job)
            self.save_count += 1

    def wait(self) -> None:
        with self._lock:
            fut = self._inflight
        if fut is not None:
            fut.result()

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
