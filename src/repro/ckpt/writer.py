"""Async sharded checkpoint writer with atomic commit + incremental dedup.

Protocol (crash-safe at every point):
  1. every host serializes + puts its *local* shards (parallel data plane);
  2. the coordinator puts the manifest (global offsets only);
  3. the store is flushed (two-tier: remote replication durable);
  4. the coordinator puts the COMMITTED marker.
A reader only trusts steps with a COMMITTED marker, so partially-written
checkpoints are invisible. The async writer stages device->host copies
synchronously (consistent snapshot at a step boundary — the JAX analogue of
DMTCP's coordinated checkpoint) and does encode+upload off the critical path
(paper §5.2's lazy local->remote copy).

Incremental saves (format v2, the default): each encoded chunk is stored
under its content digest in a shared ``<prefix>/cas/`` namespace
(layout.cas_key). Before putting, the writer consults the previous committed
manifest — any chunk whose digest is already stored is skipped, so a save
after a step that only touched a subset of leaves/shards uploads only the
delta. This attacks the paper's dominant cost driver (image size / write
time, Table 2 + Fig 6) from a different axis than the codecs: codecs shrink
every chunk, dedup removes *unchanged* chunks entirely. ``AsyncCheckpointer``
additionally keeps a per-leaf raw-content hash cache so unchanged chunks skip
even the encode step, not just the upload.

Parallel data plane (plane.py): chunks flow through a bounded encode pool
into a concurrent upload stage — ``DataPlaneConfig`` sets the worker counts
and the in-flight byte cap (backpressure). Dedup tables (``known``,
``raw_cache``) are shared across workers under one lock, and single-flight
claims per digest guarantee the same puts / counters / bytes as the serial
plane regardless of scheduling; with ``workers=1`` the plane degenerates to
exactly the serial loop. The commit protocol is untouched: every upload is
joined before the manifest is put, so steps 2–4 above still gate visibility.
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import compression
from repro.ckpt.layout import (COMMITTED, MANIFEST, ChunkInfo, LeafInfo,
                               Manifest, PreEncodedLeaf, cas_key,
                               chunk_digest, chunk_key, leaf_items,
                               local_shards, np_dtype, step_prefix,
                               structure_skeleton)
from repro.ckpt.plane import (ByteBudget, DataPlaneConfig, PreEncodedChunk,
                              SingleFlight, shared_executor)
from repro.ckpt.snapshot import SnapshotHandle, resolve_state
from repro.ckpt.storage import ObjectStore
from repro.obs.telemetry import registry
from repro.obs.trace import tracer


def _stage(tree: Any) -> List[Tuple[str, str, Tuple[int, ...], str,
                                    List[Tuple[Tuple[int, ...],
                                               Tuple[int, ...], np.ndarray]]]]:
    """Synchronous device->host staging: [(name, kind, shape, dtype, shards)].

    ``PreEncodedLeaf`` leaves (device-side encode already done) carry
    ``PreEncodedChunk`` payloads in the shard slot instead of host
    ndarrays; the encode stage passes them through untouched.
    """
    staged = []
    for name, leaf in leaf_items(tree):
        if isinstance(leaf, PreEncodedLeaf):
            staged.append((name, leaf.kind, tuple(leaf.shape), leaf.dtype,
                           list(leaf.chunks)))
            continue
        kind = "array" if isinstance(leaf, (jax.Array, np.ndarray)) else "scalar"
        shards = local_shards(leaf)
        shape = np.asarray(leaf).shape if kind == "scalar" else tuple(leaf.shape)
        dtype = str(shards[0][2].dtype) if kind == "scalar" else str(leaf.dtype)
        staged.append((name, kind, tuple(shape), dtype, shards))
    return staged


def _raw_digest(codec: str, dtype: str, raw: bytes) -> str:
    """Identity of a chunk's *unencoded* content (pre-codec dedup key).

    Scoped by codec: the cache maps raw content to an *encoded* digest,
    so the same bytes saved under a different codec (e.g. a lossless
    periodic image vs an int8 swap-out image) must miss, not alias.
    """
    h = hashlib.blake2b(digest_size=20)
    h.update(codec.encode())
    h.update(b"\0")
    h.update(dtype.encode())
    h.update(raw)
    return h.hexdigest()


def _adapt_pre_encoded(chunk: PreEncodedChunk, codec: str) -> bytes:
    """Finish a device-encoded payload for the image codec.

    Equal codec: pass through (byte-identical to the host encoder, so the
    CAS digest dedups across device- and host-compressed images).
    ``int8+zlib`` over an ``int8`` payload: apply the same deflate the
    host codec would. Anything else is a policy error — lossy payloads
    cannot satisfy a lossless image codec.
    """
    if codec == chunk.codec:
        return chunk.data
    if codec == "int8+zlib" and chunk.codec == "int8":
        return zlib.compress(chunk.data, level=1)
    raise ValueError(
        f"pre-encoded chunk (codec {chunk.codec!r}) cannot satisfy "
        f"image codec {codec!r}")


def known_digests(store: ObjectStore, prefix: str,
                  before_step: Optional[int] = None) -> Dict[str, int]:
    """digest -> encoded nbytes for the newest committed manifest.

    This is the writer's dedup table: any chunk whose encoded digest appears
    here is guaranteed live in the store (GC always retains the most recent
    committed step), so its put can be skipped without an existence check.
    """
    from repro.ckpt.reader import list_steps, load_manifest
    steps = [s for s in list_steps(store, prefix)
             if before_step is None or s < before_step]
    if not steps:
        return {}
    man = load_manifest(store, prefix, steps[-1])
    return {c.hash: c.nbytes for li in man.leaves.values()
            for c in li.chunks if c.hash is not None}


def save_checkpoint(store: ObjectStore, prefix: str, step: int, tree: Any, *,
                    codec: str = "raw", incremental: bool = True,
                    metadata: Optional[Dict[str, Any]] = None,
                    plane: Optional[DataPlaneConfig] = None,
                    trace_id: str = "") -> Manifest:
    """Blocking save. Returns the committed manifest.

    incremental=True (default) writes format-v2 content-addressed chunks and
    skips any chunk already present in the previous committed manifest;
    incremental=False writes the legacy step-private v1 layout.
    plane configures the parallel data plane (None = DataPlaneConfig()).
    ``tree`` may be a SnapshotHandle (resolved here — blocking save).
    trace_id correlates the emitted save spans with the owning job.
    """
    with tracer().span("ckpt/save", cat="ckpt", trace_id=trace_id,
                       args={"step": step, "codec": codec,
                             "blocking": True}):
        with tracer().span("ckpt/materialize", cat="ckpt"):
            tree = resolve_state(tree)
            staged = _stage(tree)
            skeleton = structure_skeleton(tree)
        return _write_staged(store, prefix, step, staged, skeleton, codec,
                             metadata or {}, incremental=incremental,
                             plane=plane, trace_id=trace_id)


class _SaveContext:
    """Shared mutable state of one save: dedup tables, stats, backpressure.

    One lock guards ``known``, ``raw_cache`` and ``stats``; the two
    SingleFlight tables share it so a claim's existence check and the table
    lookup it guards are one atomic step.
    """

    def __init__(self, store: ObjectStore, prefix: str, codec: str,
                 incremental: bool, known: Optional[Dict[str, int]],
                 raw_cache: Optional[Dict[str, Tuple[str, int]]],
                 plane: DataPlaneConfig, cas_scope: str = "",
                 trace_id: str = ""):
        self.store = store
        self.prefix = prefix
        self.codec = codec
        # span context for per-chunk stages: pool threads cannot see the
        # caller's thread-local span stack, so they parent explicitly on
        # the save's root span captured here (None when untraced)
        self.trace_id = trace_id
        self.span = tracer().current()
        # CAS key namespace tag: chunks land at <prefix>/cas/<scope><digest>.
        # Gang saves scope each rank's uploads ("r<rank>-") so one rank's
        # puts are distinguishable — per-rank fault injection and per-rank
        # incremental dedup both key off it. "" = the classic shared space.
        self.cas_scope = cas_scope
        self.incremental = incremental
        self.known = known
        self.raw_cache = raw_cache
        self.lock = threading.Lock()
        self.raw_flight = SingleFlight(self.lock)
        self.put_flight = SingleFlight(self.lock)
        self.budget = ByteBudget(0 if plane.serial_save
                                 else plane.max_inflight_bytes, name="ckpt")
        self.stats = {"chunks": 0, "dedup_hits": 0, "dedup_misses": 0,
                      "bytes_written": 0, "bytes_deduped": 0}

    def count_hit(self, nbytes: int) -> None:
        with self.lock:
            self.stats["dedup_hits"] += 1
            self.stats["bytes_deduped"] += nbytes

    def count_miss(self, nbytes: int) -> None:
        with self.lock:
            self.stats["dedup_misses"] += 1
            self.stats["bytes_written"] += nbytes


class _Encoded:
    """Result of the encode stage for one chunk, handed to the upload stage.

    ``chunk`` is set when the encode stage fully resolved the chunk (raw
    cache hit — nothing to upload); otherwise ``data`` carries the encoded
    bytes and ``raw_key`` the raw-digest claim to settle after the put.
    """
    __slots__ = ("chunk", "key", "digest", "data", "raw_key", "off", "shp")

    def __init__(self, chunk=None, key=None, digest=None, data=None,
                 raw_key=None, off=None, shp=None):
        self.chunk = chunk
        self.key = key
        self.digest = digest
        self.data = data
        self.raw_key = raw_key
        self.off = off
        self.shp = shp


def _encode_chunk(ctx: _SaveContext, step: int, name: str, off, shp,
                  host, dtype: str) -> _Encoded:
    """Stage 1: serialize + codec + digest (CPU-bound, encode pool).

    ``host`` is a host ndarray, or a PreEncodedChunk whose payload was
    built on device — then the codec is already applied and this stage
    reduces to adapt + digest (the raw cache is skipped: there is no raw
    buffer, and no encode to save).
    """
    with tracer().span("ckpt/encode", cat="ckpt", trace_id=ctx.trace_id,
                       parent=ctx.span, args={"leaf": name}):
        return _encode_chunk_inner(ctx, step, name, off, shp, host, dtype)


def _encode_chunk_inner(ctx: _SaveContext, step: int, name: str, off, shp,
                        host, dtype: str) -> _Encoded:
    if isinstance(host, PreEncodedChunk):
        data = _adapt_pre_encoded(host, ctx.codec)
        if not ctx.incremental:
            return _Encoded(key=chunk_key(ctx.prefix, step, name, off),
                            data=data, off=off, shp=shp)
        return _Encoded(digest=chunk_digest(data), data=data, off=off,
                        shp=shp)
    raw = np.ascontiguousarray(host).tobytes()
    if not ctx.incremental:
        key = chunk_key(ctx.prefix, step, name, off)
        data = compression.encode(raw, host.dtype, ctx.codec)
        return _Encoded(key=key, data=data, off=off, shp=shp)
    rk: Optional[str] = None
    if ctx.raw_cache is not None:
        rk = _raw_digest(ctx.codec, dtype, raw)
        if not ctx.raw_flight.claim(rk, lambda: rk in ctx.raw_cache):
            with ctx.lock:
                digest, nbytes = ctx.raw_cache[rk]
            ctx.count_hit(nbytes)                # skipped encode AND put
            return _Encoded(chunk=ChunkInfo(
                off, shp, cas_key(ctx.prefix, ctx.cas_scope + digest),
                nbytes, digest))
    try:
        data = compression.encode(raw, host.dtype, ctx.codec)
    except BaseException:
        if rk is not None:
            ctx.raw_flight.abort(rk)             # let a waiter retry
        raise
    return _Encoded(digest=chunk_digest(data), data=data, raw_key=rk,
                    off=off, shp=shp)


def _upload_chunk(ctx: _SaveContext, enc: _Encoded) -> ChunkInfo:
    """Stage 2: dedup-aware store put (IO-bound, upload pool)."""
    with tracer().span("ckpt/upload", cat="ckpt", trace_id=ctx.trace_id,
                       parent=ctx.span, args={"nbytes": len(enc.data)}):
        return _upload_chunk_inner(ctx, enc)


def _upload_chunk_inner(ctx: _SaveContext, enc: _Encoded) -> ChunkInfo:
    if not ctx.incremental:                      # legacy v1: plain put
        ctx.store.put(enc.key, enc.data)
        ctx.count_miss(len(enc.data))
        return ChunkInfo(enc.off, enc.shp, enc.key, len(enc.data))
    digest, nbytes = enc.digest, len(enc.data)
    ok = False
    try:
        if ctx.put_flight.claim(digest, lambda: digest in ctx.known):
            try:
                wrote = ctx.store.put_if_absent(
                    cas_key(ctx.prefix, ctx.cas_scope + digest), enc.data)
            except BaseException:
                ctx.put_flight.abort(digest)     # a waiter may retry the put
                raise
            with ctx.lock:
                ctx.known[digest] = nbytes
            (ctx.count_miss if wrote else ctx.count_hit)(nbytes)
            ctx.put_flight.done(digest)
        else:                                    # previous manifest, or a
            ctx.count_hit(nbytes)                # concurrent worker, won
        ok = True
    finally:
        if enc.raw_key is not None:
            if ok:
                with ctx.lock:
                    ctx.raw_cache[enc.raw_key] = (digest, nbytes)
            ctx.raw_flight.done(enc.raw_key)
    return ChunkInfo(enc.off, enc.shp,
                     cas_key(ctx.prefix, ctx.cas_scope + digest),
                     nbytes, digest)


def _run_pipeline(ctx: _SaveContext, plane: DataPlaneConfig, step: int,
                  tasks: List[tuple]) -> None:
    """Encode pool -> upload pool, bounded by ctx.budget; joins everything.

    Each task is (slots, i, name, off, shp, host, dtype); the finished
    ChunkInfo lands in ``slots[i]`` so the manifest is assembled in
    deterministic (staging) order no matter which worker finishes when.
    """
    up = shared_executor("up", plane.upload_workers)
    enc = shared_executor("enc", plane.encode_workers)

    def upload_job(slots, i, enc_result, admitted):
        try:
            slots[i] = _upload_chunk(ctx, enc_result)
        finally:
            ctx.budget.release(admitted)

    def encode_job(task, admitted):
        slots, i, name, off, shp, host, dtype = task
        try:
            enc_result = _encode_chunk(ctx, step, name, off, shp,
                                       host, dtype)
            if enc_result.chunk is not None:         # resolved: no upload
                slots[i] = enc_result.chunk
                ctx.budget.release(admitted)
                return None
            return up.submit(upload_job, slots, i, enc_result, admitted)
        except BaseException:
            ctx.budget.release(admitted)
            raise

    encode_futs = []
    for task in tasks:
        admitted = task[5].nbytes
        ctx.budget.acquire(admitted)                 # backpressure
        encode_futs.append(enc.submit(encode_job, task, admitted))
    upload_futs = [f.result() for f in encode_futs]
    for f in upload_futs:
        if f is not None:
            f.result()                               # join: all puts durable


def upload_staged(ctx: _SaveContext, plane: DataPlaneConfig, step: int,
                  staged) -> Dict[str, LeafInfo]:
    """Encode + upload staged shards through the data plane; no commit.

    Returns the leaf table with every put durably joined. The caller owns
    the commit protocol — `_write_staged` commits immediately; the gang
    writer (ckpt/gang.py) runs one of these per rank and commits a single
    merged manifest only after *every* rank's uploads joined.
    """
    leaves: Dict[str, LeafInfo] = {}
    tasks: List[tuple] = []
    for name, kind, shape, dtype, shards in staged:
        slots: List[Optional[ChunkInfo]] = [None] * len(shards)
        leaves[name] = LeafInfo(name, shape, dtype, kind, slots)
        for i, (off, shp, host) in enumerate(shards):
            ctx.stats["chunks"] += 1
            tasks.append((slots, i, name, off, shp, host, dtype))
    if plane.serial_save:
        for slots, i, name, off, shp, host, dtype in tasks:
            enc = _encode_chunk(ctx, step, name, off, shp, host, dtype)
            slots[i] = enc.chunk if enc.chunk is not None \
                else _upload_chunk(ctx, enc)
    else:
        _run_pipeline(ctx, plane, step, tasks)
    return leaves


def _write_staged(store: ObjectStore, prefix: str, step: int, staged,
                  skeleton, codec: str, metadata: Dict[str, Any], *,
                  incremental: bool = True,
                  known: Optional[Dict[str, int]] = None,
                  raw_cache: Optional[Dict[str, Tuple[str, int]]] = None,
                  plane: Optional[DataPlaneConfig] = None,
                  trace_id: str = "") -> Manifest:
    """Serialize + upload staged shards, then atomically commit.

    known:     digest -> nbytes of chunks guaranteed live in the store
               (primed from the previous committed manifest when None).
    raw_cache: raw-content digest -> (encoded digest, nbytes); lets repeat
               content skip the codec entirely (AsyncCheckpointer only).
    plane:     parallel data-plane knobs (None = DataPlaneConfig()).
    """
    plane = plane or DataPlaneConfig()
    if incremental and known is None:
        known = known_digests(store, prefix, before_step=step)
    ctx = _SaveContext(store, prefix, codec, incremental, known, raw_cache,
                       plane, trace_id=trace_id)
    leaves = upload_staged(ctx, plane, step, staged)
    manifest = Manifest(step=step, codec=codec, leaves=leaves,
                        skeleton=skeleton,
                        metadata={**metadata, "time": time.time(),
                                  "dedup": ctx.stats},
                        version=2 if incremental else 1)
    sp = step_prefix(prefix, step)
    tr = tracer()
    with tr.span("ckpt/manifest", cat="ckpt", trace_id=trace_id,
                 parent=ctx.span, args={"step": step}):
        store.put(f"{sp}/{MANIFEST}", manifest.to_json().encode())
    with tr.span("ckpt/commit", cat="ckpt", trace_id=trace_id,
                 parent=ctx.span, args={"step": step}):
        store.flush()                              # durable before commit
        store.put(f"{sp}/{COMMITTED}", b"1")
        store.flush()       # marker durable too: a host that loses its fast
    reg = registry()        # tier right after save still sees the commit
    if reg.enabled:
        for k, v in ctx.stats.items():
            reg.inc(f"ckpt.{k}", v)
        reg.inc("ckpt.saves")
    return manifest


class AsyncCheckpointer:
    """Double-buffered async checkpointing.

    ``save()`` blocks only for the device->host copy; serialization, codec
    and store puts run on a background thread (which in turn drives the
    parallel data plane — see ``DataPlaneConfig``). At most one snapshot is
    in flight — a second ``save()`` first waits for the previous one (double
    buffering), bounding host memory at 2x model state.

    Incremental mode maintains two dedup caches across saves:
      * ``_known``     — encoded digest -> nbytes (skips the store put);
      * ``_raw_cache`` — raw digest -> (encoded digest, nbytes) (skips the
        codec too — the common case for frozen embeddings / untouched
        optimizer slots).
    Both are shared across the plane's workers (guarded by the save's lock)
    and pruned after every commit to exactly the chunks of the manifest
    just written: those are the only chunks mark-and-sweep GC (ckpt/gc.py)
    is guaranteed to retain, so a cache hit can never reference a swept key.
    """

    def __init__(self, store: ObjectStore, prefix: str, *,
                 codec: str = "raw", incremental: bool = True,
                 plane: Optional[DataPlaneConfig] = None,
                 trace_id: str = ""):
        self.store = store
        self.prefix = prefix
        self.codec = codec
        self.trace_id = trace_id
        self.incremental = incremental
        self.plane = plane or DataPlaneConfig()
        self._pool = cf.ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ckpt")
        self._inflight: Optional[cf.Future] = None
        self._lock = threading.Lock()
        self.last_committed: Optional[int] = None
        self.save_count = 0
        self.staging_time = 0.0
        self._known: Optional[Dict[str, int]] = None
        self._raw_cache: Dict[str, Tuple[str, int]] = {}
        # cumulative dedup counters across saves (read via stats())
        self.dedup_hits = 0
        self.dedup_misses = 0
        self.bytes_written = 0
        self.bytes_deduped = 0
        self.last_error: Optional[BaseException] = None
        self.failed_saves = 0

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict[str, Any]] = None,
             on_commit=None, codec: Optional[str] = None) -> None:
        """Submit an async save of ``tree`` (a pytree or SnapshotHandle).

        A materialized pytree is staged synchronously here (legacy
        contract: the caller's lock protects it only for this call). A
        SnapshotHandle is resolved *on the writer thread* — the caller
        returns in microseconds and the device→host copy (or device
        encode) overlaps whatever the app does next. ``codec`` overrides
        this checkpointer's default for just this save (e.g. the lossy
        swap-out codec for a suspend image).
        """
        # A previous save's failure (e.g. a transient storage fault) must
        # not poison this independent save: record it and move on. The
        # failed step has no COMMITTED marker, so it is simply invisible.
        self.wait(raise_error=False)
        t0 = time.monotonic()
        if isinstance(tree, SnapshotHandle):
            staged = skeleton = None               # resolved on writer thread
        else:
            with tracer().span("ckpt/stage", cat="ckpt",
                               trace_id=self.trace_id,
                               args={"step": step}):
                staged = _stage(tree)              # sync: consistent snapshot
                skeleton = structure_skeleton(tree)
        self.staging_time += time.monotonic() - t0
        save_codec = codec or self.codec

        def job():
            with tracer().span("ckpt/save", cat="ckpt",
                               trace_id=self.trace_id,
                               args={"step": step, "codec": save_codec,
                                     "blocking": False}):
                if staged is None:
                    with tracer().span("ckpt/materialize", cat="ckpt"):
                        state = tree.resolve()     # off the app's hot path
                        job_staged = _stage(state)
                        job_skeleton = structure_skeleton(state)
                else:
                    job_staged, job_skeleton = staged, skeleton
                if self.incremental and self._known is None:
                    self._known = known_digests(self.store, self.prefix,
                                                before_step=step)
                man = _write_staged(self.store, self.prefix, step,
                                    job_staged, job_skeleton, save_codec,
                                    metadata or {},
                                    incremental=self.incremental,
                                    known=self._known,
                                    raw_cache=self._raw_cache,
                                    plane=self.plane,
                                    trace_id=self.trace_id)
                self._absorb(man)
                with self._lock:
                    self.last_committed = step
                if on_commit is not None:
                    on_commit(step)
        with self._lock:
            self._inflight = self._pool.submit(job)
            self.save_count += 1

    def _absorb(self, man: Manifest) -> None:
        """Fold a committed manifest's dedup stats into the cumulative
        counters and prune caches to its (GC-protected) chunk set."""
        d = man.metadata.get("dedup", {})
        with self._lock:
            self.dedup_hits += d.get("dedup_hits", 0)
            self.dedup_misses += d.get("dedup_misses", 0)
            self.bytes_written += d.get("bytes_written", 0)
            self.bytes_deduped += d.get("bytes_deduped", 0)
        if not self.incremental:
            return
        live = {c.hash for li in man.leaves.values() for c in li.chunks}
        self._known = {h: n for h, n in (self._known or {}).items()
                       if h in live}
        self._raw_cache = {rk: v for rk, v in self._raw_cache.items()
                           if v[0] in live}

    def run_serialized(self, fn):
        """Run ``fn`` on the writer thread, after any in-flight save.

        Deletes/sweeps of this prefix must go through here: a sweep computes
        refcounts from *committed* manifests only, so racing an in-flight
        save could reap chunks the save has put but not yet committed.
        """
        fut = self._pool.submit(fn)
        return fut.result()

    def invalidate(self, keys) -> None:
        """Drop dedup-cache entries for deleted chunk keys (their digests).

        Call after sweeping chunks outside the writer's own commit cycle
        (e.g. CheckpointManager.delete_image); a stale hit would commit a
        manifest pointing at a reaped chunk.
        """
        digests = {k.rsplit("/", 1)[-1] for k in keys}
        if self._known:
            self._known = {h: n for h, n in self._known.items()
                           if h not in digests}
        self._raw_cache = {rk: v for rk, v in self._raw_cache.items()
                           if v[0] not in digests}

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"save_count": self.save_count,
                    "dedup_hits": self.dedup_hits,
                    "dedup_misses": self.dedup_misses,
                    "bytes_written": self.bytes_written,
                    "bytes_deduped": self.bytes_deduped}

    def wait(self, raise_error: bool = True) -> None:
        """Block until the in-flight save (if any) finishes.

        A failed save is consumed exactly once: its exception is recorded
        in ``last_error``/``failed_saves`` and the in-flight slot cleared,
        so one transient fault does not re-raise forever. With
        ``raise_error=False`` the failure is recorded but swallowed (the
        recovery path wants the newest COMMITTED image, not the error)."""
        with self._lock:
            fut = self._inflight
        if fut is None:
            return
        try:
            fut.result()
        except BaseException as e:                 # noqa: BLE001
            with self._lock:
                self.last_error = e
                self.failed_saves += 1
                if self._inflight is fut:
                    self._inflight = None
            if raise_error:
                raise

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
