"""repro — Checkpointing-as-a-Service for multi-pod JAX training/serving.

Reproduction (+ TPU adaptation) of "Checkpointing as a Service in
Heterogeneous Cloud Environments" (Cao, Simonin, Cooperman, Morin; 2014).
See DESIGN.md for the paper -> system mapping.
"""
__version__ = "1.0.0"
