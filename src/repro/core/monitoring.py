"""Monitoring Manager (paper §6.3): liveness + application health.

Two mechanisms, mirroring the paper exactly:
  * native failure notifications, where the backend supports them (Snooze) —
    zero polling, immediate recovery;
  * a cloud-agnostic **binary broadcast tree** of per-VM monitoring daemons
    for backends without notifications (OpenStack): the root probes down the
    tree and aggregates health reports up — one round trip costs
    O(log2 n) hops (reproduced in Fig 4c's benchmark).

Health ≠ liveness: each application provides a health hook; the monitor also
derives *performance* health (straggler detection via per-step-time
z-scores) — the paper's "exceptionally low performance ... proactively
suspends the job" feature (§1, use case 3 of §2.2).

Consumers: `core/app_manager.py` subscribes and maps reports onto the
paper's two recovery paths — VM failure → replace + restore from latest
image (§6.3 case 1); application failure → in-place restart (§6.3 case 2).
The broadcast-tree round-trip cost is measured in
`benchmarks/fig4_service_load.py` (Fig 4c).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.clusters.base import VMHandle
from repro.obs.telemetry import paper_now, registry
from repro.obs.trace import tracer
from repro.sim.simtime import active_clock
from repro.clusters.simulator import sim_sleep


@dataclasses.dataclass
class HealthReport:
    unreachable: List[str]           # vm ids
    unhealthy: List[str]             # vm ids failing the app health hook
    stragglers: List[str]            # vm ids with degraded performance
    rtt_s: float                     # broadcast-tree round-trip (simulated)

    @property
    def ok(self) -> bool:
        return not (self.unreachable or self.unhealthy)


def tree_depth(n: int) -> int:
    return max(1, math.ceil(math.log2(n + 1)))


def heartbeat_roundtrip(vms: Sequence[VMHandle],
                        health_hook: Optional[Callable[[], bool]] = None,
                        hop_latency_s: float = 0.05,
                        straggler_threshold: float = 3.0) -> HealthReport:
    """One probe/aggregate round over the binary broadcast tree.

    The tree is rooted at vms[0]; node i's children are 2i+1 / 2i+2. The
    probe descends and reports ascend level-by-level, so the critical path
    is 2 * depth hops — each VM is visited once (the paper's evidence that
    the tree "consumes few network resources and scales").
    """
    n = len(vms)
    depth = tree_depth(n)
    sim_sleep(2 * depth * hop_latency_s)          # critical path
    unreachable = [vm.vm_id for vm in vms if not vm.reachable]
    reachable = [vm for vm in vms if vm.reachable]
    unhealthy: List[str] = []
    # Only ask the app when it can answer: with every VM unreachable there
    # is no daemon to run the hook, and a raising hook is an *unhealthy
    # application*, not a dead monitor thread (the old behaviour let a
    # broken user hook kill the polling loop).
    if health_hook is not None and reachable:
        try:
            healthy = bool(health_hook())
        except Exception:                          # noqa: BLE001
            healthy = False
        if not healthy:
            # the hook is application-scoped; attribute it to the root daemon
            unhealthy.append(vms[0].vm_id)
    # performance health: hosts running significantly slower than the
    # fleet's typical pace (median-relative — uniform slowness is the
    # workload, an outlier is a straggler). With <2 reachable hosts (or a
    # degenerate zero median) there is no pace baseline: report none.
    slowdowns = sorted(vm.host.slowdown for vm in reachable)
    stragglers = []
    if len(slowdowns) >= 2:
        median = slowdowns[len(slowdowns) // 2]
        if median > 0:
            for vm in reachable:
                if vm.host.slowdown > straggler_threshold * median:
                    stragglers.append(vm.vm_id)
    return HealthReport(unreachable, unhealthy, stragglers,
                        rtt_s=2 * depth * hop_latency_s)


@dataclasses.dataclass
class LowPerfConfig:
    """Baseline-relative low-performance detection (paper §1: jobs that
    "incur exceptionally low performance" are proactively suspended).

    Each watched app publishes a throughput sample per poll (its
    ``perf_fn`` progress counter differenced over the poll window, in
    units/paper-second) into the metrics registry, smoothed by an EWMA.
    The first ``warmup_samples`` samples establish a baseline (the peak
    observed rate — it also ratchets up later, so jit warmup cannot lock
    in a slow baseline); once the EWMA stays below
    ``degradation_factor * baseline`` for ``grace_polls`` consecutive
    samples the monitor reports ``low_performance`` exactly once per
    watch. ``min_window_s`` (paper seconds) is the smallest poll window a
    rate is computed over (shorter windows are folded into the next one).
    """
    degradation_factor: float = 0.4
    grace_polls: int = 3
    warmup_samples: int = 3
    ewma_alpha: float = 0.3
    min_window_s: float = 0.5


class MonitoringManager:
    """Watches RUNNING applications; triggers recovery callbacks.

    ``recover_cb(coord_id, kind)`` with kind in {"vm_failure",
    "app_failure", "straggler", "low_performance"} — the Application
    Manager decides the recovery action (paper §6.3's two cases +
    proactive suspend).
    """

    def __init__(self, recover_cb: Callable[[str, str], None],
                 poll_interval_s: float = 0.05,
                 native_grace_polls: int = 3,
                 straggler_threshold: float = 3.0,
                 lowperf: Optional[LowPerfConfig] = None):
        self._recover_cb = recover_cb
        self.poll_interval_s = poll_interval_s
        # Native backends notify VM *crashes*, but a network partition is
        # invisible to the IaaS — after this many consecutive unreachable
        # polls the tree declares the VM failed anyway (paper §6.3's
        # cloud-agnostic path backstopping the notification path).
        self.native_grace_polls = native_grace_polls
        # z-score cutoff for the broadcast tree's host-pace straggler
        # check; float("inf") disables it (e.g. to exercise the
        # telemetry-driven detector alone)
        self.straggler_threshold = straggler_threshold
        # telemetry-driven throughput watchdog; None = disabled (chaos
        # scenarios and CACSService(lowperf=...) turn it on)
        self.lowperf = lowperf
        self.lowperf_detections = 0
        self._watched: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.heartbeats = 0
        self.native_notifications = 0
        self.partition_fallbacks = 0
        # whole-fleet outage telemetry: polls where EVERY VM of an app was
        # unreachable at once. A single VM failing is the paper's §6.3
        # case 1; the entire fleet going dark at once is the cloud-outage
        # signature that cross-cloud failover (core/replication.py) keys on.
        self.fleet_unreachable_polls = 0
        self._fleet_down: set = set()

    # ---- registration --------------------------------------------------
    def watch(self, coord_id: str, vms: Sequence[VMHandle],
              health_hook: Optional[Callable[[], bool]],
              native_notifications: bool,
              perf_fn: Optional[Callable[[], float]] = None,
              trace_id: str = "") -> None:
        """``perf_fn`` is a monotonic progress counter (steps, tokens,
        iterations); the monitor differences it per poll into a
        throughput gauge and feeds the low-performance detector.  A
        re-watch (resume, restart) resets the perf baseline — the new
        placement earns its own warmup."""
        anchor = None
        if perf_fn is not None:
            try:
                anchor = (paper_now(), float(perf_fn()))
            except Exception:                      # noqa: BLE001
                anchor = None                      # app not started yet
        with self._lock:
            self._watched[coord_id] = {
                "vms": list(vms), "hook": health_hook,
                "native": native_notifications, "unreachable_polls": 0,
                "perf_fn": perf_fn, "trace_id": trace_id,
                "perf_anchor": anchor, "perf_ewma": None,
                "perf_peak": 0.0, "perf_warmup": 0,
                "perf_baseline": None, "perf_below": 0, "perf_fired": False,
            }
            self._fleet_down.discard(coord_id)

    def unwatch(self, coord_id: str) -> None:
        with self._lock:
            self._watched.pop(coord_id, None)

    def on_native_failure(self, coord_id: str) -> None:
        """Entry point for backend failure notifications (Snooze path)."""
        self.native_notifications += 1
        self._recover_cb(coord_id, "vm_failure")

    # ---- polling loop (agent-based path) ---------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def _loop(self) -> None:
        # poll pacing through the installed clock (read live so a virtual
        # clock installed for the test session is honored): under SimClock
        # the interval elapses in virtual time instead of wall sleeping
        while not active_clock().wait(self._stop, self.poll_interval_s):
            with self._lock:
                watched = dict(self._watched)
            for coord_id, info in watched.items():
                try:
                    self._poll_one(coord_id, info)
                except Exception:                  # noqa: BLE001
                    # one bad probe must not kill the monitor for everyone
                    continue

    def _poll_one(self, coord_id: str, info: dict) -> None:
        report = self.check_once(coord_id)
        if report is None:
            return
        registry().inc("monitor.polls")
        tracer().event("monitor/poll", cat="monitor",
                       trace_id=info.get("trace_id", ""),
                       args={"coord": coord_id, "ok": report.ok,
                             "stragglers": len(report.stragglers)})
        if report.unreachable:
            if len(report.unreachable) == len(info["vms"]):
                # the whole fleet is dark at once — record the outage
                # signature (sticky until the next successful watch) for
                # the failover controller to corroborate against
                with self._lock:
                    self.fleet_unreachable_polls += 1
                    self._fleet_down.add(coord_id)
            if not info["native"]:
                self._recover_cb(coord_id, "vm_failure")
            elif self._bump_unreachable(coord_id) >= self.native_grace_polls:
                # partition fallback: the IaaS never reported a crash, yet
                # the tree cannot reach the VM — declare it failed. Reset
                # the streak so one partition counts once (the recovery's
                # unwatch lands asynchronously; later ticks must restart
                # the grace window, not re-count the same fault).
                self._reset_unreachable(coord_id)
                self.partition_fallbacks += 1
                self._recover_cb(coord_id, "vm_failure")
            return
        self._reset_unreachable(coord_id)
        with self._lock:
            self._fleet_down.discard(coord_id)
        if report.unhealthy:
            self._recover_cb(coord_id, "app_failure")
        elif report.stragglers:
            self._recover_cb(coord_id, "straggler")
        elif self._check_perf(coord_id, info):
            self.lowperf_detections += 1
            registry().inc("monitor.lowperf_detections")
            tracer().event("monitor/low_performance", cat="monitor",
                           trace_id=info.get("trace_id", ""),
                           args={"coord": coord_id,
                                 "ewma": info.get("perf_ewma"),
                                 "baseline": info.get("perf_baseline")})
            self._recover_cb(coord_id, "low_performance")

    def _check_perf(self, coord_id: str, info: dict) -> bool:
        """One throughput sample for the low-performance detector; True
        exactly once per watch when degradation is confirmed."""
        cfg = self.lowperf
        fn = info.get("perf_fn")
        if cfg is None or fn is None or info.get("perf_fired"):
            return False
        try:
            count = float(fn())
        except Exception:                          # noqa: BLE001
            return False
        now = paper_now()
        anchor = info.get("perf_anchor")
        if anchor is None:
            info["perf_anchor"] = (now, count)
            return False
        t0, c0 = anchor
        if now - t0 < cfg.min_window_s:
            return False                           # fold into the next poll
        rate = max(0.0, count - c0) / (now - t0)
        info["perf_anchor"] = (now, count)
        ewma = info.get("perf_ewma")
        ewma = rate if ewma is None else (
            cfg.ewma_alpha * rate + (1.0 - cfg.ewma_alpha) * ewma)
        info["perf_ewma"] = ewma
        reg = registry()
        reg.set_gauge(f"app.throughput:{coord_id}", rate)
        reg.set_gauge(f"app.throughput_ewma:{coord_id}", ewma)
        baseline = info.get("perf_baseline")
        if baseline is None:
            # warmup: the peak observed rate becomes the baseline (a mean
            # would be polluted by a fault landing mid-warmup)
            info["perf_peak"] = max(info["perf_peak"], rate)
            info["perf_warmup"] += 1
            if info["perf_warmup"] >= cfg.warmup_samples \
                    and info["perf_peak"] > 0:
                info["perf_baseline"] = info["perf_peak"]
            return False
        if ewma > baseline:                        # jit warmup can raise the
            info["perf_baseline"] = baseline = ewma    # pace post-warmup
        if ewma < cfg.degradation_factor * baseline:
            info["perf_below"] += 1
        else:
            info["perf_below"] = 0
        if info["perf_below"] >= cfg.grace_polls:
            info["perf_fired"] = True              # once per watch
            return True
        return False

    def _bump_unreachable(self, coord_id: str) -> int:
        with self._lock:
            info = self._watched.get(coord_id)
            if info is None:
                return 0
            info["unreachable_polls"] += 1
            return info["unreachable_polls"]

    def _reset_unreachable(self, coord_id: str) -> None:
        with self._lock:
            info = self._watched.get(coord_id)
            if info is not None:
                info["unreachable_polls"] = 0

    def fleet_unreachable(self, coord_id: str) -> bool:
        """True while the last probes saw *every* VM of this app dark (the
        flag is sticky across unwatch so a post-recovery-failure failover
        decision can still read it; re-watching clears it)."""
        with self._lock:
            return coord_id in self._fleet_down

    def check_once(self, coord_id: str) -> Optional[HealthReport]:
        with self._lock:
            info = self._watched.get(coord_id)
        if info is None:
            return None
        self.heartbeats += 1
        return heartbeat_roundtrip(
            info["vms"], info["hook"],
            straggler_threshold=self.straggler_threshold)
