"""Monitoring Manager (paper §6.3): liveness + application health.

Two mechanisms, mirroring the paper exactly:
  * native failure notifications, where the backend supports them (Snooze) —
    zero polling, immediate recovery;
  * a cloud-agnostic **binary broadcast tree** of per-VM monitoring daemons
    for backends without notifications (OpenStack): the root probes down the
    tree and aggregates health reports up — one round trip costs
    O(log2 n) hops (reproduced in Fig 4c's benchmark).

Health ≠ liveness: each application provides a health hook; the monitor also
derives *performance* health (straggler detection via per-step-time
z-scores) — the paper's "exceptionally low performance ... proactively
suspends the job" feature (§1, use case 3 of §2.2).

Consumers: `core/app_manager.py` subscribes and maps reports onto the
paper's two recovery paths — VM failure → replace + restore from latest
image (§6.3 case 1); application failure → in-place restart (§6.3 case 2).
The broadcast-tree round-trip cost is measured in
`benchmarks/fig4_service_load.py` (Fig 4c).
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.clusters.base import VMHandle
from repro.clusters.simulator import sim_sleep


@dataclasses.dataclass
class HealthReport:
    unreachable: List[str]           # vm ids
    unhealthy: List[str]             # vm ids failing the app health hook
    stragglers: List[str]            # vm ids with degraded performance
    rtt_s: float                     # broadcast-tree round-trip (simulated)

    @property
    def ok(self) -> bool:
        return not (self.unreachable or self.unhealthy)


def tree_depth(n: int) -> int:
    return max(1, math.ceil(math.log2(n + 1)))


def heartbeat_roundtrip(vms: Sequence[VMHandle],
                        health_hook: Optional[Callable[[], bool]] = None,
                        hop_latency_s: float = 0.05,
                        straggler_threshold: float = 3.0) -> HealthReport:
    """One probe/aggregate round over the binary broadcast tree.

    The tree is rooted at vms[0]; node i's children are 2i+1 / 2i+2. The
    probe descends and reports ascend level-by-level, so the critical path
    is 2 * depth hops — each VM is visited once (the paper's evidence that
    the tree "consumes few network resources and scales").
    """
    n = len(vms)
    depth = tree_depth(n)
    sim_sleep(2 * depth * hop_latency_s)          # critical path
    unreachable = [vm.vm_id for vm in vms if not vm.reachable]
    unhealthy: List[str] = []
    if health_hook is not None and not health_hook():
        # the hook is application-scoped; attribute it to the root daemon
        unhealthy.append(vms[0].vm_id if n else "app")
    # performance health: hosts running significantly slower than the
    # fleet's typical pace (median-relative — uniform slowness is the
    # workload, an outlier is a straggler)
    slowdowns = sorted(vm.host.slowdown for vm in vms if vm.reachable)
    stragglers = []
    if len(slowdowns) >= 2:
        median = slowdowns[len(slowdowns) // 2]
        for vm in vms:
            if vm.reachable and vm.host.slowdown > straggler_threshold * median:
                stragglers.append(vm.vm_id)
    return HealthReport(unreachable, unhealthy, stragglers,
                        rtt_s=2 * depth * hop_latency_s)


class MonitoringManager:
    """Watches RUNNING applications; triggers recovery callbacks.

    ``recover_cb(coord_id, kind)`` with kind in {"vm_failure",
    "app_failure", "straggler"} — the Application Manager decides the
    recovery action (paper §6.3's two cases + proactive suspend).
    """

    def __init__(self, recover_cb: Callable[[str, str], None],
                 poll_interval_s: float = 0.05):
        self._recover_cb = recover_cb
        self.poll_interval_s = poll_interval_s
        self._watched: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.heartbeats = 0
        self.native_notifications = 0

    # ---- registration --------------------------------------------------
    def watch(self, coord_id: str, vms: Sequence[VMHandle],
              health_hook: Optional[Callable[[], bool]],
              native_notifications: bool) -> None:
        with self._lock:
            self._watched[coord_id] = {
                "vms": list(vms), "hook": health_hook,
                "native": native_notifications, "suspended_polls": 0,
            }

    def unwatch(self, coord_id: str) -> None:
        with self._lock:
            self._watched.pop(coord_id, None)

    def on_native_failure(self, coord_id: str) -> None:
        """Entry point for backend failure notifications (Snooze path)."""
        self.native_notifications += 1
        self._recover_cb(coord_id, "vm_failure")

    # ---- polling loop (agent-based path) ---------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_interval_s):
            with self._lock:
                watched = dict(self._watched)
            for coord_id, info in watched.items():
                report = self.check_once(coord_id)
                if report is None:
                    continue
                if report.unreachable and not info["native"]:
                    self._recover_cb(coord_id, "vm_failure")
                elif report.unhealthy:
                    self._recover_cb(coord_id, "app_failure")
                elif report.stragglers:
                    self._recover_cb(coord_id, "straggler")

    def check_once(self, coord_id: str) -> Optional[HealthReport]:
        with self._lock:
            info = self._watched.get(coord_id)
        if info is None:
            return None
        self.heartbeats += 1
        return heartbeat_roundtrip(info["vms"], info["hook"])
