"""Coordinator records + lifecycle state machine (paper Fig 2, Table 1).

One coordinator per application, exactly as DMTCP associates one coordinator
per checkpointed computation. We extend the paper's state set with
SUSPENDED (job swapping, use case 2) and RESTARTING (recovery in progress).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.ckpt.plane import DataPlaneConfig
from repro.ckpt.storage import ObjectStore
from repro.clusters.base import VMHandle, VMTemplate
from repro.clusters.simulator import fresh_id


class CoordState(enum.Enum):
    CREATING = "CREATING"
    PROVISIONING = "PROVISIONING"
    READY = "READY"
    RUNNING = "RUNNING"
    SUSPENDED = "SUSPENDED"          # swapped out to stable storage
    RESTARTING = "RESTARTING"
    TERMINATING = "TERMINATING"
    TERMINATED = "TERMINATED"
    ERROR = "ERROR"


# Legal transitions (paper Fig 2 + swapping/recovery extensions).
TRANSITIONS: Dict[CoordState, tuple] = {
    CoordState.CREATING: (CoordState.PROVISIONING, CoordState.ERROR,
                          CoordState.TERMINATING),
    CoordState.PROVISIONING: (CoordState.READY, CoordState.ERROR,
                              CoordState.TERMINATING),
    CoordState.READY: (CoordState.RUNNING, CoordState.ERROR,
                       CoordState.TERMINATING),
    CoordState.RUNNING: (CoordState.SUSPENDED, CoordState.RESTARTING,
                         CoordState.TERMINATING, CoordState.ERROR),
    CoordState.SUSPENDED: (CoordState.RESTARTING, CoordState.TERMINATING,
                           CoordState.ERROR),
    # RESTARTING -> SUSPENDED: a resume aborted before any VM was claimed
    # (capacity raced away) falls back to stable storage, not ERROR.
    CoordState.RESTARTING: (CoordState.RUNNING, CoordState.SUSPENDED,
                            CoordState.ERROR, CoordState.TERMINATING),
    CoordState.TERMINATING: (CoordState.TERMINATED, CoordState.ERROR),
    CoordState.TERMINATED: (),
    CoordState.ERROR: (CoordState.TERMINATING, CoordState.RESTARTING),
}


@dataclasses.dataclass
class CheckpointPolicy:
    period_s: float = 0.0            # 0 = no periodic checkpoints
    codec: str = "raw"
    keep_last: int = 3
    keep_every: int = 0
    store: str = "default"           # named storage backend
    # per-app override of the checkpoint data-plane parallelism (worker
    # counts, in-flight byte cap); None = the CheckpointManager's default
    plane: Optional[DataPlaneConfig] = None


@dataclasses.dataclass
class ASR:
    """Application Submission Request (paper §5.1)."""
    name: str
    n_vms: int
    backend: str                     # cloud backend name
    app_factory: Callable[[], Any]   # () -> Application
    template: VMTemplate = dataclasses.field(default_factory=VMTemplate)
    policy: CheckpointPolicy = dataclasses.field(
        default_factory=CheckpointPolicy)
    priority: int = 0                # higher preempts lower
    provision_cmds: tuple = ()       # user-defined provisioning hooks
    health_hook: Optional[Callable[[], bool]] = None


@dataclasses.dataclass
class Coordinator:
    coord_id: str
    asr: ASR
    state: CoordState = CoordState.CREATING
    vms: List[VMHandle] = dataclasses.field(default_factory=list)
    app: Any = None                          # live Application (not persisted)
    history: List[tuple] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    created_at: float = dataclasses.field(default_factory=time.time)
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    recoveries: int = 0
    lock: threading.RLock = dataclasses.field(default_factory=threading.RLock,
                                              repr=False)

    @property
    def ckpt_prefix(self) -> str:
        return f"apps/{self.coord_id}"

    def to_dict(self) -> Dict[str, Any]:
        return {
            "id": self.coord_id,
            "name": self.asr.name,
            "state": self.state.value,
            "backend": self.asr.backend,
            "n_vms": self.asr.n_vms,
            "vms": [vm.vm_id for vm in self.vms],
            "priority": self.asr.priority,
            "error": self.error,
            "recoveries": self.recoveries,
            "history": [(t, s) for t, s, *_ in self.history],
        }


class CoordinatorDB:
    """Thread-safe coordinator database with ObjectStore persistence.

    The paper keeps it in memory (§6.5) and notes it "could be implemented
    relying on a NoSQL reliable distributed database" (§6.4) — persistence
    to the reliable object store gives managers the same restartability.
    """

    def __init__(self, store: Optional[ObjectStore] = None):
        self._lock = threading.RLock()
        self._coords: Dict[str, Coordinator] = {}
        self._store = store

    def create(self, asr: ASR) -> Coordinator:
        coord = Coordinator(coord_id=fresh_id("coord"), asr=asr)
        coord.history.append((time.time(), coord.state.value))
        with self._lock:
            self._coords[coord.coord_id] = coord
        self._persist(coord)
        return coord

    def get(self, coord_id: str) -> Coordinator:
        with self._lock:
            if coord_id not in self._coords:
                raise KeyError(f"unknown coordinator {coord_id}")
            return self._coords[coord_id]

    def list(self) -> List[Coordinator]:
        with self._lock:
            return list(self._coords.values())

    def remove(self, coord_id: str) -> None:
        with self._lock:
            self._coords.pop(coord_id, None)
        if self._store is not None:
            self._store.delete(f"db/coordinators/{coord_id}.json")

    def transition(self, coord: Coordinator, new: CoordState,
                   reason: str = "") -> None:
        with coord.lock:
            if new not in TRANSITIONS[coord.state]:
                raise InvalidTransition(
                    f"{coord.coord_id}: {coord.state.value} -> {new.value}")
            coord.state = new
            coord.history.append((time.time(), new.value, reason))
        self._persist(coord)

    def _persist(self, coord: Coordinator) -> None:
        if self._store is not None:
            self._store.put(f"db/coordinators/{coord.coord_id}.json",
                            json.dumps(coord.to_dict()).encode())


class InvalidTransition(RuntimeError):
    pass
