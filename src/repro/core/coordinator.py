"""Coordinator records + lifecycle state machine (paper Fig 2, Table 1).

One coordinator per application, exactly as DMTCP associates one coordinator
per checkpointed computation. We extend the paper's state set with
SUSPENDED (job swapping, use case 2) and RESTARTING (recovery in progress).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.ckpt.plane import DataPlaneConfig
from repro.ckpt.storage import ObjectStore
from repro.clusters.base import VMHandle, VMTemplate
from repro.clusters.simulator import fresh_id
from repro.obs.telemetry import registry
from repro.sim.simtime import active_clock


class _CoordMetrics(dict):
    """Coordinator metrics dict with registry write-through.

    Drop-in for the plain dict it replaces (same reads, same
    ``to_dict()`` serialization). Once bound to the job's deterministic
    trace_id (``CoordinatorDB`` binds at create/load), numeric writes are
    mirrored as registry gauges ``coord.<trace_id>.<key>`` so per-job
    RPO/MTTR/queue-wait numbers appear in one telemetry snapshot without
    any new accessor; non-numeric values stay dict-only.
    """

    _label = ""

    def bind(self, label: str) -> "_CoordMetrics":
        self._label = label
        for k, v in self.items():              # back-fill pre-bind writes
            self._mirror(k, v)
        return self

    def _mirror(self, key: str, value: Any) -> None:
        if (self._label and isinstance(value, (int, float))
                and not isinstance(value, bool)):
            registry().set_gauge(f"coord.{self._label}.{key}", float(value))

    def __setitem__(self, key: str, value: Any) -> None:
        super().__setitem__(key, value)
        self._mirror(key, value)

    def setdefault(self, key: str, default: Any = None) -> Any:
        if key not in self:
            self[key] = default
            return default
        return self[key]

    def update(self, *args: Any, **kwargs: Any) -> None:
        for k, v in dict(*args, **kwargs).items():
            self[k] = v


class CoordState(enum.Enum):
    CREATING = "CREATING"
    QUEUED = "QUEUED"                # admitted but waiting for capacity
    PROVISIONING = "PROVISIONING"
    READY = "READY"
    RUNNING = "RUNNING"
    SUSPENDED = "SUSPENDED"          # swapped out to stable storage
    RESTARTING = "RESTARTING"
    TERMINATING = "TERMINATING"
    TERMINATED = "TERMINATED"
    ERROR = "ERROR"


# Legal transitions (paper Fig 2 + swapping/recovery extensions).
TRANSITIONS: Dict[CoordState, tuple] = {
    CoordState.CREATING: (CoordState.QUEUED, CoordState.PROVISIONING,
                          CoordState.ERROR, CoordState.TERMINATING),
    # QUEUED is a persisted record with no resources: the GlobalScheduler
    # owns when its bring-up (-> PROVISIONING) or image restart
    # (-> RESTARTING, for requeued jobs that already hold images) starts,
    # so queued work survives a service restart (paper §6.4).
    CoordState.QUEUED: (CoordState.PROVISIONING, CoordState.RESTARTING,
                        CoordState.ERROR, CoordState.TERMINATING),
    CoordState.PROVISIONING: (CoordState.READY, CoordState.ERROR,
                              CoordState.TERMINATING),
    CoordState.READY: (CoordState.RUNNING, CoordState.ERROR,
                       CoordState.TERMINATING),
    CoordState.RUNNING: (CoordState.SUSPENDED, CoordState.RESTARTING,
                         CoordState.TERMINATING, CoordState.ERROR),
    CoordState.SUSPENDED: (CoordState.RESTARTING, CoordState.TERMINATING,
                           CoordState.ERROR),
    # RESTARTING -> SUSPENDED: a resume aborted before any VM was claimed
    # (capacity raced away) falls back to stable storage, not ERROR.
    CoordState.RESTARTING: (CoordState.RUNNING, CoordState.SUSPENDED,
                            CoordState.ERROR, CoordState.TERMINATING),
    CoordState.TERMINATING: (CoordState.TERMINATED, CoordState.ERROR),
    CoordState.TERMINATED: (),
    # ERROR -> QUEUED: the scheduler requeues a job whose whole cloud died
    # (recovery exhausted at home); it waits for a warm standby or a heal.
    CoordState.ERROR: (CoordState.TERMINATING, CoordState.RESTARTING,
                       CoordState.QUEUED),
}


@dataclasses.dataclass
class CheckpointPolicy:
    period_s: float = 0.0            # 0 = no periodic checkpoints
    codec: str = "raw"
    keep_last: int = 3
    keep_every: int = 0
    store: str = "default"           # named storage backend
    # Codec for *swap-out* images (suspend/preemption). A preempted job's
    # image is written once and read once, so a lossy codec ("int8":
    # device-side qsnap encode, ~4x fewer device-exit bytes) is often
    # acceptable there while periodic images stay lossless for exact
    # restarts. None = use ``codec`` for swap-outs too.
    swap_codec: Optional[str] = None
    # per-app override of the checkpoint data-plane parallelism (worker
    # counts, in-flight byte cap); None = the CheckpointManager's default
    plane: Optional[DataPlaneConfig] = None


@dataclasses.dataclass
class ASR:
    """Application Submission Request (paper §5.1)."""
    name: str
    n_vms: int
    backend: str                     # cloud backend name
    app_factory: Callable[[], Any]   # () -> Application
    template: VMTemplate = dataclasses.field(default_factory=VMTemplate)
    policy: CheckpointPolicy = dataclasses.field(
        default_factory=CheckpointPolicy)
    priority: int = 0                # higher preempts lower
    # backends this job may run on (cloud-spanning placement / backfill
    # stays inside the list); empty = any registered backend. ``backend``
    # above is the *home* cloud — the placement scorer's affinity target.
    clouds: tuple = ()
    provision_cmds: tuple = ()       # user-defined provisioning hooks
    health_hook: Optional[Callable[[], bool]] = None
    # Gang job: the application is an N-rank distributed computation whose
    # snapshots must be gang-consistent (core/gang.py barrier protocol).
    # Placement is all-or-nothing: the scheduler never starts a gang on
    # fewer than min_vms ranks, and only shrinks below n_vms when the job
    # already holds a gang image to reshard from (elastic shrink-restore).
    gang: bool = False
    min_vms: int = 0                 # 0 = full n_vms required
    # What the monitor does when it detects a straggling host (paper use
    # case 3): "suspend" proactively swaps the job out; "ignore" leaves
    # handling to the application — gang jobs often prefer "ignore" so the
    # barrier's own straggler abort isn't raced by a concurrent swap-out.
    straggler_action: str = "suspend"


@dataclasses.dataclass
class Coordinator:
    coord_id: str
    asr: ASR
    state: CoordState = CoordState.CREATING
    vms: List[VMHandle] = dataclasses.field(default_factory=list)
    app: Any = None                          # live Application (not persisted)
    history: List[tuple] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    created_at: float = dataclasses.field(
        default_factory=lambda: active_clock().timestamp())
    metrics: Dict[str, float] = dataclasses.field(
        default_factory=_CoordMetrics)
    recoveries: int = 0
    # Failover targets restore from the *primary's* replicated prefix
    # (core/replication.py): overriding the prefix lets a standby
    # coordinator adopt an already-replicated image lineage with zero
    # chunk copies, and continue appending to it after failover.
    ckpt_prefix_override: Optional[str] = None
    # Seed-lineage adoption for serving-fleet scale-out (serve/fleet.py):
    # unlike ckpt_prefix_override (which rehomes the job's whole lineage),
    # an adopt prefix only redirects *reads while this job's own prefix
    # holds no committed image* — the replica cold-starts from the shared
    # seed image with zero chunk copies, then its own suspend/periodic
    # saves start a private lineage under ckpt_prefix (many replicas can
    # adopt one seed without their saves colliding).
    ckpt_adopt_prefix: Optional[str] = None
    # Per-job trace id threaded through every control-plane record touching
    # this job (scheduler decision_trace rows, chaos outcomes, replication
    # stats) so one gang lifecycle is debuggable from a single grep. It is
    # DETERMINISTIC — derived from the DB's creation sequence, not a uuid —
    # because seeded chaos tests compare traces across replays for
    # bit-for-bit equality.
    trace_id: str = ""
    lock: threading.RLock = dataclasses.field(default_factory=threading.RLock,
                                              repr=False)

    @property
    def ckpt_prefix(self) -> str:
        return self.ckpt_prefix_override or f"apps/{self.coord_id}"

    def to_dict(self) -> Dict[str, Any]:
        return {
            "id": self.coord_id,
            "name": self.asr.name,
            "trace_id": self.trace_id,
            "state": self.state.value,
            "backend": self.asr.backend,
            "n_vms": self.asr.n_vms,
            "gang": self.asr.gang,
            "min_vms": self.asr.min_vms,
            "vms": [vm.vm_id for vm in self.vms],
            "priority": self.asr.priority,
            "clouds": list(self.asr.clouds),
            "error": self.error,
            "recoveries": self.recoveries,
            "history": [(t, s) for t, s, *_ in self.history],
            "ckpt_prefix": self.ckpt_prefix,
            "ckpt_adopt_prefix": self.ckpt_adopt_prefix,
            "policy": {
                "period_s": self.asr.policy.period_s,
                "codec": self.asr.policy.codec,
                "keep_last": self.asr.policy.keep_last,
                "keep_every": self.asr.policy.keep_every,
                "store": self.asr.policy.store,
            },
            "metrics": {k: v for k, v in self.metrics.items()
                        if isinstance(v, (int, float, str))},
        }


def _unrehydratable_app() -> Any:
    raise RuntimeError(
        "coordinator was rehydrated from its persisted record and has no "
        "live application factory (code is not persisted); assign "
        "coord.asr.app_factory before restarting it")


class CoordinatorDB:
    """Thread-safe coordinator database with ObjectStore persistence.

    The paper keeps it in memory (§6.5) and notes it "could be implemented
    relying on a NoSQL reliable distributed database" (§6.4) — persistence
    to the reliable object store gives managers the same restartability:
    ``load()`` is the read path, rehydrating records (sans live app/VMs)
    from ``db/coordinators/*.json`` so a restarted service instance sees
    its coordinators again and can restart them from their images.
    """

    def __init__(self, store: Optional[ObjectStore] = None):
        self._lock = threading.RLock()
        self._coords: Dict[str, Coordinator] = {}
        self._store = store
        self._created = 0            # trace_id sequence (deterministic)

    def load(self) -> List[Coordinator]:
        """Rehydrate persisted coordinator records from the object store.

        Live state (the Application instance, VM handles) is process-bound
        and not persisted — rehydrated coordinators come back with
        ``app=None`` / ``vms=[]`` and an ``app_factory`` placeholder that
        raises until re-attached; their checkpoint images, step history
        and state survive, so ``restart_from`` (after re-attaching a
        factory) resumes them on a fresh cluster. Records already present
        in memory are left untouched. Returns the rehydrated coordinators.
        """
        if self._store is None:
            return []
        loaded: List[Coordinator] = []
        for key in self._store.list("db/coordinators/"):
            d = json.loads(self._store.get(key).decode())
            with self._lock:
                if d["id"] in self._coords:
                    continue
            pol = d.get("policy", {})
            asr = ASR(name=d["name"], n_vms=d["n_vms"], backend=d["backend"],
                      app_factory=_unrehydratable_app,
                      policy=CheckpointPolicy(
                          period_s=pol.get("period_s", 0.0),
                          codec=pol.get("codec", "raw"),
                          keep_last=pol.get("keep_last", 3),
                          keep_every=pol.get("keep_every", 0),
                          store=pol.get("store", "default")),
                      priority=d.get("priority", 0),
                      clouds=tuple(d.get("clouds", ())),
                      gang=d.get("gang", False),
                      min_vms=d.get("min_vms", 0))
            coord = Coordinator(
                coord_id=d["id"], asr=asr,
                state=CoordState(d["state"]),
                history=[(t, s) for t, s in d.get("history", [])],
                error=d.get("error"),
                recoveries=d.get("recoveries", 0),
                metrics=_CoordMetrics(d.get("metrics", {})),
                trace_id=d.get("trace_id", ""))
            coord.metrics.bind(coord.trace_id)
            prefix = d.get("ckpt_prefix")
            if prefix and prefix != f"apps/{coord.coord_id}":
                coord.ckpt_prefix_override = prefix
            coord.ckpt_adopt_prefix = d.get("ckpt_adopt_prefix")
            with self._lock:
                self._coords[coord.coord_id] = coord
            loaded.append(coord)
        return loaded

    def create(self, asr: ASR) -> Coordinator:
        coord = Coordinator(coord_id=fresh_id("coord"), asr=asr)
        coord.history.append((active_clock().timestamp(), coord.state.value))
        with self._lock:
            # trace_id is a pure function of (submission order, job name) so
            # a replayed seeded scenario produces byte-identical traces
            coord.trace_id = f"tr-{asr.name}-{self._created:04d}"
            self._created += 1
            if isinstance(coord.metrics, _CoordMetrics):
                coord.metrics.bind(coord.trace_id)
            self._coords[coord.coord_id] = coord
        self._persist(coord)
        return coord

    def get(self, coord_id: str) -> Coordinator:
        with self._lock:
            if coord_id not in self._coords:
                raise KeyError(f"unknown coordinator {coord_id}")
            return self._coords[coord_id]

    def list(self) -> List[Coordinator]:
        with self._lock:
            return list(self._coords.values())

    def remove(self, coord_id: str) -> None:
        with self._lock:
            self._coords.pop(coord_id, None)
        if self._store is not None:
            self._store.delete(f"db/coordinators/{coord_id}.json")

    def transition(self, coord: Coordinator, new: CoordState,
                   reason: str = "") -> None:
        with coord.lock:
            if new not in TRANSITIONS[coord.state]:
                raise InvalidTransition(
                    f"{coord.coord_id}: {coord.state.value} -> {new.value}")
            coord.state = new
            coord.history.append((active_clock().timestamp(), new.value, reason))
        self._persist(coord)

    def persist(self, coord: Coordinator) -> None:
        """Re-write a coordinator's persisted record outside a transition —
        for metadata that must survive a restart, like the scheduler's
        queue-entry stamp (aging restarts from the persisted wait)."""
        self._persist(coord)

    def _persist(self, coord: Coordinator) -> None:
        if self._store is not None:
            self._store.put(f"db/coordinators/{coord.coord_id}.json",
                            json.dumps(coord.to_dict()).encode())


class InvalidTransition(RuntimeError):
    pass
