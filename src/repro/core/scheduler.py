"""Cloud-spanning over-subscription scheduler (paper use case 2 / §2.2(4)).

The paper's second stated purpose is "the administrative capability to
manage an over-subscribed cloud by temporarily swapping out jobs when
higher priority jobs arrive" — the backfill-lease pattern of Marshall et
al. [MKF11]. One :class:`GlobalScheduler` now spans *every* registered
cloud backend:

  * **placement scorer** — candidate clouds are ranked by home-cloud
    affinity (``ASR.backend``), free capacity, and per-cloud *replication
    warmth* (``replication_stats`` / the cloud store's committed images):
    a cloud already holding the newest fully replicated image of a job
    can resume it with zero chunk re-uploads.
  * **preemptive swap-out** — when a higher-priority job cannot fit, the
    lowest-priority RUNNING jobs are checkpointed to stable storage and
    their VMs released. Preemption is all-or-nothing: if any victim's
    swap-out fails, already-suspended victims are resumed (no stranded
    work).
  * **cross-cloud backfill** — a swapped-out job whose images are fully
    replicated on another cloud resumes there through the PR 4
    prefix-adoption path (`core/replication.py`): the coordinator's home
    backend and checkpoint store are retargeted, the cached async writer
    dropped, and the restore reads only pre-replicated chunks — zero
    re-uploads across the inter-cloud link.
  * **aging anti-starvation** — a job's effective priority grows with its
    queue wait (``aging_rate`` priority units per second on the injected
    clock), so low-priority work eventually outranks — and may preempt —
    long-running higher-priority jobs instead of starving.
  * **queue persistence** — submissions are admitted as persisted QUEUED
    coordinator records (``CoordinatorDB``), so queued and swapped work
    survives a service restart; a fresh scheduler adopts them.

Scheduling passes are **event-driven**: capacity-freed / fault events
from the cluster simulator, submissions, and image-replication
completions all kick the scheduler (a coarse heartbeat only re-evaluates
aging). Every blocking ``suspend`` / ``resume`` / ``submit`` /
``restart_from`` call runs *outside* the scheduler lock — the same
hold-a-lock-across-a-save hazard PR 3 removed from ``Coordinator.suspend``
— and ``lock_held()`` lets tests verify it.

Every decision is appended to a wall-clock-free *decision trace*
(``decision_trace()``): same seed → identical trace across runs, which is
what `tests/test_scheduler_chaos.py` holds it to.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.ckpt.reader import list_steps, load_manifest
from repro.core.coordinator import ASR, Coordinator, CoordState
from repro.obs.trace import tracer
from repro.sim.simtime import active_clock

# per-instance registry namespace (sched1.*, sched2.* …) — creation order,
# never hash order, so metric names replay deterministically in-process
_SCHED_SEQ = itertools.count(1)


class _RegCounter:
    """Scheduler counter stored in the metrics registry.

    Keeps the public attribute contract (``sched.preemptions`` reads as an
    int, supports ``+=`` and assignment) while the value itself lives in
    the registry the instance was created under — ``stats()`` is then a
    thin view over telemetry, not a parallel book. NOTE: disabling that
    registry freezes these counters (the overhead benchmark only disables
    a fresh registry around pure ckpt calls, never around a scheduler).
    """

    def __set_name__(self, owner, name):
        self._name = name

    def _counter(self, obj):
        return obj._obs_reg.counter(f"sched.{obj._obs_tag}.{self._name}")

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        v = self._counter(obj).value
        return int(v) if float(v).is_integer() else v

    def __set__(self, obj, value):
        self._counter(obj).value = value


class WallClock:
    """Default scheduler clock (monotonic wall seconds). Chaos scenarios
    inject :class:`repro.core.chaos.VirtualClock` instead so queue
    timestamps and aging run in TIME_SCALE-compressed virtual seconds and
    replay bit-for-bit.  When a virtual clock is installed process-wide
    (repro.sim), the scheduler defaults to it instead — see
    ``GlobalScheduler.__init__``."""

    def now(self) -> float:
        return time.monotonic()


@dataclasses.dataclass(frozen=True)
class PlacementWeights:
    """Knobs of the placement scorer (higher score wins; ties resolve to
    the home cloud, then stable name order)."""
    affinity: float = 1.0        # the ASR's home backend
    warmth: float = 2.0          # newest image fully replicated there
    free: float = 0.5            # × fraction of the cloud's hosts idle
    preempt_penalty: float = 0.25   # × victims a preemptive placement needs


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One job of a seeded workload trace."""
    name: str
    arrival_s: float             # virtual seconds after trace start
    n_vms: int
    priority: int
    duration_iters: int          # app iterations to completion
    backend: str                 # home cloud (placement affinity)


@dataclasses.dataclass
class WorkloadTrace:
    """Seeded over-subscription workload: same seed → same jobs, always.

    `benchmarks/oversubscription.py` replays one trace through the
    cloud-spanning scheduler and a single-cloud baseline; the property
    suite draws whole traces per hypothesis example."""
    seed: int
    jobs: List[JobSpec]

    @classmethod
    def generate(cls, seed: int, n_jobs: int = 12, *,
                 backends: Tuple[str, ...] = ("cloud",),
                 horizon_s: float = 10.0, max_vms: int = 4,
                 max_priority: int = 9, min_iters: int = 3,
                 max_iters: int = 10) -> "WorkloadTrace":
        rng = random.Random(seed)
        arrivals = sorted(round(rng.uniform(0.0, horizon_s), 3)
                          for _ in range(n_jobs))
        jobs = [JobSpec(name=f"job-{i:03d}", arrival_s=t,
                        n_vms=rng.randint(1, max_vms),
                        priority=rng.randint(0, max_priority),
                        duration_iters=rng.randint(min_iters, max_iters),
                        backend=rng.choice(list(backends)))
                for i, t in enumerate(arrivals)]
        return cls(seed=seed, jobs=jobs)


class GlobalScheduler:
    # decision counters — registry-backed views (see _RegCounter): the
    # attribute reads/writes below behave like plain ints, but the live
    # value sits in the metrics registry under sched.<tag>.<name>
    preemptions = _RegCounter()
    aborted_preemptions = _RegCounter()
    resumes = _RegCounter()
    backfills = _RegCounter()
    backfill_reuploads = _RegCounter()
    requeues = _RegCounter()
    capacity_races = _RegCounter()
    shrinks = _RegCounter()
    tick_errors = _RegCounter()

    def __init__(self, service, *, clock=None,
                 cloud_stores: Optional[Dict[str, str]] = None,
                 aging_rate: float = 0.0, tick_s: float = 0.25,
                 weights: PlacementWeights = PlacementWeights()):
        """``cloud_stores`` maps backend name → the named store
        (``CheckpointManager``) that cloud checkpoints to; placement onto
        a cloud retargets the job's ``CheckpointPolicy.store`` there.
        ``aging_rate`` is effective-priority units per (injected-clock)
        second of queue wait; 0 disables aging."""
        self.service = service
        # explicit clock wins; otherwise the process-wide installed clock
        # (WallClock in production, SimClock under the virtual-time fixture)
        self.clock = clock or active_clock()
        self.cloud_stores = {name: "default"
                             for name in service.cloud.backends()}
        self.cloud_stores.update(cloud_stores or {})
        self.aging_rate = aging_rate
        self.tick_s = tick_s
        self.weights = weights
        self._lock = threading.Lock()      # planning state only — never
        self._held = threading.local()     # held across a blocking call
        self._tick_mutex = threading.Lock()   # one pass at a time
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._tlock = threading.Lock()
        self._seq = 0
        self._trace: List[Tuple] = []
        # capacity reservations for placements dispatched but not yet
        # allocated: bring-ups run concurrently on the app manager's
        # background pool (paper §6.5), so the planner must not hand the
        # same free hosts to two jobs. coord_id -> (backend, n_vms); a
        # reservation stops counting against free capacity the moment the
        # coordinator's VMs are assigned (the backend's own capacity then
        # reflects the claim — counting both would double-book).
        self._rlock = threading.Lock()
        self._reserved: Dict[str, Tuple[str, int]] = {}
        # registry-backed counters (_RegCounter descriptors): bind this
        # instance's namespace before the zeroing assignments below
        from repro.obs.telemetry import registry as _registry
        self._obs_reg = _registry()
        self._obs_tag = f"sched{next(_SCHED_SEQ)}"
        self.preemptions = 0
        self.aborted_preemptions = 0
        self.resumes = 0
        self.backfills = 0               # cross-cloud resumes/restarts
        self.backfill_reuploads = 0      # chunks a backfill had to ship (0!)
        self.requeues = 0                # dead-cloud jobs sent back to queue
        self.capacity_races = 0          # placements aborted back to queue
        self.shrinks = 0                 # gang jobs placed below full size
        self.tick_errors = 0
        self._subscribe()
        self._adopt_existing()

    # ------------------------------------------------------------------
    # lock discipline
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def _locked(self):
        with self._lock:
            self._held.flag = True
            try:
                yield
            finally:
                self._held.flag = False

    def lock_held(self) -> bool:
        """True iff the *calling thread* holds the scheduler lock. Every
        blocking service call the scheduler makes asserts this is False."""
        return getattr(self._held, "flag", False)

    def _assert_unlocked(self) -> None:
        if self.lock_held():
            raise AssertionError(
                "blocking scheduler action attempted under the scheduler "
                "lock (suspend/resume/submit must run outside it)")

    # ------------------------------------------------------------------
    # event wiring
    # ------------------------------------------------------------------
    def _subscribe(self) -> None:
        for backend in self.service.cloud.backends().values():
            sim = getattr(backend, "sim", None)
            if sim is None:
                continue
            if hasattr(sim, "on_capacity"):
                sim.on_capacity(lambda: self.kick("capacity"))
            if hasattr(sim, "on_fault"):
                sim.on_fault(lambda *_: self.kick("fault"))
            if hasattr(sim, "on_allocation"):
                sim.on_allocation(lambda owner, n: self._mark_allocated(owner))
        rep = getattr(self.service, "replicator", None)
        if rep is not None and hasattr(rep, "on_replicated"):
            rep.on_replicated(lambda *_: self.kick("replicated"))

    def _adopt_existing(self) -> None:
        """Adopt rehydrated / pre-existing QUEUED and SUSPENDED records
        into the queue (service restart: the persisted queue comes back
        through ``CoordinatorDB.load``)."""
        now = self.clock.now()
        for coord in self.service.db.list():
            if coord.state in (CoordState.QUEUED, CoordState.SUSPENDED):
                coord.metrics.setdefault("queued_at_v", now)

    def kick(self, reason: str = "") -> None:
        """Request a scheduling pass (non-blocking; safe from any
        thread/callback). Capacity events, faults, submissions and
        replication completions all land here."""
        self._wake.set()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="gsched")
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            # event-driven: woken by capacity/fault/submit/replication
            # events; tick_s is only the aging-re-evaluation heartbeat
            active_clock().wait(self._wake, self.tick_s)
            self._wake.clear()
            if self._stop.is_set():
                return
            try:
                self.tick()
            except Exception:                  # noqa: BLE001
                self.tick_errors += 1

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, asr: ASR, *,
               adopt_prefix: Optional[str] = None) -> str:
        """Admit a job: a persisted QUEUED coordinator record is created
        immediately (it survives restarts) and a scheduling pass decides
        when and *where* it actually starts. Returns the coord_id; poll
        its state (QUEUED until placed).

        ``adopt_prefix`` sets the job's checkpoint *read* adoption before
        the first scheduling pass can race it: a serving-fleet replica
        submitted against a seed lineage restores that shared image on
        cold start (zero re-uploads) while its own saves stay private —
        see ``Coordinator.ckpt_adopt_prefix``."""
        coord = self.service.apps.enqueue(asr)
        if adopt_prefix:
            coord.ckpt_adopt_prefix = adopt_prefix
        coord.metrics["queued_at_v"] = self.clock.now()
        self.service.db.persist(coord)
        self._record("submit", coord, asr.backend)
        self.nudge("submit")
        return coord.coord_id

    def nudge(self, reason: str = "") -> None:
        """Request a pass the way submit() does: synchronous tick when no
        loop thread is running (tests/tools), event kick otherwise. For
        external queue mutations — e.g. a FleetController unparking a
        suspended replica."""
        if self._thread is None:
            self.tick()
        else:
            self.kick(reason)

    # ------------------------------------------------------------------
    # scheduling pass
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One scheduling pass: order the queue under the lock (pure
        in-memory state — store I/O and every blocking call run outside
        it), dispatch each decision, repeat until nothing places.
        Placements of different jobs run concurrently on the app
        manager's pool behind capacity reservations; preemptive swap-outs
        run synchronously here (their all-or-nothing rollback needs to
        finish before the beneficiary starts). Returns the number of
        actions dispatched."""
        with tracer().span("sched/tick", cat="sched") as sp:
            done = self._tick_inner()
            sp.set("actions", done)
        return done

    def _tick_inner(self) -> int:
        done = 0
        with self._tick_mutex:
            while True:
                with self._locked():
                    requeue, waiting = self._plan()
                action = requeue
                if action is None:
                    for c in waiting:      # placement reads stores: outside
                        action = self._place(c)      # the scheduler lock
                        if action is not None:
                            break
                if action is None:
                    return done
                if not self._execute(action):
                    return done            # blocked/raced: retry next pass
                done += 1

    def effective_priority(self, coord: Coordinator) -> int:
        """A waiter's priority: base + accrued queue-wait aging."""
        base = coord.asr.priority
        queued_at = coord.metrics.get("queued_at_v")
        if queued_at is None or self.aging_rate <= 0:
            return base
        wait = max(0.0, self.clock.now() - queued_at)
        return base + int(self.aging_rate * wait)

    def defense_priority(self, coord: Coordinator) -> int:
        """A runner's priority against preemption: base + the age credit
        it held when it was placed. Without the credit, an aged-up job
        that finally won capacity would be preempted right back by the
        higher-base-priority job it outranked — aging would be
        self-defeating. With ``aging_rate == 0`` this is just the base."""
        return coord.asr.priority + int(coord.metrics.get("prio_boost", 0))

    def _plan(self) -> Tuple[Optional[Dict[str, Any]], List[Coordinator]]:
        """Queue bookkeeping + ordering (in-memory only, runs under the
        scheduler lock): returns a requeue action (dead-cloud job) or the
        effective-priority-ordered waiting list for placement."""
        coords = self.service.db.list()
        now = self.clock.now()
        for c in coords:
            # adopt monitor-suspended (straggler) and rehydrated work
            if c.state in (CoordState.QUEUED, CoordState.SUSPENDED):
                c.metrics.setdefault("queued_at_v", now)
        for c in coords:
            if c.state == CoordState.ERROR and self._cloud_dead(c):
                return {"op": "requeue", "coord": c}, []
        with self._rlock:
            inflight = set(self._reserved)
        # fleet-parked replicas (scale-in suspends, serve/fleet.py) are
        # deliberately swapped out to hand their hosts to batch work —
        # auto-resuming them here would undo the reclaim; only their
        # FleetController unparks them (clearing the flag) on scale-out
        waiting = [c for c in coords
                   if c.state in (CoordState.QUEUED, CoordState.SUSPENDED)
                   and c.coord_id not in inflight
                   and not (c.state == CoordState.SUSPENDED
                            and c.metrics.get("fleet_parked"))]
        waiting.sort(key=lambda c: (-self.effective_priority(c),
                                    c.metrics.get("queued_at_v", 0.0),
                                    c.asr.name, c.coord_id))
        return None, waiting

    def _cloud_dead(self, coord: Coordinator) -> bool:
        """Conclusive home-cloud loss for a managed job — the
        FailoverController trigger adapted in-service: ERROR (recovery
        exhausted at home), the old fleet fully dark, zero spare
        capacity. Requeued jobs wait for a warm standby or a heal."""
        if coord.vms and any(vm.reachable for vm in coord.vms):
            return False
        try:
            if self.service.cloud.capacity(coord.asr.backend) > 0:
                return False               # the home cloud can still recover
        except Exception:                  # noqa: BLE001
            pass                           # unreachable backend == down
        if coord.vms and not self.service.apps.monitor.fleet_unreachable(
                coord.coord_id):
            return False                   # e.g. ERROR from an app bug
        return True

    # ---- placement -----------------------------------------------------
    def _allowed(self, asr: ASR) -> List[str]:
        names = [n for n in self.service.cloud.backends()
                 if not asr.clouds or n in asr.clouds]
        names.sort(key=lambda n: (n != asr.backend, n))   # home first
        return names

    def _home_latest(self, coord: Coordinator) -> Optional[int]:
        try:
            return self.service.ckpt.latest(coord)
        except Exception:                  # noqa: BLE001
            return None                    # home store unreachable

    def _read_prefix(self, coord: Coordinator, store) -> str:
        """The prefix a restore on ``store`` would read: the job's own
        prefix when it holds images there, else its adopt prefix (fleet
        replicas restoring a replicated seed lineage on another cloud
        pass the zero-re-upload gate through the seed's replicas)."""
        adopt = coord.ckpt_adopt_prefix
        if adopt and not list_steps(store, coord.ckpt_prefix):
            return adopt
        return coord.ckpt_prefix

    def _warm_step(self, coord: Coordinator, backend: str) -> Optional[int]:
        """Newest step COMMITTED in ``backend``'s store under this job's
        read prefix — what a resume there could restore without any
        upload."""
        try:
            store = self.service.ckpt.store(
                self.cloud_stores.get(backend, "default"))
            steps = list_steps(store, self._read_prefix(coord, store))
        except Exception:                  # noqa: BLE001
            return None
        return steps[-1] if steps else None

    def _replication_warmth(self, coord: Coordinator) -> Dict[str, float]:
        """backend → warmth in [0, 1] from the attached replicator's
        ``replication_stats`` (lag_images == 0 → fully warm; a partial
        replica scores half — resumable only after the backlog drains)."""
        rep = getattr(self.service, "replicator", None)
        if rep is None:
            return {}
        try:
            stats = self.service.replication_stats(coord.coord_id)
        except Exception:                  # noqa: BLE001
            return {}
        out: Dict[str, float] = {}
        for name, t in (stats.get("targets") or {}).items():
            try:
                backend = rep.target(name).backend
            except Exception:              # noqa: BLE001
                backend = None
            if backend:
                out[backend] = (1.0 if t.get("lag_images") == 0
                                else 0.5 if t.get("last_step") is not None
                                else 0.0)
        return out

    def _mark_allocated(self, coord_id: str) -> None:
        """Allocation-claim event (``ClusterSim.on_allocation``): the
        backend's capacity counters now carry this job's hosts, so its
        reservation must stop counting — keeping both would double-book
        the hosts for the whole simulated boot."""
        with self._rlock:
            entry = self._reserved.get(coord_id)
            if entry is not None:
                self._reserved[coord_id] = (entry[0], 0)

    def _free(self, backend: str) -> int:
        try:
            free = self.service.cloud.capacity(backend)
        except Exception:                  # noqa: BLE001
            return 0
        with self._rlock:
            pending = [(cid, n) for cid, (b, n) in self._reserved.items()
                       if b == backend and n > 0]
        for cid, n in pending:
            try:
                coord = self.service.db.get(cid)
            except KeyError:
                continue
            # belt-and-braces for backends without allocation events:
            # once the bring-up has assigned vms, capacity() already
            # accounts for them
            if not coord.vms:
                free -= n
        return max(0, free)

    def _score(self, coord: Coordinator, backend: str, free: int,
               warmth: Dict[str, float], n_victims: int = 0) -> float:
        w = self.weights
        b = self.service.cloud.backend(backend)
        sim = getattr(b, "sim", None)
        total = sim.n_hosts if sim is not None else max(free, 1)
        score = w.free * (free / max(1, total))
        if backend == coord.asr.backend:
            score += w.affinity + w.warmth   # home store holds the lineage
        else:
            score += w.warmth * warmth.get(backend, 0.0)
        return score - w.preempt_penalty * n_victims

    def _place(self, coord: Coordinator) -> Optional[Dict[str, Any]]:
        """Best placement for one waiting job, or None.

        Jobs holding images (SUSPENDED, or QUEUED after a requeue) may
        only go to their home cloud or a cloud whose store holds the
        newest image fully replicated — the zero-re-upload invariant.
        Free-capacity fits are preferred; otherwise the cheapest
        all-or-nothing preemption of strictly-lower-priority work wins
        (waiters attack with their *aged* priority, runners defend with
        ``defense_priority`` — base plus the age credit they were placed
        with; that asymmetry is the anti-starvation)."""
        asr = coord.asr
        home_latest = self._home_latest(coord)
        needs_image = (coord.state == CoordState.SUSPENDED
                       or home_latest is not None)
        warmth = self._replication_warmth(coord) if needs_image else {}
        mode = ("resume" if coord.state == CoordState.SUSPENDED
                else "restart" if needs_image else "fresh")
        candidates: List[Tuple[float, int, str]] = []   # (score, i, name)
        preemptive: List[Tuple[int, float, int, str, List]] = []
        eff = self.effective_priority(coord)
        for i, name in enumerate(self._allowed(asr)):
            if needs_image and name != asr.backend:
                warm = self._warm_step(coord, name)
                if warm is None or (home_latest is not None
                                    and warm < home_latest):
                    continue               # not fully replicated: no backfill
            free = self._free(name)
            if free >= asr.n_vms:
                candidates.append(
                    (self._score(coord, name, free, warmth), -i, name))
                continue
            victims = self._pick_victims(coord, name, free, eff)
            if victims is not None:
                preemptive.append(
                    (len(victims),
                     -self._score(coord, name, free, warmth, len(victims)),
                     i, name, victims))
        if candidates:
            candidates.sort(reverse=True)
            return {"op": "place", "coord": coord, "mode": mode,
                    "backend": candidates[0][2]}
        if preemptive:
            preemptive.sort()              # fewest victims, best score
            _, _, _, name, victims = preemptive[0]
            return {"op": "place", "coord": coord, "mode": mode,
                    "backend": name, "victims": victims}
        # Gang elastic shrink: a gang job that holds a committed gang
        # image can reshard onto fewer ranks than it ran with, so when
        # nothing fits at full size it may claim a smaller free block —
        # but never below min_vms (0 = shrink disabled: full n_vms or
        # nothing), and never without an image (a fresh gang start is
        # all-or-nothing at n_vms).
        if asr.gang and needs_image and 0 < asr.min_vms < asr.n_vms:
            floor = asr.min_vms
            shrunk: List[Tuple[float, int, int, str]] = []
            for i, name in enumerate(self._allowed(asr)):
                if needs_image and name != asr.backend:
                    warm = self._warm_step(coord, name)
                    if warm is None or (home_latest is not None
                                        and warm < home_latest):
                        continue           # zero-re-upload gate still holds
                free = self._free(name)
                if floor <= free < asr.n_vms:
                    shrunk.append((self._score(coord, name, free, warmth),
                                   free, -i, name))
            if shrunk:
                shrunk.sort(reverse=True)
                return {"op": "place", "coord": coord, "mode": mode,
                        "backend": shrunk[0][3], "n_vms": shrunk[0][1]}
        return None

    def _pick_victims(self, coord: Coordinator, backend: str, free: int,
                      eff: int) -> Optional[List[Coordinator]]:
        """Lowest-priority RUNNING jobs on ``backend`` whose (base)
        priority is strictly below the waiter's effective priority, until
        the job fits — or None when even preempting all of them would not
        free enough hosts (then nothing is preempted at all)."""
        running = [c for c in self.service.db.list()
                   if c.state == CoordState.RUNNING
                   and c.asr.backend == backend
                   and self.defense_priority(c) < eff
                   and c.coord_id != coord.coord_id]
        running.sort(key=lambda c: (self.defense_priority(c), c.asr.name,
                                    c.coord_id))
        victims: List[Coordinator] = []
        for c in running:
            if free >= coord.asr.n_vms:
                break
            victims.append(c)
            free += len(c.vms)
        return victims if free >= coord.asr.n_vms else None

    # ------------------------------------------------------------------
    # execution (every blocking call lives below — outside the lock)
    # ------------------------------------------------------------------
    def _execute(self, action: Dict[str, Any]) -> bool:
        self._assert_unlocked()
        try:
            if action["op"] == "requeue":
                return self._exec_requeue(action["coord"])
            victims = action.get("victims")
            if victims and not self._exec_preempt(action["coord"], victims):
                return False
            return self._exec_place(action["coord"], action["backend"],
                                    action["mode"],
                                    n_vms=action.get("n_vms"))
        except Exception:                  # noqa: BLE001
            self._count("tick_errors")
            return False

    def _exec_requeue(self, coord: Coordinator) -> bool:
        self._assert_unlocked()
        # take ownership FIRST: only strip the VM handles once the
        # transition has succeeded under the lock — a concurrent
        # restart_from/terminate that won the record must find its
        # handles intact
        with coord.lock:
            if coord.state != CoordState.ERROR:
                return False
            vms, coord.vms = coord.vms, []
            coord.metrics["queued_at_v"] = self.clock.now()
            self.service.db.transition(coord, CoordState.QUEUED,
                                       "requeue:cloud-dead")
        if vms:
            try:                           # release the dead fleet's handles
                self.service.cloud.destroy_cluster(coord.asr.backend, vms)
            except Exception:              # noqa: BLE001
                pass                       # the cloud is down; best-effort
        self._count("requeues")
        self._record("requeue", coord, coord.asr.backend)
        return True

    def _exec_preempt(self, coord: Coordinator,
                      victims: List[Coordinator]) -> bool:
        """All-or-nothing swap-out: if any victim's suspend fails, the
        already-suspended victims are resumed — a failed preemption must
        not strand work on stable storage with its capacity gone."""
        self._assert_unlocked()
        done: List[Coordinator] = []
        now = self.clock.now()
        try:
            for v in victims:
                self.service.apps.suspend(
                    v.coord_id, reason=f"preempted:{coord.asr.name}")
                self._stamp_queued(v, now)
                done.append(v)
                self._count("preemptions")
                self._record("preempt", v, v.asr.backend, coord.asr.name)
        except Exception:                  # noqa: BLE001
            for v in done:
                try:
                    self.service.apps.resume(v.coord_id, block=True)
                except Exception:          # noqa: BLE001
                    pass                   # stays SUSPENDED; queued for later
            self._count("aborted_preemptions")
            self._record("preempt_abort", coord, "",
                         ",".join(v.asr.name for v in victims))
            return False
        return True

    def _exec_place(self, coord: Coordinator, backend: str,
                    mode: str, n_vms: Optional[int] = None) -> bool:
        """Dispatch one placement. The decision (retarget, reservation,
        trace entry) is taken here in planning order — deterministic —
        while the blocking bring-up/restore runs on the app manager's
        background pool, so placements of different jobs overlap."""
        self._assert_unlocked()
        # lock in the age credit this placement was won with (see
        # defense_priority); overwritten on every placement, never stacked
        coord.metrics["prio_boost"] = max(
            0, self.effective_priority(coord) - coord.asr.priority)
        cross = backend != coord.asr.backend
        # remembered for rollback: a cross placement that loses the
        # capacity race must return home, or the job is silently rehomed
        prev = (coord.asr.backend, coord.asr.policy.store)
        if cross:
            if mode in ("resume", "restart"):
                reuploads = self._missing_chunks(coord, backend)
                coord.metrics["backfill_reuploads"] = reuploads
                self._count("backfill_reuploads", reuploads)
            self._retarget(coord, backend)
        op = ("backfill" if cross and mode != "fresh"
              else {"fresh": "start", "resume": "resume",
                    "restart": "restart"}[mode])
        self._record(op, coord, backend)
        if n_vms is not None and n_vms < coord.asr.n_vms:
            # elastic gang shrink: remember the full size (a later grow
            # pass can restore it), then place at the surviving count —
            # restart_from/resume allocate coord.asr.n_vms, so the
            # override must land before the reservation and dispatch
            coord.metrics.setdefault("gang_full_vms", coord.asr.n_vms)
            coord.asr.n_vms = n_vms
            self._count("shrinks")
            self._record("shrink", coord, backend,
                         f"{n_vms}/{coord.metrics['gang_full_vms']}")
        with self._rlock:
            self._reserved[coord.coord_id] = (backend, coord.asr.n_vms)

        def run() -> None:
            try:
                if mode == "fresh":
                    self._finish_start(coord, backend)
                elif mode == "resume":
                    self._finish_resume(coord, cross, prev)
                else:
                    self._finish_restart(coord, cross, prev)
            except Exception:              # noqa: BLE001
                self._count("tick_errors")
            finally:
                with self._rlock:
                    self._reserved.pop(coord.coord_id, None)
                self.kick("placed")

        self.service.apps.pool.submit(run)
        return True

    def _finish_start(self, coord: Coordinator, backend: str) -> None:
        try:
            self.service.apps.start_queued(coord.coord_id, block=True)
        except RuntimeError:
            return                         # state raced (e.g. terminated)
        if coord.state == CoordState.ERROR:
            if "CapacityError" in (coord.error or ""):
                # capacity raced away between plan and claim: back to the
                # queue (keeping its original wait stamp would double-age)
                with coord.lock:
                    if coord.state == CoordState.ERROR:
                        self.service.db.transition(
                            coord, CoordState.QUEUED, "capacity race")
                self._stamp_queued(coord)
                self._count("capacity_races")
            else:
                self._record("start_failed", coord, backend)

    def _finish_resume(self, coord: Coordinator, cross: bool,
                       prev: Tuple[str, str]) -> None:
        try:
            self.service.apps.resume(coord.coord_id, block=True)
        except RuntimeError:
            self._rollback_retarget(coord, cross, prev)
            return
        if coord.state == CoordState.SUSPENDED:
            self._rollback_retarget(coord, cross, prev)
            self._count("capacity_races")  # fell back to stable storage
            return
        if coord.state != CoordState.RUNNING:
            return
        self._count("resumes")
        if cross:
            self._count("backfills")

    def _finish_restart(self, coord: Coordinator, cross: bool,
                        prev: Tuple[str, str]) -> None:
        try:
            self.service.apps.restart_from(coord.coord_id)
        except Exception as e:             # noqa: BLE001
            # restart_from raises on allocation races; the job still
            # holds its images — park it SUSPENDED for a later pass
            with coord.lock:
                if coord.state == CoordState.RESTARTING:
                    self.service.db.transition(
                        coord, CoordState.SUSPENDED,
                        f"restart aborted: {type(e).__name__}")
            self._rollback_retarget(coord, cross, prev)
            self._stamp_queued(coord)
            self._count("capacity_races")
            return
        if coord.state != CoordState.RUNNING:
            return
        self._count("resumes")
        if cross:
            self._count("backfills")

    def _count(self, counter: str, n: int = 1) -> None:
        with self._rlock:
            setattr(self, counter, getattr(self, counter) + n)

    def _stamp_queued(self, coord: Coordinator,
                      now: Optional[float] = None) -> None:
        """(Re-)stamp a job's queue-entry time AND persist the record —
        aging must resume from the accrued wait after a service restart,
        not from zero."""
        coord.metrics["queued_at_v"] = (self.clock.now()
                                        if now is None else now)
        try:
            self.service.db.persist(coord)
        except Exception:                  # noqa: BLE001
            pass                           # persistence store unreachable

    def _retarget(self, coord: Coordinator, backend: str,
                  store: Optional[str] = None) -> None:
        """Move a coordinator's home to another cloud: swap the ASR's
        backend and checkpoint store to the target's and drop the cached
        async writer (bound to the old store). The checkpoint prefix is
        unchanged — the restore adopts the replica the ImageReplicator
        already committed there (PR 4's prefix adoption), and
        post-backfill saves continue the lineage on the new store."""
        self.service.ckpt.detach(coord.coord_id)
        coord.asr.backend = backend
        coord.asr.policy.store = (store if store is not None
                                  else self.cloud_stores.get(backend,
                                                             "default"))

    def _rollback_retarget(self, coord: Coordinator, cross: bool,
                           prev: Tuple[str, str]) -> None:
        """Undo a cross-cloud retarget whose placement failed: the job
        returns home (original backend + store), so the eventual retry
        re-evaluates placement — and counts as a backfill — correctly."""
        if cross:
            self._retarget(coord, prev[0], store=prev[1])

    def _missing_chunks(self, coord: Coordinator, backend: str) -> int:
        """Chunks of the newest replicated image NOT already present in
        the target cloud's store — what a backfill would have to ship
        across the inter-cloud link (0 == the pure replica-hit path)."""
        try:
            store = self.service.ckpt.store(
                self.cloud_stores.get(backend, "default"))
            prefix = self._read_prefix(coord, store)
            steps = list_steps(store, prefix)
            if not steps:
                return 0
            man = load_manifest(store, prefix, steps[-1])
        except Exception:                  # noqa: BLE001
            return 0
        keys = {c.key for li in man.leaves.values() for c in li.chunks}
        return sum(1 for k in keys if not store.exists(k))

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def _record(self, op: str, coord: Coordinator, backend: str,
                detail: str = "") -> None:
        with self._tlock:
            self._seq += 1
            seq = self._seq
            self._trace.append((seq, op, coord.asr.name, backend,
                                detail, coord.trace_id))
        # mirror each decision into the span tracer so a job's placement
        # correlates with its ckpt/monitor spans by trace_id; the local
        # tuple list above stays the replay-exact source of truth for
        # decision_trace() (the tracer has a drop cap, this list doesn't)
        tracer().event(f"sched/{op}", cat="sched", trace_id=coord.trace_id,
                       args={"seq": seq, "job": coord.asr.name,
                             "backend": backend, "detail": detail})

    def decision_trace(self) -> List[Tuple]:
        """Wall-clock-free decision log: (seq, op, job name, backend,
        detail, trace_id). Two runs of the same seeded scenario must
        produce the same trace — the determinism contract; trace_id is
        derived from the DB creation sequence, so it replays too."""
        with self._tlock:
            return list(self._trace)

    @property
    def queue_depth(self) -> int:
        """QUEUED records not yet dispatched (in-flight bring-ups are no
        longer waiting — they hold a capacity reservation)."""
        with self._rlock:
            inflight = set(self._reserved)
        return sum(1 for c in self.service.db.list()
                   if c.state == CoordState.QUEUED
                   and c.coord_id not in inflight)

    @property
    def inflight_depth(self) -> int:
        """Placements dispatched but not yet completed."""
        with self._rlock:
            return len(self._reserved)

    def stats(self) -> Dict[str, Any]:
        return {
            "queue_depth": self.queue_depth,
            "preemptions": self.preemptions,
            "aborted_preemptions": self.aborted_preemptions,
            "resumes": self.resumes,
            "backfills": self.backfills,
            "backfill_reuploads": self.backfill_reuploads,
            "requeues": self.requeues,
            "capacity_races": self.capacity_races,
            "shrinks": self.shrinks,
            "tick_errors": self.tick_errors,
        }
