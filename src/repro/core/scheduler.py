"""Priority scheduler with job swapping (paper use case 2 / §2.2(4)).

Manages an over-subscribed cloud: when a higher-priority job arrives and
capacity is insufficient, the lowest-priority RUNNING jobs are *swapped out*
(checkpointed to stable storage, VMs released). When capacity frees, the
highest-priority SUSPENDED/queued work resumes — the backfill-lease pattern
of Marshall et al. [MKF11] that the paper cites.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.coordinator import ASR, CoordState
from repro.core.service import CACSService


class PriorityScheduler:
    def __init__(self, service: CACSService, backend: str,
                 tick_s: float = 0.05):
        self.service = service
        self.backend = backend
        self.tick_s = tick_s
        self._queue: List[Tuple[int, float, ASR]] = []   # (prio, t, asr)
        self._queued_ids: Dict[str, ASR] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.preemptions = 0
        self.resumes = 0
        self.capacity_races = 0          # resumes aborted back to SUSPENDED

    # ------------------------------------------------------------------
    def submit(self, asr: ASR) -> Optional[str]:
        """Submit respecting priorities. Returns coord_id if started now,
        None if queued (a later tick will start it)."""
        with self._lock:
            if self._try_make_room(asr):
                return self.service.submit(asr)
            self._queue.append((asr.priority, time.monotonic(), asr))
            self._queue.sort(key=lambda t: (-t[0], t[1]))
            return None

    def _capacity(self) -> int:
        return self.service.cloud.capacity(self.backend)

    def _try_make_room(self, asr: ASR) -> bool:
        """True if asr can start now, preempting lower-priority jobs if
        needed (and only if that actually frees enough hosts)."""
        free = self._capacity()
        if free >= asr.n_vms:
            return True
        # candidates: RUNNING jobs with strictly lower priority, lowest first
        running = [c for c in self.service.db.list()
                   if c.state == CoordState.RUNNING
                   and c.asr.priority < asr.priority
                   and c.asr.backend == self.backend]
        running.sort(key=lambda c: c.asr.priority)
        victims = []
        for c in running:
            if free >= asr.n_vms:
                break
            victims.append(c)
            free += len(c.vms)
        if free < asr.n_vms:
            return False
        for c in victims:
            try:
                self.service.apps.suspend(c.coord_id, reason="preempted")
                self.preemptions += 1
            except RuntimeError:
                return False
        return True

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.tick_s):
            self.tick()

    def tick(self) -> None:
        """One scheduling pass: start queued work, resume suspended work."""
        with self._lock:
            # queued submissions first (highest priority first); blocking
            # submits serialize capacity claims (no double-start races)
            still_queued = []
            for prio, t, asr in self._queue:
                if self._capacity() >= asr.n_vms:
                    self.service.submit(asr, block=True)
                else:
                    still_queued.append((prio, t, asr))
            self._queue = still_queued
            # resume suspended jobs, highest priority first
            suspended = [c for c in self.service.db.list()
                         if c.state == CoordState.SUSPENDED
                         and c.asr.backend == self.backend]
            suspended.sort(key=lambda c: -c.asr.priority)
            for c in suspended:
                if self._capacity() >= c.asr.n_vms:
                    # don't resume over queued higher-priority work
                    if any(q[0] > c.asr.priority for q in self._queue):
                        continue
                    try:
                        self.service.apps.resume(c.coord_id, block=True)
                        if c.state == CoordState.SUSPENDED:
                            # capacity raced away mid-resume: the app fell
                            # back to stable storage; a later tick retries
                            self.capacity_races += 1
                        else:
                            self.resumes += 1
                    except RuntimeError:
                        pass

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)
