"""Cross-cloud migration, cloning and cloudification (paper §5.3, §7.3).

All three scenarios are compositions of the same three REST calls the paper
uses: POST /coordinators (create), POST .../checkpoints (upload image),
POST .../checkpoints/:id (restart) — applied across *two service instances*
running on different cloud backends:

  * ``clone``    — copy a checkpoint image to another cloud and start a
                   second instance there (source keeps running);
  * ``migrate``  — clone + terminate the source (paper's migration);
  * ``cloudify`` — migrate from the Local ("desktop") backend to a cloud
                   (paper §7.3.1's NS-3 scenario).

Because checkpoint images are topology-agnostic (repro.ckpt.layout), the
destination may use a different VM count / mesh shape — the JAX analogue of
migrating between heterogeneous clouds. The paper demonstrated this
Snooze→OpenStack (§7.3.2, Table 3); here any two `clusters/` backends work,
and `examples/cloud_migration.py` is the §7.3 scenario end-to-end.

Image transfer goes through CheckpointManager.upload_image, which resolves
chunks via the source manifest and dedups on ingest (content-addressed
chunks the destination already holds are not re-uploaded) — repeated
migrations of a slowly-changing job cost only the delta, the same economics
docs/architecture.md describes for the write path. The transfer itself runs
on the destination service's parallel data plane (DataPlaneConfig
upload_workers concurrent chunk copies), so the ``transfer_s`` term of
MigrationResult — the dominant cost of cross-cloud migration in the paper's
Table 3 — scales with stream count on latency/bandwidth-bound links.

When an ImageReplicator (core/replication.py) has been keeping the
destination cloud warm, migration upgrades further: upload_image sources
every already-replicated chunk from the destination-side replica, so the
inter-cloud link carries only the unreplicated delta and ``transfer_s``
collapses (benchmarks/replication.py measures cold vs warm side by side).

Failure containment: a clone/migrate that dies mid-flight (upload fault,
destination never reaching RUNNING) must leave the *source untouched* and
must not leak the half-created destination coordinator — the destination
record is torn down before the error propagates, and ``migrate`` only
terminates the source after the clone has fully succeeded.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core.coordinator import ASR, CoordState
from repro.core.service import CACSService


@dataclasses.dataclass
class MigrationResult:
    src_id: str
    dst_id: str
    step: int
    checkpoint_s: float
    transfer_s: float
    restart_s: float

    @property
    def total_s(self) -> float:
        return self.checkpoint_s + self.transfer_s + self.restart_s


def clone(src: CACSService, coord_id: str, dst: CACSService, *,
          backend: str, n_vms: Optional[int] = None,
          step: Optional[int] = None, fresh_checkpoint: bool = True,
          ) -> MigrationResult:
    """Clone a running application onto another cloud (paper §5.3 case 2)."""
    src_coord = src.db.get(coord_id)

    t0 = time.monotonic()
    if fresh_checkpoint:
        step = src.trigger_checkpoint(coord_id, blocking=True)
    elif step is None:
        step = src.ckpt.latest(src_coord)
        if step is None:
            raise RuntimeError(f"{coord_id} has no checkpoint to clone from")
    t1 = time.monotonic()

    # 1. POST /coordinators on the destination (do not auto-start the app:
    #    submission here creates the record; bring-up happens at restart).
    new_asr = dataclasses.replace(
        src_coord.asr, backend=backend,
        n_vms=n_vms if n_vms is not None else src_coord.asr.n_vms)
    dst_coord = dst.db.create(new_asr)

    try:
        # 2. POST .../checkpoints — upload the image (n chunk objects).
        src_store = src.ckpt.store(src_coord.asr.policy.store)
        dst.upload_checkpoint(dst_coord.coord_id, src_store,
                              src_coord.ckpt_prefix, step)
        t2 = time.monotonic()

        # 3. POST .../checkpoints/:id — restart on the destination cloud.
        #    Passive recovery allocates + provisions the new virtual cluster.
        dst.restart_from(dst_coord.coord_id, step)
        dst.wait_for_state(dst_coord.coord_id, CoordState.RUNNING, timeout=60)
        t3 = time.monotonic()
    except BaseException:
        # The clone failed mid-flight. The source keeps running untouched
        # (its image is still committed in its own store); the half-created
        # destination coordinator — record, any uploaded chunks, any VMs a
        # partial restart claimed — must not leak.
        _cleanup_failed_clone(dst, dst_coord.coord_id)
        raise

    return MigrationResult(
        src_id=coord_id, dst_id=dst_coord.coord_id, step=step,
        checkpoint_s=t1 - t0, transfer_s=t2 - t1, restart_s=t3 - t2)


def _cleanup_failed_clone(dst: CACSService, dst_id: str) -> None:
    """Tear down the destination side of a failed clone, never masking the
    original error (cleanup failures are swallowed: the record may already
    be gone, or the destination store may itself be the faulty party)."""
    try:
        dst.delete_coordinator(dst_id)
    except Exception:                          # noqa: BLE001
        try:
            dst.db.remove(dst_id)              # at least drop the record
        except Exception:                      # noqa: BLE001
            pass


def migrate(src: CACSService, coord_id: str, dst: CACSService, *,
            backend: str, n_vms: Optional[int] = None) -> MigrationResult:
    """Migration = clone + terminate on the source cloud (paper §5.3).

    The source is only terminated after the destination is verifiably
    RUNNING — a clone that fails at any point propagates its error with
    the source still running and the destination cleaned up, so a failed
    migration never strands the job."""
    result = clone(src, coord_id, dst, backend=backend, n_vms=n_vms)
    src.delete_coordinator(coord_id)
    return result


def cloudify(local: CACSService, coord_id: str, cloud: CACSService, *,
             backend: str, n_vms: int) -> MigrationResult:
    """Desktop -> cloud migration (paper §7.3.1). The app's libraries travel
    inside the checkpoint image, so the destination needs no preinstall."""
    return migrate(local, coord_id, cloud, backend=backend, n_vms=n_vms)
