"""Cloud Manager: cloud-agnostic virtual-cluster management (paper §4.2).

Holds a registry of named ``ClusterBackend``s and creates/destroys virtual
clusters on any of them through one API — the portability boundary the paper
demonstrates with Snooze + OpenStack.
"""
from __future__ import annotations

import threading
from typing import Dict, List

from repro.clusters.base import ClusterBackend, VMHandle, VMTemplate


class CloudManager:
    def __init__(self, backends: Dict[str, ClusterBackend]):
        self._backends = dict(backends)
        self._lock = threading.Lock()

    def backend(self, name: str) -> ClusterBackend:
        if name not in self._backends:
            raise KeyError(f"unknown cloud backend {name!r}; "
                           f"have {sorted(self._backends)}")
        return self._backends[name]

    def backends(self) -> Dict[str, ClusterBackend]:
        return dict(self._backends)

    def register(self, name: str, backend: ClusterBackend) -> None:
        with self._lock:
            self._backends[name] = backend

    def create_cluster(self, backend_name: str, n_vms: int,
                       template: VMTemplate, owner: str) -> List[VMHandle]:
        return self.backend(backend_name).allocate_vms(n_vms, template, owner)

    def destroy_cluster(self, backend_name: str,
                        vms: List[VMHandle]) -> None:
        live = [vm for vm in vms if vm.state.value != "terminated"]
        if live:
            self.backend(backend_name).terminate_vms(live)

    def replace_failed(self, backend_name: str, vms: List[VMHandle],
                       template: VMTemplate, owner: str) -> List[VMHandle]:
        """Passive recovery (paper §5.3): swap unreachable VMs for fresh ones."""
        backend = self.backend(backend_name)
        healthy = [vm for vm in vms if vm.reachable]
        dead = [vm for vm in vms if not vm.reachable]
        if not dead:
            return vms
        backend.terminate_vms(dead)
        fresh = backend.allocate_vms(len(dead), template, owner)
        return healthy + fresh

    def capacity(self, backend_name: str) -> int:
        return self.backend(backend_name).capacity()
