"""Checkpoint Manager (paper §6.2): application-image lifecycle over
pluggable storage backends.

Stateless by design: "The Checkpoint Manager is not aware of the existence
of checkpoint images until a restart is required. At that time [it] will
choose the most recent checkpoint image by default, but a user may also
specify an earlier image." — reproduced verbatim: all queries go to the
store's committed manifests; nothing is cached in the manager.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from repro.ckpt import gc as ckpt_gc
from repro.ckpt.gang import GangCheckpointer, load_gang_ranks
from repro.ckpt.plane import DataPlaneConfig, shared_executor
from repro.ckpt.reader import (latest_step, list_steps, load_manifest,
                               restore)
from repro.ckpt.storage import ObjectStore
from repro.ckpt.writer import AsyncCheckpointer, save_checkpoint
from repro.core.coordinator import CheckpointPolicy, Coordinator


class CheckpointManager:
    def __init__(self, stores: Dict[str, ObjectStore],
                 plane: Optional[DataPlaneConfig] = None):
        self._stores = dict(stores)
        self._async: Dict[str, AsyncCheckpointer] = {}
        self._gangs: Dict[str, GangCheckpointer] = {}
        self._lock = threading.Lock()
        # service-wide default for the parallel checkpoint data plane;
        # CheckpointPolicy.plane overrides per application
        self.plane = plane or DataPlaneConfig()

    def _plane_for(self, coord: Coordinator) -> DataPlaneConfig:
        return getattr(coord.asr.policy, "plane", None) or self.plane

    def store(self, name: str = "default") -> ObjectStore:
        if name not in self._stores:
            raise KeyError(f"unknown store {name!r}; have {sorted(self._stores)}")
        return self._stores[name]

    def register_store(self, name: str, store: ObjectStore) -> None:
        with self._lock:
            self._stores[name] = store

    # ---- save ----------------------------------------------------------
    def save(self, coord: Coordinator, step: int, state: Any, *,
             blocking: bool = True,
             metadata: Optional[Dict[str, Any]] = None,
             codec: Optional[str] = None) -> None:
        """Save ``state`` — a materialized pytree or a SnapshotHandle.

        A handle is resolved on the coordinator's writer thread (both
        blocking and async paths), so the device→host copy never runs on
        the caller — ``checkpoint_now``/``suspend`` hold the app stalled
        only for the microsecond capture. ``codec`` overrides the
        policy's image codec for this save (suspend passes
        ``policy.swap_codec``).
        """
        pol = coord.asr.policy
        store = self.store(pol.store)
        save_codec = codec or pol.codec
        meta = {"app": coord.asr.name, **(metadata or {})}

        def run_gc(_step=None):
            if pol.keep_last:
                # Invalidate writer-side dedup caches for whatever the sweep
                # reaps. The async writer's own commits already prune its
                # caches (writer._absorb), but interleaved *blocking* saves
                # can age the async writer's last manifest out of the keep
                # window — at which point its cached digests point at
                # sweepable chunks.
                with self._lock:
                    ck = self._async.get(coord.coord_id)
                ckpt_gc.collect(store, coord.ckpt_prefix,
                                keep_last=pol.keep_last,
                                keep_every=pol.keep_every,
                                on_swept=(None if ck is None
                                          else ck.invalidate))

        if blocking:
            def _save_and_gc():
                save_checkpoint(store, coord.ckpt_prefix, step, state,
                                codec=save_codec, metadata=meta,
                                plane=self._plane_for(coord),
                                trace_id=getattr(coord, "trace_id", ""))
                run_gc()
            # Run the blocking save + GC on the coordinator's writer
            # thread (creating it if needed — checking for an existing one
            # would be TOCTOU against a concurrent async save creating
            # it), after any in-flight async save. Otherwise this GC's
            # sweep_orphans could reap chunks an in-flight save has put
            # but not yet committed — committing a manifest that
            # references reaped keys (the invariant delete_image already
            # serializes the same way).
            self._checkpointer(coord).run_serialized(_save_and_gc)
        else:
            # GC must run post-commit, or it would count the in-flight step
            ck = self._checkpointer(coord)
            ck.save(step, state, metadata=meta, on_commit=run_gc,
                    codec=None if save_codec == ck.codec else save_codec)

    def _checkpointer(self, coord: Coordinator) -> AsyncCheckpointer:
        with self._lock:
            if coord.coord_id not in self._async:
                pol = coord.asr.policy
                self._async[coord.coord_id] = AsyncCheckpointer(
                    self.store(pol.store), coord.ckpt_prefix, codec=pol.codec,
                    plane=self._plane_for(coord),
                    trace_id=getattr(coord, "trace_id", ""))
            return self._async[coord.coord_id]

    # ---- gang images (core/gang.py barrier protocol) -------------------
    def save_gang(self, coord: Coordinator, step: int, rank_trees: List[Any],
                  *, sharded: Dict[str, int],
                  routed: Optional[Dict[str, Dict[str, Any]]] = None,
                  metadata: Optional[Dict[str, Any]] = None) -> Any:
        """Commit one all-or-nothing gang image (called from inside the
        barrier's SAVE phase — blocking by construction: the ranks stay
        quiesced until every chunk joined and the marker is durable).
        Raises without side effects beyond orphan chunks on any rank's
        storage fault; the barrier turns that into an epoch abort."""
        pol = coord.asr.policy
        store = self.store(pol.store)
        ck = self._gang_checkpointer(coord)
        meta = {"app": coord.asr.name, "trace_id": coord.trace_id,
                **(metadata or {})}
        manifest = ck.save(step, rank_trees, sharded=sharded, routed=routed,
                           metadata=meta)
        if pol.keep_last:
            ckpt_gc.collect(store, coord.ckpt_prefix, keep_last=pol.keep_last,
                            keep_every=pol.keep_every, on_swept=ck.invalidate)
        return manifest

    def load_gang(self, coord: Coordinator, step: Optional[int] = None, *,
                  n_ranks: Optional[int] = None) -> Any:
        """(per-rank trees, manifest, fetch stats) resharded onto
        ``n_ranks`` — the restore half of elastic shrink/grow."""
        return load_gang_ranks(self.store(coord.asr.policy.store),
                               coord.ckpt_prefix, step, n_ranks,
                               plane=self._plane_for(coord))

    def _gang_checkpointer(self, coord: Coordinator) -> GangCheckpointer:
        with self._lock:
            ck = self._gangs.get(coord.coord_id)
            if ck is None:
                pol = coord.asr.policy
                ck = GangCheckpointer(self.store(pol.store),
                                      coord.ckpt_prefix, codec=pol.codec,
                                      plane=self._plane_for(coord))
                self._gangs[coord.coord_id] = ck
            return ck

    def detach(self, coord_id: str) -> None:
        """Forget the coordinator's cached async writer, draining any
        in-flight save first. Required when a coordinator is *retargeted*
        to a different store (cross-cloud backfill adopts the replicated
        prefix on another cloud's store): the cached writer is bound to
        the old store and would commit post-resume saves to the wrong
        cloud."""
        with self._lock:
            ck = self._async.pop(coord_id, None)
            self._gangs.pop(coord_id, None)  # gang writers are synchronous
        if ck is not None:                   # (barrier-held): drop is safe
            # drain without raising: a failed in-flight save is already
            # consumed by the suspend/recovery path; detaching only needs
            # quiescence before the writer is rebound to the new store
            ck.wait(raise_error=False)
            ck.close()

    def wait(self, coord: Coordinator, strict: bool = True):
        """Join any in-flight async save. strict=False swallows a failed
        save (returning the exception): the recovery/terminate paths only
        need quiescence — the newest COMMITTED image is still intact, the
        torn step is invisible, and its orphan chunks are swept by GC."""
        with self._lock:
            ck = self._async.get(coord.coord_id)
        if ck is None:
            return None
        if strict:
            ck.wait()
            return None
        try:
            ck.wait()
        except Exception as e:                     # noqa: BLE001
            return e
        return None

    # ---- query / restore -------------------------------------------------
    def list_images(self, coord: Coordinator) -> List[int]:
        return list_steps(self.store(coord.asr.policy.store),
                          self.read_prefix(coord))

    def image_info(self, coord: Coordinator, step: int) -> Dict[str, Any]:
        man = load_manifest(self.store(coord.asr.policy.store),
                            coord.ckpt_prefix, step)
        nbytes = sum(c.nbytes for li in man.leaves.values()
                     for c in li.chunks)
        return {"step": man.step, "codec": man.codec, "bytes": nbytes,
                "format_version": man.version,
                "dedup": man.metadata.get("dedup"),
                "leaves": len(man.leaves), "metadata": man.metadata}

    def dedup_stats(self, coord: Coordinator) -> Dict[str, int]:
        """Cumulative incremental-checkpointing counters for one app:
        store-level dedup hits/misses plus the async writer's cache hits
        (which never reach the store). bytes_deduped / (bytes_written +
        bytes_deduped) is the fraction of image bytes incrementality saved."""
        out = dict(self.store(coord.asr.policy.store).dedup_stats())
        with self._lock:
            ck = self._async.get(coord.coord_id)
        if ck is not None:
            out.update({f"writer_{k}": v for k, v in ck.stats().items()})
        return out

    def read_prefix(self, coord: Coordinator,
                    store: Optional[ObjectStore] = None) -> str:
        """The prefix restores should read: the coordinator's own prefix
        once it holds a committed image, else its ``ckpt_adopt_prefix``
        (serving-fleet scale-out: a fresh replica cold-starts from the
        shared seed lineage — pure CAS reads, zero chunk copies — while
        its own saves open a private lineage under ``ckpt_prefix``).
        Writes, GC and delete paths NEVER use this: they stay on the own
        prefix, so terminating a replica can't reap the seed image.
        getattr: tests drive this manager with duck-typed coordinator
        stand-ins that predate the adoption field."""
        adopt = getattr(coord, "ckpt_adopt_prefix", "")
        if not adopt:
            return coord.ckpt_prefix
        store = store if store is not None \
            else self.store(coord.asr.policy.store)
        if latest_step(store, coord.ckpt_prefix) is not None:
            return coord.ckpt_prefix
        return adopt

    def latest(self, coord: Coordinator) -> Optional[int]:
        return latest_step(self.store(coord.asr.policy.store),
                           self.read_prefix(coord))

    def load(self, coord: Coordinator, step: Optional[int] = None, *,
             shardings: Any = None, target: Any = None) -> Any:
        tree, _ = restore(self.store(coord.asr.policy.store),
                          self.read_prefix(coord), step,
                          target=target, shardings=shardings,
                          plane=self._plane_for(coord),
                          trace_id=getattr(coord, "trace_id", ""))
        return tree

    # ---- upload (migration ingest; paper §5.3 "upload a checkpoint") ----
    def upload_image(self, coord: Coordinator, src_store: ObjectStore,
                     src_prefix: str, step: int) -> None:
        """Copy a committed image from another service's store (clone).

        Chunks are resolved through the source *manifest* (content-addressed
        chunks live outside the step directory), rewritten onto this app's
        prefix, and deduped on ingest: chunks the destination already holds
        (e.g. from an earlier clone of the same lineage) are not re-uploaded.

        Warm path: when the ImageReplicator (core/replication.py) has
        already shipped a chunk to the destination side — it lives in the
        destination store under the *source* prefix — the copy is sourced
        from that local replica instead of crossing the inter-cloud link
        again (counted in ``replica_hits``/``replica_bytes_local``).
        Cross-cloud transfer then moves only the unreplicated delta.

        The per-chunk copies are independent, so they run on the parallel
        data plane's upload streams — cross-cloud transfer (the dominant
        term of migration, paper Table 3) overlaps source gets with
        destination puts. The commit protocol is the writer's: every chunk
        durable, then manifest, flush, COMMITTED.
        """
        from repro.ckpt.layout import MANIFEST, step_prefix
        from repro.ckpt.reader import load_manifest as _load
        dst = self.store(coord.asr.policy.store)
        man = _load(src_store, src_prefix, step)
        dst_sp = step_prefix(coord.ckpt_prefix, step)

        def copy_chunk(c) -> None:
            new_key = coord.ckpt_prefix + c.key[len(src_prefix):]
            if dst.exists(new_key):          # ingest dedup: count, skip the
                dst.count_ingest_hit(c.nbytes)  # source read entirely
                return
            if dst is not src_store and dst.exists(c.key):
                # warm migration: a replica of this chunk is already on
                # the destination side — copy store-locally, not across
                # the inter-cloud link. The replica may vanish between the
                # exists check and the read (the replicator mirrors
                # primary GC pruning concurrently); fall back to the
                # cross-cloud source rather than failing the clone.
                try:
                    data = dst.get(c.key)
                except (KeyError, FileNotFoundError):
                    data = None
                if data is not None:
                    dst.count_replica_hit(c.nbytes)
                    dst.put_if_absent(new_key, data)
                    return
            dst.put_if_absent(new_key, src_store.get(c.key))

        unique = {c.key: c for li in man.leaves.values()
                  for c in li.chunks}
        workers = max(1, self._plane_for(coord).upload_workers)
        if workers == 1 or len(unique) <= 1:
            for c in unique.values():
                copy_chunk(c)
        else:
            ex = shared_executor("up", workers)
            for fut in [ex.submit(copy_chunk, c) for c in unique.values()]:
                fut.result()                 # join: all chunks durable
        manifest_json = man.to_json().replace(src_prefix, coord.ckpt_prefix)
        dst.put(f"{dst_sp}/{MANIFEST}", manifest_json.encode())
        dst.flush()
        dst.put(f"{dst_sp}/COMMITTED", b"1")
        dst.flush()                          # marker durable, like writer.py

    def delete_image(self, coord: Coordinator, step: int) -> None:
        from repro.ckpt.layout import step_prefix
        store = self.store(coord.asr.policy.store)
        with self._lock:
            ck = self._async.get(coord.coord_id)
            gck = self._gangs.get(coord.coord_id)

        def _delete():
            store.delete_prefix(step_prefix(coord.ckpt_prefix, step))
            # chunks may be shared with surviving steps — sweep, don't
            # prefix-delete
            swept = ckpt_gc.sweep_orphans(store, coord.ckpt_prefix)
            if swept:
                if ck is not None:
                    ck.invalidate(swept)  # a stale dedup hit would commit a
                if gck is not None:       # manifest pointing at reaped chunks
                    gck.invalidate(swept)
        if ck is not None:
            # serialize with in-flight saves: sweeping concurrently could
            # reap chunks a save has put but not yet committed
            ck.run_serialized(_delete)
        else:
            _delete()

    def delete_all(self, coord: Coordinator) -> None:
        with self._lock:
            ck = self._async.pop(coord.coord_id, None)
            self._gangs.pop(coord.coord_id, None)
        if ck is not None:
            ck.close()                   # drain in-flight save first, or it
        self.store(coord.asr.policy.store).delete_prefix(coord.ckpt_prefix)
        # would re-create keys under the prefix after the delete
