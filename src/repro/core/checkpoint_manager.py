"""Checkpoint Manager (paper §6.2): application-image lifecycle over
pluggable storage backends.

Stateless by design: "The Checkpoint Manager is not aware of the existence
of checkpoint images until a restart is required. At that time [it] will
choose the most recent checkpoint image by default, but a user may also
specify an earlier image." — reproduced verbatim: all queries go to the
store's committed manifests; nothing is cached in the manager.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from repro.ckpt import gc as ckpt_gc
from repro.ckpt.reader import (latest_step, list_steps, load_manifest,
                               restore)
from repro.ckpt.storage import ObjectStore
from repro.ckpt.writer import AsyncCheckpointer, save_checkpoint
from repro.core.coordinator import CheckpointPolicy, Coordinator


class CheckpointManager:
    def __init__(self, stores: Dict[str, ObjectStore]):
        self._stores = dict(stores)
        self._async: Dict[str, AsyncCheckpointer] = {}
        self._lock = threading.Lock()

    def store(self, name: str = "default") -> ObjectStore:
        if name not in self._stores:
            raise KeyError(f"unknown store {name!r}; have {sorted(self._stores)}")
        return self._stores[name]

    def register_store(self, name: str, store: ObjectStore) -> None:
        with self._lock:
            self._stores[name] = store

    # ---- save ----------------------------------------------------------
    def save(self, coord: Coordinator, step: int, state: Any, *,
             blocking: bool = True,
             metadata: Optional[Dict[str, Any]] = None) -> None:
        pol = coord.asr.policy
        store = self.store(pol.store)
        meta = {"app": coord.asr.name, **(metadata or {})}

        def run_gc(_step=None):
            if pol.keep_last:
                ckpt_gc.collect(store, coord.ckpt_prefix,
                                keep_last=pol.keep_last,
                                keep_every=pol.keep_every)
                # Writer-side dedup caches are pruned to the latest manifest
                # after each commit (writer._absorb), so nothing referencing
                # a swept chunk can survive in them; no invalidation needed.

        if blocking:
            save_checkpoint(store, coord.ckpt_prefix, step, state,
                            codec=pol.codec, metadata=meta)
            run_gc()
        else:
            # GC must run post-commit, or it would count the in-flight step
            ck = self._checkpointer(coord)
            ck.save(step, state, metadata=meta, on_commit=run_gc)

    def _checkpointer(self, coord: Coordinator) -> AsyncCheckpointer:
        with self._lock:
            if coord.coord_id not in self._async:
                pol = coord.asr.policy
                self._async[coord.coord_id] = AsyncCheckpointer(
                    self.store(pol.store), coord.ckpt_prefix, codec=pol.codec)
            return self._async[coord.coord_id]

    def wait(self, coord: Coordinator) -> None:
        with self._lock:
            ck = self._async.get(coord.coord_id)
        if ck is not None:
            ck.wait()

    # ---- query / restore -------------------------------------------------
    def list_images(self, coord: Coordinator) -> List[int]:
        return list_steps(self.store(coord.asr.policy.store),
                          coord.ckpt_prefix)

    def image_info(self, coord: Coordinator, step: int) -> Dict[str, Any]:
        man = load_manifest(self.store(coord.asr.policy.store),
                            coord.ckpt_prefix, step)
        nbytes = sum(c.nbytes for li in man.leaves.values()
                     for c in li.chunks)
        return {"step": man.step, "codec": man.codec, "bytes": nbytes,
                "format_version": man.version,
                "dedup": man.metadata.get("dedup"),
                "leaves": len(man.leaves), "metadata": man.metadata}

    def dedup_stats(self, coord: Coordinator) -> Dict[str, int]:
        """Cumulative incremental-checkpointing counters for one app:
        store-level dedup hits/misses plus the async writer's cache hits
        (which never reach the store). bytes_deduped / (bytes_written +
        bytes_deduped) is the fraction of image bytes incrementality saved."""
        out = dict(self.store(coord.asr.policy.store).dedup_stats())
        with self._lock:
            ck = self._async.get(coord.coord_id)
        if ck is not None:
            out.update({f"writer_{k}": v for k, v in ck.stats().items()})
        return out

    def latest(self, coord: Coordinator) -> Optional[int]:
        return latest_step(self.store(coord.asr.policy.store),
                           coord.ckpt_prefix)

    def load(self, coord: Coordinator, step: Optional[int] = None, *,
             shardings: Any = None, target: Any = None) -> Any:
        tree, _ = restore(self.store(coord.asr.policy.store),
                          coord.ckpt_prefix, step,
                          target=target, shardings=shardings)
        return tree

    # ---- upload (migration ingest; paper §5.3 "upload a checkpoint") ----
    def upload_image(self, coord: Coordinator, src_store: ObjectStore,
                     src_prefix: str, step: int) -> None:
        """Copy a committed image from another service's store (clone).

        Chunks are resolved through the source *manifest* (content-addressed
        chunks live outside the step directory), rewritten onto this app's
        prefix, and deduped on ingest: chunks the destination already holds
        (e.g. from an earlier clone of the same lineage) are not re-uploaded.
        """
        from repro.ckpt.layout import MANIFEST, step_prefix
        from repro.ckpt.reader import load_manifest as _load
        dst = self.store(coord.asr.policy.store)
        man = _load(src_store, src_prefix, step)
        dst_sp = step_prefix(coord.ckpt_prefix, step)
        seen = set()
        for li in man.leaves.values():
            for c in li.chunks:
                if c.key in seen:
                    continue
                seen.add(c.key)
                new_key = coord.ckpt_prefix + c.key[len(src_prefix):]
                if dst.exists(new_key):      # ingest dedup: count, skip the
                    dst.dedup_hits += 1      # source read entirely
                    dst.dedup_bytes_skipped += c.nbytes
                    continue
                dst.put_if_absent(new_key, src_store.get(c.key))
        manifest_json = man.to_json().replace(src_prefix, coord.ckpt_prefix)
        dst.put(f"{dst_sp}/{MANIFEST}", manifest_json.encode())
        dst.flush()
        dst.put(f"{dst_sp}/COMMITTED", b"1")
        dst.flush()                          # marker durable, like writer.py

    def delete_image(self, coord: Coordinator, step: int) -> None:
        from repro.ckpt.layout import step_prefix
        store = self.store(coord.asr.policy.store)
        with self._lock:
            ck = self._async.get(coord.coord_id)

        def _delete():
            store.delete_prefix(step_prefix(coord.ckpt_prefix, step))
            # chunks may be shared with surviving steps — sweep, don't
            # prefix-delete
            swept = ckpt_gc.sweep_orphans(store, coord.ckpt_prefix)
            if ck is not None and swept:
                ck.invalidate(swept)     # a stale dedup hit would commit a
        if ck is not None:               # manifest pointing at reaped chunks
            # serialize with in-flight saves: sweeping concurrently could
            # reap chunks a save has put but not yet committed
            ck.run_serialized(_delete)
        else:
            _delete()

    def delete_all(self, coord: Coordinator) -> None:
        with self._lock:
            ck = self._async.pop(coord.coord_id, None)
        if ck is not None:
            ck.close()                   # drain in-flight save first, or it
        self.store(coord.asr.policy.store).delete_prefix(coord.ckpt_prefix)
        # would re-create keys under the prefix after the delete
