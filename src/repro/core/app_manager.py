"""Application Manager (paper §4.2): orchestrates the coordinator lifecycle.

Owns the bring-up pipeline (allocate -> provision -> start), the periodic
checkpoint daemon, and all recovery paths:
  * VM failure  -> passive recovery: replace unreachable VMs, restore from
                   the latest image, restart (paper §6.3 case 1);
  * app failure -> in-place restart on the same VMs (paper §6.3 case 2 —
                   "as an optimization");
  * straggler   -> proactive suspend to stable storage (paper §1: "detects
                   ... exceptionally low performance ... and proactively
                   suspends the job"); the scheduler resumes it later.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import traceback
from typing import Any, Callable, Dict, Optional

from repro.clusters.base import SimBackend
from repro.clusters.simulator import CapacityError
from repro.core.application import AppContext, snapshot_of
from repro.core.checkpoint_manager import CheckpointManager
from repro.core.cloud_manager import CloudManager
from repro.obs.telemetry import registry
from repro.obs.trace import tracer
from repro.sim.simtime import active_clock
from repro.core.coordinator import (ASR, Coordinator, CoordinatorDB,
                                    CoordState, InvalidTransition)
from repro.core.gang import GANG_ROUTED, GANG_SHARDED, GangCoordinator
from repro.core.monitoring import LowPerfConfig, MonitoringManager
from repro.core.provision import ProvisionManager


def progress_counter(app: Any) -> Optional[Callable[[], float]]:
    """Monotonic progress counter for the monitor's throughput gauge:
    Trainer steps, Serve tokens, gang min-iteration, SimulatedApp
    iterations — falling back to ``progress()`` when nothing better
    exists. None when the app exposes no usable counter."""
    for attr in ("current_step", "generated", "iteration"):
        if hasattr(app, attr):
            def fn(a=app, name=attr) -> float:
                v = getattr(a, name)
                return float(v() if callable(v) else v)
            return fn
    if hasattr(app, "min_iteration"):
        return lambda: float(app.min_iteration())
    if hasattr(app, "progress"):
        return lambda: float(app.progress())
    return None


class AppManager:
    def __init__(self, db: CoordinatorDB, cloud: CloudManager,
                 provision: ProvisionManager, ckpt: CheckpointManager,
                 workers: int = 100, recover_retries: int = 2,
                 retry_backoff_s: float = 0.02,
                 lowperf: Optional[LowPerfConfig] = None):
        self.db = db
        self.cloud = cloud
        self.provision = provision
        self.ckpt = ckpt
        # "users requests are mostly treated in background using a pool of
        # threads" (§6.5) — sized for the paper's 100-concurrent-apps test.
        self.pool = cf.ThreadPoolExecutor(max_workers=workers,
                                          thread_name_prefix="appmgr")
        self.monitor = MonitoringManager(self._on_monitor_event,
                                         lowperf=lowperf)
        self._ckpt_daemon_stop = threading.Event()
        self._ckpt_daemon: Optional[threading.Thread] = None
        self._next_ckpt: Dict[str, float] = {}
        self._step_counter: Dict[str, int] = {}
        # At most one recovery/suspend action in flight per coordinator:
        # the monitor re-reports a fault every poll tick (~50 ms) for as
        # long as it persists, and duplicate submissions used to race into
        # RuntimeError tracebacks inside _guarded.
        self._inflight_ops: Dict[str, cf.Future] = {}
        self._inflight_lock = threading.Lock()
        self.events_deduped = 0
        # transient-fault tolerance on the restore path (chaos: a storage
        # get error mid-recovery should cost a retry, not an ERROR state)
        self.recover_retries = recover_retries
        self.retry_backoff_s = retry_backoff_s
        # per-coordinator gang barrier drivers (core/gang.py), kept across
        # restarts so epoch/abort counters and armed chaos hooks survive
        # a recovery — rebound to the restarted app at each use
        self._gangs: Dict[str, GangCoordinator] = {}

    # ------------------------------------------------------------------
    # Submission (paper §5.1)
    # ------------------------------------------------------------------
    def submit(self, asr: ASR, block: bool = False) -> Coordinator:
        coord = self.db.create(asr)
        fut = self.pool.submit(self._bringup, coord)
        if block:
            fut.result()
        return coord

    def enqueue(self, asr: ASR) -> Coordinator:
        """Admit a job without starting it: the record is created and
        parked in QUEUED (persisted — queued work survives a service
        restart), holding no resources until a scheduler calls
        ``start_queued`` (fresh bring-up) or ``restart_from`` (requeued
        jobs that already hold images)."""
        coord = self.db.create(asr)
        self.db.transition(coord, CoordState.QUEUED, "queued")
        return coord

    def start_queued(self, coord_id: str, block: bool = True) -> Coordinator:
        """Begin the bring-up of a QUEUED coordinator (allocate →
        provision → start). Capacity races surface as an ERROR record
        whose error names CapacityError; the scheduler requeues those."""
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.QUEUED:
                raise RuntimeError(
                    f"cannot start queued job in state {coord.state.value}")
        fut = self.pool.submit(self._bringup, coord)
        if block:
            fut.result()
        return coord

    def _provision_cost(self, backend_name: str):
        backend = self.cloud.backend(backend_name)
        return {"cost": backend.sim.cost} if isinstance(backend, SimBackend) \
            else {}

    def _bringup_infra(self, coord: Coordinator) -> None:
        """CREATING -> PROVISIONING -> READY (allocate + provision)."""
        asr = coord.asr
        vms = self.cloud.create_cluster(asr.backend, asr.n_vms,
                                        asr.template, coord.coord_id)
        coord.vms = vms
        self.db.transition(coord, CoordState.PROVISIONING)
        self.provision.provision(vms, asr.provision_cmds,
                                 **self._provision_cost(asr.backend))
        self.db.transition(coord, CoordState.READY)

    def _bringup(self, coord: Coordinator,
                 restore_state: Any = None) -> None:
        try:
            self._bringup_infra(coord)
            self._start_app(coord, restore_state)
        except Exception as e:                     # noqa: BLE001
            coord.error = f"{e}\n{traceback.format_exc()}"
            try:
                self.db.transition(coord, CoordState.ERROR, str(e))
            except Exception:
                pass

    def _start_app(self, coord: Coordinator, restore_state: Any) -> bool:
        asr = coord.asr
        if coord.app is None:
            coord.app = asr.app_factory()
        backend = self.cloud.backend(asr.backend)
        ctx = AppContext(coord.coord_id, coord.vms, service=None)
        # gang apps exchange messages over the backend's simulated fabric;
        # handing it through the context keeps Application signature-stable
        ctx.transport = getattr(backend, "sim", None)
        coord.app.start(ctx, restore_state)
        try:
            self.db.transition(coord, CoordState.RUNNING)
        except InvalidTransition:
            # terminate() raced the bring-up/recovery: stop quietly and let
            # the terminating thread (which joins us) release the resources
            coord.app.stop()
            return False
        native = backend.supports_failure_notifications
        hook = asr.health_hook or (lambda: coord.app.healthy())
        self.monitor.watch(coord.coord_id, coord.vms, hook, native,
                           perf_fn=progress_counter(coord.app),
                           trace_id=coord.trace_id)
        if asr.policy.period_s > 0:
            clk = active_clock()
            self._next_ckpt[coord.coord_id] = (
                clk.now() + clk.from_wall(asr.policy.period_s))
        return True

    # ------------------------------------------------------------------
    # Gang jobs (core/gang.py): barrier driver plumbing
    # ------------------------------------------------------------------
    def gang(self, coord_id: str) -> Optional[GangCoordinator]:
        """The job's barrier driver (tests arm chaos hooks through it)."""
        return self._gangs.get(coord_id)

    def _gang(self, coord: Coordinator) -> GangCoordinator:
        transport = getattr(self.cloud.backend(coord.asr.backend), "sim",
                            None)

        def save_fn(step, trees):
            return self.ckpt.save_gang(coord, step, trees,
                                       sharded=GANG_SHARDED,
                                       routed=GANG_ROUTED)

        g = self._gangs.get(coord.coord_id)
        if g is None:
            g = GangCoordinator(coord.app, transport, save_fn,
                                trace_id=coord.trace_id)
            self._gangs[coord.coord_id] = g
        else:
            # the app instance / backend may have changed across a
            # recovery or cross-cloud retarget — repoint, keep counters
            g.rebind(coord.app, transport)
            g.save_fn = save_fn
        return g

    def _gang_snapshot(self, coord: Coordinator, step: int) -> None:
        """One barrier epoch; mirrors the driver's counters into the
        coordinator record so traces/metrics survive the driver."""
        g = self._gang(coord)
        try:
            g.snapshot(step)
        finally:
            coord.metrics.update(
                gang_epochs=g.epochs_committed, gang_aborts=g.aborts,
                gang_last_abort=g.last_abort_reason or "")

    # ------------------------------------------------------------------
    # Checkpointing (paper §5.2: user-initiated / periodic / app-initiated)
    # ------------------------------------------------------------------
    def checkpoint_now(self, coord_id: str, *, blocking: bool = True) -> int:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state not in (CoordState.RUNNING, CoordState.READY):
                raise RuntimeError(
                    f"cannot checkpoint in state {coord.state.value}")
            # a gang snapshot is cut by the barrier (quiesce + drain), not
            # by reading app state under the lock — only the step number
            # is claimed here. Staged apps hand back a handle in
            # microseconds; materialization runs on the writer thread.
            if coord.asr.gang:
                state = None
            else:
                with tracer().span("ckpt/pin", cat="ckpt",
                                   trace_id=coord.trace_id):
                    state = snapshot_of(coord.app)
            # claim the step under the lock: a concurrent suspend (or a
            # second checkpoint_now) must not mint the same step number
            step = self._step_counter.get(coord_id, 0) + 1
            self._step_counter[coord_id] = step
        if coord.asr.gang:
            # blocking by nature: the ranks stay quiesced until committed
            self._gang_snapshot(coord, step)
        else:
            self.ckpt.save(coord, step, state, blocking=blocking)
        return step

    def start_checkpoint_daemon(self, tick_s: float = 0.02) -> None:
        if self._ckpt_daemon is None:
            self._ckpt_daemon_stop.clear()
            self._ckpt_daemon = threading.Thread(
                target=self._ckpt_loop, args=(tick_s,), daemon=True)
            self._ckpt_daemon.start()
        self.monitor.start()

    def stop_daemons(self) -> None:
        self._ckpt_daemon_stop.set()
        if self._ckpt_daemon is not None:
            self._ckpt_daemon.join(timeout=5)
            self._ckpt_daemon = None
        self.monitor.stop()

    def _ckpt_loop(self, tick_s: float) -> None:
        while not active_clock().wait(self._ckpt_daemon_stop, tick_s):
            clk = active_clock()
            now = clk.now()
            for coord_id, due in list(self._next_ckpt.items()):
                if now < due:
                    continue
                try:
                    coord = self.db.get(coord_id)
                except KeyError:
                    self._next_ckpt.pop(coord_id, None)
                    continue
                if coord.state != CoordState.RUNNING:
                    continue
                try:
                    self.checkpoint_now(coord_id, blocking=False)
                except Exception as e:             # noqa: BLE001
                    # state raced (RuntimeError) or the store faulted
                    # (IOError): one app's bad save must not kill the
                    # periodic daemon for every app — skip this period,
                    # but leave a telemetry breadcrumb instead of vanishing
                    registry().inc("appmgr.daemon_errors",
                                   note=f"{type(e).__name__}: {e}")
                self._next_ckpt[coord_id] = (
                    now + clk.from_wall(coord.asr.policy.period_s))

    # ------------------------------------------------------------------
    # Recovery (paper §5.3 / §6.3)
    # ------------------------------------------------------------------
    def _on_monitor_event(self, coord_id: str, kind: str) -> None:
        try:
            coord = self.db.get(coord_id)
        except KeyError:
            return
        if kind in ("straggler", "low_performance"):
            action = getattr(coord.asr, "straggler_action", "suspend")
            done = False
            if coord.app is not None:
                try:
                    done = bool(coord.app.is_done())
                except Exception:                  # noqa: BLE001
                    done = False
            if action == "suspend" and not done:
                # the suspend reason keeps the detection path attributable
                # (chaos reads it to distinguish telemetry from liveness)
                self._submit_once(coord_id, self._suspend_if_running,
                                  coord_id, kind)
            return
        self._submit_once(coord_id, self._recover, coord_id, kind)

    def _submit_once(self, coord_id: str, fn, *args) -> Optional[cf.Future]:
        """Submit a recovery action unless one is already in flight for
        this coordinator. The monitor re-fires every poll tick while a
        fault persists (a straggler keeps straggling for the whole of the
        suspend's swap-out write) — duplicates are dropped, not raced."""
        with self._inflight_lock:
            if coord_id in self._inflight_ops:
                self.events_deduped += 1
                return None
            fut = self.pool.submit(self._guarded, fn, *args)
            self._inflight_ops[coord_id] = fut
        fut.add_done_callback(lambda _f: self._clear_inflight(coord_id))
        return fut

    def _clear_inflight(self, coord_id: str) -> None:
        with self._inflight_lock:
            self._inflight_ops.pop(coord_id, None)

    def _join_inflight(self, coord_id: str, timeout: float = 30.0) -> None:
        with self._inflight_lock:
            fut = self._inflight_ops.get(coord_id)
        if fut is not None:
            cf.wait([fut], timeout=timeout)

    def _guarded(self, fn, *args) -> None:
        try:
            fn(*args)
        except Exception as e:                     # noqa: BLE001
            registry().inc("appmgr.op_errors",
                           note=f"{type(e).__name__}: {e}")
            traceback.print_exc()

    def _suspend_if_running(self, coord_id: str, reason: str) -> None:
        """Monitor-driven suspend: losing the race to another state change
        (a concurrent recovery, terminate, or an earlier suspend that just
        won) is expected — swallow it instead of stack-tracing."""
        try:
            self.suspend(coord_id, reason)
        except (RuntimeError, KeyError):
            pass

    def _seed_step_counter(self, coord: Coordinator) -> None:
        """Re-seed the save counter from the newest COMMITTED image.

        Every restore path must do this: a fresh manager (service restart,
        clone target) or a restore to an earlier image would otherwise
        count from 0 again — the next save would clobber newer images and
        corrupt keep_last pruning / latest() ordering."""
        latest = self.ckpt.latest(coord)
        if latest is not None:
            cur = self._step_counter.get(coord.coord_id, 0)
            self._step_counter[coord.coord_id] = max(cur, latest)

    def _aborted(self, coord: Coordinator) -> bool:
        """True when this recovery no longer owns the coordinator (a
        concurrent terminate moved it out of RESTARTING)."""
        with coord.lock:
            return coord.state != CoordState.RESTARTING

    def _recover(self, coord_id: str, kind: str) -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.RUNNING:
                return                              # debounce duplicates
            self.db.transition(coord, CoordState.RESTARTING, kind)
        self.monitor.unwatch(coord_id)
        coord.recoveries += 1
        t0 = active_clock().now()
        try:
            coord.app.stop()
            err = self.ckpt.wait(coord, strict=False)
            if err is not None:
                # an in-flight save died (e.g. transient storage fault);
                # the newest COMMITTED image is still the restore point
                coord.metrics["last_save_error"] = repr(err)
            if self._aborted(coord):
                return
            if kind == "vm_failure":
                # passive recovery: replace unreachable VMs with fresh ones
                self.provision.forget(coord.vms)
                fresh = self.cloud.replace_failed(
                    coord.asr.backend, coord.vms, coord.asr.template,
                    coord.coord_id)
                with coord.lock:
                    coord.vms = fresh
                if self._aborted(coord):
                    return                  # terminate() now owns the VMs
                self.provision.provision(fresh, coord.asr.provision_cmds,
                                         **self._provision_cost(coord.asr.backend))
            state = self._load_latest_with_retry(coord)
            self._seed_step_counter(coord)
            if self._aborted(coord):
                return
            if self._start_app(coord, state):
                coord.metrics["last_recovery_s"] = (
                    active_clock().now() - t0)
        except Exception as e:                     # noqa: BLE001
            coord.error = str(e)
            # Only flag ERROR while we still own the coordinator: if a
            # terminate() took it (TERMINATING), moving to ERROR — legal
            # from TERMINATING — would wedge terminate's final TERMINATED
            # transition.
            with coord.lock:
                if coord.state == CoordState.RESTARTING:
                    self.db.transition(coord, CoordState.ERROR, str(e))

    def _load_latest_with_retry(self, coord: Coordinator) -> Any:
        """Restore the newest COMMITTED image, absorbing transient storage
        errors (bounded retries). Returns None when no image exists yet."""
        for attempt in range(self.recover_retries + 1):
            try:
                latest = self.ckpt.latest(coord)
                if latest is None:
                    return None
                return self._load_state(coord, latest)
            except Exception:                      # noqa: BLE001
                if attempt >= self.recover_retries:
                    raise
                active_clock().sleep(self.retry_backoff_s * (attempt + 1))

    def _load_state(self, coord: Coordinator, step: Optional[int] = None):
        """Restore-path dispatch: gang images reshard onto however many
        VMs the coordinator holds NOW (shrink-restore after an outage
        lands on fewer ranks than the image was cut from)."""
        if not coord.asr.gang:
            return self.ckpt.load(coord, step)
        n = len(coord.vms) or coord.asr.n_vms
        trees, _man, stats = self.ckpt.load_gang(coord, step, n_ranks=n)
        coord.metrics["gang_restore_ranks"] = n
        coord.metrics["gang_restore_fetches"] = stats["chunk_fetches"]
        coord.metrics["gang_restore_unique"] = stats["unique_chunks"]
        return trees

    def restart_from(self, coord_id: str, step: Optional[int] = None) -> None:
        """POST /coordinators/:id/checkpoints/:id — restart from an image.

        Covers all the paper's §5.3 cases: restart a running app from an
        earlier image; restart a suspended/errored app; and bring up a
        freshly-created clone target whose image was just uploaded ("this
        will trigger the passive recovery mechanism to generate a new
        virtual cluster").
        """
        coord = self.db.get(coord_id)
        fresh_clone = False
        with coord.lock:
            if coord.state == CoordState.RUNNING:
                self.db.transition(coord, CoordState.RESTARTING, "user")
                self.monitor.unwatch(coord_id)
                if coord.app is not None:      # rehydrated records
                    coord.app.stop()           # (CoordinatorDB.load) have
                                               # no live app to stop
            elif coord.state in (CoordState.SUSPENDED, CoordState.ERROR,
                                 CoordState.QUEUED):
                # QUEUED here is a *requeued* job (dead cloud / capacity
                # race) that already holds images — restart, don't rerun
                self.db.transition(coord, CoordState.RESTARTING, "user")
            elif coord.state == CoordState.CREATING:
                fresh_clone = True
            else:
                raise RuntimeError(f"cannot restart from {coord.state.value}")
        self.ckpt.wait(coord, strict=False)
        if fresh_clone:
            self._bringup_infra(coord)
        elif not coord.vms:
            coord.vms = self.cloud.create_cluster(
                coord.asr.backend, coord.asr.n_vms, coord.asr.template,
                coord.coord_id)
            self.provision.provision(coord.vms, coord.asr.provision_cmds,
                                     **self._provision_cost(coord.asr.backend))
        elif not all(vm.reachable for vm in coord.vms):
            self.provision.forget(coord.vms)
            coord.vms = self.cloud.replace_failed(
                coord.asr.backend, coord.vms, coord.asr.template,
                coord.coord_id)
            self.provision.provision(coord.vms, coord.asr.provision_cmds,
                                     **self._provision_cost(coord.asr.backend))
        state = self._load_state(coord, step)
        # seed from the NEWEST committed image (not the restored one): a
        # user restarting from an earlier image must not have the next
        # save clobber the newer images still in the store
        self._seed_step_counter(coord)
        self._start_app(coord, state)

    # ------------------------------------------------------------------
    # Job swapping (use case 2) + proactive suspend
    # ------------------------------------------------------------------
    def suspend(self, coord_id: str, reason: str = "user") -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.RUNNING:
                raise RuntimeError(f"cannot suspend {coord.state.value}")
            pol = coord.asr.policy
            swap_codec = pol.swap_codec or None
            if coord.asr.gang:
                state = None
            else:
                with tracer().span("ckpt/pin", cat="ckpt",
                                   trace_id=coord.trace_id,
                                   args={"suspend": reason}):
                    state = snapshot_of(coord.app, codec=swap_codec)
            step = self._step_counter.get(coord_id, 0) + 1
            self._step_counter[coord_id] = step
        # The blocking swap-out write runs OUTSIDE coord.lock: holding the
        # lock across a full save would stall checkpoint_now, the periodic
        # daemon and monitor-event handling for this coordinator for the
        # whole write. The snapshot above is already step-consistent (for
        # a gang job the barrier cuts it here instead — an epoch abort
        # fails the suspend with the job still RUNNING and unharmed).
        if coord.asr.gang:
            self._gang_snapshot(coord, step)
        else:
            self.ckpt.save(coord, step, state, blocking=True,
                           metadata={"suspend": reason}, codec=swap_codec)
        with coord.lock:
            if coord.state != CoordState.RUNNING:
                # a recovery/terminate won the race during the write; the
                # image is committed and harmless, but the suspend is off
                raise RuntimeError(
                    f"suspend({coord_id}) aborted: state became "
                    f"{coord.state.value} during swap-out")
            coord.app.stop()
            # detach monitoring + the VM handles BEFORE publishing
            # SUSPENDED: the instant the new state is visible, a resume
            # may allocate a fresh cluster and re-watch — teardown must
            # only ever touch the old cluster
            self.monitor.unwatch(coord_id)
            self._next_ckpt.pop(coord_id, None)
            old_vms, coord.vms = coord.vms, []
            self.db.transition(coord, CoordState.SUSPENDED, reason)
        self.provision.forget(old_vms)
        self.cloud.destroy_cluster(coord.asr.backend, old_vms)

    def resume(self, coord_id: str, block: bool = True) -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.SUSPENDED:
                raise RuntimeError(f"cannot resume {coord.state.value}")
            self.db.transition(coord, CoordState.RESTARTING, "resume")

        def _do():
            asr = coord.asr
            try:
                fresh = self.cloud.create_cluster(
                    asr.backend, asr.n_vms, asr.template, coord.coord_id)
            except CapacityError as e:
                # capacity raced away between the scheduler's check and
                # the claim: the job is still safely swapped out — return
                # to SUSPENDED so a later tick retries, don't wedge ERROR
                # (unless a terminate took ownership mid-resume)
                with coord.lock:
                    if coord.state == CoordState.RESTARTING:
                        self.db.transition(coord, CoordState.SUSPENDED,
                                           f"resume aborted: {e}")
                return
            except Exception as e:                 # noqa: BLE001
                # any other allocation failure must not strand the job in
                # RESTARTING (or kill a blocking caller's loop thread)
                coord.error = str(e)
                with coord.lock:
                    if coord.state == CoordState.RESTARTING:
                        self.db.transition(coord, CoordState.ERROR, str(e))
                return
            with coord.lock:
                owned = coord.state == CoordState.RESTARTING
                if owned:
                    coord.vms = fresh
            if not owned:
                # terminate() raced the resume: release what we claimed
                self.cloud.destroy_cluster(asr.backend, fresh)
                return
            try:
                self.provision.provision(coord.vms, asr.provision_cmds,
                                         **self._provision_cost(asr.backend))
                state = self._load_state(coord)
                self._seed_step_counter(coord)
                self._start_app(coord, state)
            except Exception as e:                 # noqa: BLE001
                coord.error = str(e)
                with coord.lock:
                    if coord.state == CoordState.RESTARTING:
                        self.db.transition(coord, CoordState.ERROR, str(e))

        if block:
            _do()
        else:
            self.pool.submit(_do)

    # ------------------------------------------------------------------
    # Termination (paper §5.4)
    # ------------------------------------------------------------------
    def terminate(self, coord_id: str, *, delete_images: bool = True) -> Dict:
        coord = self.db.get(coord_id)
        with coord.lock:
            self.db.transition(coord, CoordState.TERMINATING, "user")
        self.monitor.unwatch(coord_id)
        self._next_ckpt.pop(coord_id, None)
        # Join any in-flight recovery/suspend: it aborts at its next state
        # check (the TERMINATING transition above makes _aborted() true)
        # and must stop touching coord.vms before we destroy them.
        self._join_inflight(coord_id)
        if coord.app is not None:
            coord.app.stop()
        self.ckpt.wait(coord, strict=False)
        if coord.vms:
            self.provision.forget(coord.vms)
            self.cloud.destroy_cluster(coord.asr.backend, coord.vms)
            coord.vms = []
        if delete_images:
            self.ckpt.delete_all(coord)
        self._gangs.pop(coord_id, None)
        self.db.transition(coord, CoordState.TERMINATED)
        final = coord.to_dict()
        self.db.remove(coord_id)          # paper §5.4: delete the db entry
        return final
