"""Application Manager (paper §4.2): orchestrates the coordinator lifecycle.

Owns the bring-up pipeline (allocate -> provision -> start), the periodic
checkpoint daemon, and all recovery paths:
  * VM failure  -> passive recovery: replace unreachable VMs, restore from
                   the latest image, restart (paper §6.3 case 1);
  * app failure -> in-place restart on the same VMs (paper §6.3 case 2 —
                   "as an optimization");
  * straggler   -> proactive suspend to stable storage (paper §1: "detects
                   ... exceptionally low performance ... and proactively
                   suspends the job"); the scheduler resumes it later.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
import traceback
from typing import Any, Dict, Optional

from repro.clusters.base import SimBackend
from repro.core.application import AppContext
from repro.core.checkpoint_manager import CheckpointManager
from repro.core.cloud_manager import CloudManager
from repro.core.coordinator import (ASR, Coordinator, CoordinatorDB,
                                    CoordState)
from repro.core.monitoring import MonitoringManager
from repro.core.provision import ProvisionManager


class AppManager:
    def __init__(self, db: CoordinatorDB, cloud: CloudManager,
                 provision: ProvisionManager, ckpt: CheckpointManager,
                 workers: int = 100):
        self.db = db
        self.cloud = cloud
        self.provision = provision
        self.ckpt = ckpt
        # "users requests are mostly treated in background using a pool of
        # threads" (§6.5) — sized for the paper's 100-concurrent-apps test.
        self.pool = cf.ThreadPoolExecutor(max_workers=workers,
                                          thread_name_prefix="appmgr")
        self.monitor = MonitoringManager(self._on_monitor_event)
        self._ckpt_daemon_stop = threading.Event()
        self._ckpt_daemon: Optional[threading.Thread] = None
        self._next_ckpt: Dict[str, float] = {}
        self._step_counter: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # Submission (paper §5.1)
    # ------------------------------------------------------------------
    def submit(self, asr: ASR, block: bool = False) -> Coordinator:
        coord = self.db.create(asr)
        fut = self.pool.submit(self._bringup, coord)
        if block:
            fut.result()
        return coord

    def _provision_cost(self, backend_name: str):
        backend = self.cloud.backend(backend_name)
        return {"cost": backend.sim.cost} if isinstance(backend, SimBackend) \
            else {}

    def _bringup_infra(self, coord: Coordinator) -> None:
        """CREATING -> PROVISIONING -> READY (allocate + provision)."""
        asr = coord.asr
        vms = self.cloud.create_cluster(asr.backend, asr.n_vms,
                                        asr.template, coord.coord_id)
        coord.vms = vms
        self.db.transition(coord, CoordState.PROVISIONING)
        self.provision.provision(vms, asr.provision_cmds,
                                 **self._provision_cost(asr.backend))
        self.db.transition(coord, CoordState.READY)

    def _bringup(self, coord: Coordinator,
                 restore_state: Any = None) -> None:
        try:
            self._bringup_infra(coord)
            self._start_app(coord, restore_state)
        except Exception as e:                     # noqa: BLE001
            coord.error = f"{e}\n{traceback.format_exc()}"
            try:
                self.db.transition(coord, CoordState.ERROR, str(e))
            except Exception:
                pass

    def _start_app(self, coord: Coordinator, restore_state: Any) -> None:
        asr = coord.asr
        if coord.app is None:
            coord.app = asr.app_factory()
        ctx = AppContext(coord.coord_id, coord.vms, service=None)
        coord.app.start(ctx, restore_state)
        self.db.transition(coord, CoordState.RUNNING)
        backend = self.cloud.backend(asr.backend)
        native = backend.supports_failure_notifications
        hook = asr.health_hook or (lambda: coord.app.healthy())
        self.monitor.watch(coord.coord_id, coord.vms, hook, native)
        if asr.policy.period_s > 0:
            self._next_ckpt[coord.coord_id] = (
                time.monotonic() + asr.policy.period_s)

    # ------------------------------------------------------------------
    # Checkpointing (paper §5.2: user-initiated / periodic / app-initiated)
    # ------------------------------------------------------------------
    def checkpoint_now(self, coord_id: str, *, blocking: bool = True) -> int:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state not in (CoordState.RUNNING, CoordState.READY):
                raise RuntimeError(
                    f"cannot checkpoint in state {coord.state.value}")
            state = coord.app.checkpoint_state()
        step = self._step_counter.get(coord_id, 0) + 1
        self._step_counter[coord_id] = step
        self.ckpt.save(coord, step, state, blocking=blocking)
        return step

    def start_checkpoint_daemon(self, tick_s: float = 0.02) -> None:
        if self._ckpt_daemon is None:
            self._ckpt_daemon_stop.clear()
            self._ckpt_daemon = threading.Thread(
                target=self._ckpt_loop, args=(tick_s,), daemon=True)
            self._ckpt_daemon.start()
        self.monitor.start()

    def stop_daemons(self) -> None:
        self._ckpt_daemon_stop.set()
        if self._ckpt_daemon is not None:
            self._ckpt_daemon.join(timeout=5)
            self._ckpt_daemon = None
        self.monitor.stop()

    def _ckpt_loop(self, tick_s: float) -> None:
        while not self._ckpt_daemon_stop.wait(tick_s):
            now = time.monotonic()
            for coord_id, due in list(self._next_ckpt.items()):
                if now < due:
                    continue
                try:
                    coord = self.db.get(coord_id)
                except KeyError:
                    self._next_ckpt.pop(coord_id, None)
                    continue
                if coord.state != CoordState.RUNNING:
                    continue
                try:
                    self.checkpoint_now(coord_id, blocking=False)
                except RuntimeError:
                    pass
                self._next_ckpt[coord_id] = (
                    now + coord.asr.policy.period_s)

    # ------------------------------------------------------------------
    # Recovery (paper §5.3 / §6.3)
    # ------------------------------------------------------------------
    def _on_monitor_event(self, coord_id: str, kind: str) -> None:
        try:
            coord = self.db.get(coord_id)
        except KeyError:
            return
        if kind == "straggler":
            action = getattr(coord.asr, "straggler_action", "suspend")
            if action == "suspend":
                self.pool.submit(self._guarded, self.suspend, coord_id,
                                 "straggler")
            return
        self.pool.submit(self._guarded, self._recover, coord_id, kind)

    def _guarded(self, fn, *args) -> None:
        try:
            fn(*args)
        except Exception:                          # noqa: BLE001
            traceback.print_exc()

    def _recover(self, coord_id: str, kind: str) -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.RUNNING:
                return                              # debounce duplicates
            self.db.transition(coord, CoordState.RESTARTING, kind)
        self.monitor.unwatch(coord_id)
        coord.recoveries += 1
        try:
            coord.app.stop()
            self.ckpt.wait(coord)
            if kind == "vm_failure":
                # passive recovery: replace unreachable VMs with fresh ones
                self.provision.forget(coord.vms)
                coord.vms = self.cloud.replace_failed(
                    coord.asr.backend, coord.vms, coord.asr.template,
                    coord.coord_id)
                self.provision.provision(coord.vms, coord.asr.provision_cmds,
                                         **self._provision_cost(coord.asr.backend))
            state = None
            latest = self.ckpt.latest(coord)
            if latest is not None:
                state = self.ckpt.load(coord, latest)
            self._start_app(coord, state)
        except Exception as e:                     # noqa: BLE001
            coord.error = str(e)
            self.db.transition(coord, CoordState.ERROR, str(e))

    def restart_from(self, coord_id: str, step: Optional[int] = None) -> None:
        """POST /coordinators/:id/checkpoints/:id — restart from an image.

        Covers all the paper's §5.3 cases: restart a running app from an
        earlier image; restart a suspended/errored app; and bring up a
        freshly-created clone target whose image was just uploaded ("this
        will trigger the passive recovery mechanism to generate a new
        virtual cluster").
        """
        coord = self.db.get(coord_id)
        fresh_clone = False
        with coord.lock:
            if coord.state == CoordState.RUNNING:
                self.db.transition(coord, CoordState.RESTARTING, "user")
                self.monitor.unwatch(coord_id)
                coord.app.stop()
            elif coord.state in (CoordState.SUSPENDED, CoordState.ERROR):
                self.db.transition(coord, CoordState.RESTARTING, "user")
            elif coord.state == CoordState.CREATING:
                fresh_clone = True
            else:
                raise RuntimeError(f"cannot restart from {coord.state.value}")
        self.ckpt.wait(coord)
        if fresh_clone:
            self._bringup_infra(coord)
        elif not coord.vms:
            coord.vms = self.cloud.create_cluster(
                coord.asr.backend, coord.asr.n_vms, coord.asr.template,
                coord.coord_id)
            self.provision.provision(coord.vms, coord.asr.provision_cmds,
                                     **self._provision_cost(coord.asr.backend))
        elif not all(vm.reachable for vm in coord.vms):
            self.provision.forget(coord.vms)
            coord.vms = self.cloud.replace_failed(
                coord.asr.backend, coord.vms, coord.asr.template,
                coord.coord_id)
            self.provision.provision(coord.vms, coord.asr.provision_cmds,
                                     **self._provision_cost(coord.asr.backend))
        state = self.ckpt.load(coord, step)
        self._start_app(coord, state)

    # ------------------------------------------------------------------
    # Job swapping (use case 2) + proactive suspend
    # ------------------------------------------------------------------
    def suspend(self, coord_id: str, reason: str = "user") -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.RUNNING:
                raise RuntimeError(f"cannot suspend {coord.state.value}")
            state = coord.app.checkpoint_state()
            step = self._step_counter.get(coord_id, 0) + 1
            self._step_counter[coord_id] = step
            self.ckpt.save(coord, step, state, blocking=True,
                           metadata={"suspend": reason})
            coord.app.stop()
            self.db.transition(coord, CoordState.SUSPENDED, reason)
        self.monitor.unwatch(coord_id)
        self._next_ckpt.pop(coord_id, None)
        self.provision.forget(coord.vms)
        self.cloud.destroy_cluster(coord.asr.backend, coord.vms)
        coord.vms = []

    def resume(self, coord_id: str, block: bool = True) -> None:
        coord = self.db.get(coord_id)
        with coord.lock:
            if coord.state != CoordState.SUSPENDED:
                raise RuntimeError(f"cannot resume {coord.state.value}")
            self.db.transition(coord, CoordState.RESTARTING, "resume")

        def _do():
            try:
                asr = coord.asr
                coord.vms = self.cloud.create_cluster(
                    asr.backend, asr.n_vms, asr.template, coord.coord_id)
                self.provision.provision(coord.vms, asr.provision_cmds,
                                         **self._provision_cost(asr.backend))
                state = self.ckpt.load(coord)
                self._start_app(coord, state)
            except Exception as e:                 # noqa: BLE001
                coord.error = str(e)
                self.db.transition(coord, CoordState.ERROR, str(e))

        if block:
            _do()
        else:
            self.pool.submit(_do)

    # ------------------------------------------------------------------
    # Termination (paper §5.4)
    # ------------------------------------------------------------------
    def terminate(self, coord_id: str, *, delete_images: bool = True) -> Dict:
        coord = self.db.get(coord_id)
        with coord.lock:
            self.db.transition(coord, CoordState.TERMINATING, "user")
        self.monitor.unwatch(coord_id)
        self._next_ckpt.pop(coord_id, None)
        if coord.app is not None:
            coord.app.stop()
        self.ckpt.wait(coord)
        if coord.vms:
            self.provision.forget(coord.vms)
            self.cloud.destroy_cluster(coord.asr.backend, coord.vms)
            coord.vms = []
        if delete_images:
            self.ckpt.delete_all(coord)
        self.db.transition(coord, CoordState.TERMINATED)
        final = coord.to_dict()
        self.db.remove(coord_id)          # paper §5.4: delete the db entry
        return final
