"""Cross-cloud checkpoint replication & standby failover.

The paper's headline capability is that a cloud-agnostic checkpoint
service enables "migration of applications from one cloud platform to
another" (§5.3, §7.3) — but on-demand migration is *cold*: the full image
crosses the inter-cloud link at migration time, and ``transfer_s``
dominates exactly as in the paper's Table 3. This module keeps standby
clouds continuously warm instead:

  * :class:`ReplicationPolicy` — per-app replication contract: which
    standby targets to keep warm, the lag budget (RPO target) and an
    optional bandwidth cap on replication traffic.
  * :class:`ImageReplicator`  — an asynchronous daemon that watches every
    newly COMMITTED image of a watched app and ships only the chunks the
    standby store is missing (content-addressed dedup via the CAS digests),
    through the parallel data plane's upload streams with ``ByteBudget``
    backpressure. Replication repeats the writer's commit protocol on the
    standby — chunks, then manifest, then COMMITTED — so a standby reader
    only ever sees *fully replicated* images, and tracks per-target
    replication lag / RPO (``replication_stats``).
  * :class:`FailoverController` — pairs a primary :class:`CACSService`
    with standby services: when the primary's cloud suffers a whole-cloud
    outage (``ClusterSim.cloud_outage`` / the ``cloud_outage`` chaos
    event), it restarts the job on the best standby from the newest fully
    replicated image — with **zero chunk re-uploads**, because the standby
    coordinator adopts the replicated prefix — and records failover MTTR.

Warm migration falls out of the same substrate: ``migration.clone`` /
``migrate`` transfer through ``CheckpointManager.upload_image``, which
sources any chunk already replicated to the destination side from the
local replica instead of the inter-cloud link, so ``transfer_s`` collapses
to the unreplicated delta (``benchmarks/replication.py`` measures both
economics; Spot-on, arXiv:2210.02589, takes the same direction for
preemptible capacity).

Note the failure model: an outage takes the primary *compute* down; the
primary object store may or may not survive it. Failover never depends on
the primary store — the standby restores purely from its own replica —
but post-failover RPO accounting reads the primary store opportunistically
when it is still reachable.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.ckpt import gc as ckpt_gc
from repro.ckpt.layout import COMMITTED, MANIFEST, step_prefix
from repro.ckpt.plane import ByteBudget, DataPlaneConfig, shared_executor
from repro.ckpt.reader import list_steps, load_manifest
from repro.ckpt.storage import ObjectStore
from repro.obs.telemetry import registry
from repro.obs.trace import tracer
from repro.sim.simtime import active_clock
from repro.core.coordinator import Coordinator, CoordState


@dataclasses.dataclass(frozen=True)
class ReplicationPolicy:
    """Per-application replication contract.

    targets:        names of registered :class:`StandbyTarget`\\ s to keep
                    warm (replication fans out to all of them).
    lag_budget_s:   RPO target — the newest fully replicated image should
                    be at most this many seconds behind the newest
                    committed primary image (reported, not enforced:
                    ``replication_stats`` flags budget violations).
    bandwidth_bps:  optional cap on replication throughput per app
                    (cross-cloud egress is metered; background replication
                    must not starve the foreground save path).
    prune_with_primary: mirror primary GC — drop standby steps the primary
                    retention policy already deleted, sweeping orphaned
                    replica chunks, so standby storage stays bounded.
    """
    targets: Tuple[str, ...]
    lag_budget_s: float = 30.0
    bandwidth_bps: Optional[float] = None
    prune_with_primary: bool = True


@dataclasses.dataclass
class StandbyTarget:
    """A standby cloud: its object store, plus (for failover) the service
    instance running there and the backend/size to restart onto."""
    name: str
    store: ObjectStore
    service: Any = None                   # standby CACSService (failover)
    backend: Optional[str] = None         # backend name on that service
    n_vms: Optional[int] = None           # standby cluster size override


class _Throttle:
    """Leaky-bucket bytes/sec limiter shared by one app's copy streams.

    ``debit`` reserves the caller's slot under a lock and sleeps outside
    it, so parallel streams stay parallel while their *aggregate* rate
    converges on ``bps``. No-op when uncapped.
    """

    def __init__(self, bps: Optional[float]):
        self.bps = bps
        self._lock = threading.Lock()
        self._next_free = active_clock().now()

    def debit(self, nbytes: int) -> None:
        if not self.bps:
            return
        clk = active_clock()
        with self._lock:
            now = clk.now()
            # nbytes/bps is a wall-tuned duration; map it onto the clock's
            # native axis so the aggregate rate is preserved virtually
            start = max(self._next_free, now)
            self._next_free = start + clk.from_wall(nbytes / self.bps)
            # the chunk occupies the link for nbytes/bps: wait for our own
            # transfer slot to finish, not just for the link to free up —
            # otherwise a single large chunk would never be throttled
            delay = self._next_free - now
        if delay > 0:
            clk.sleep_until(now + delay)


def _pair_state() -> Dict[str, Any]:
    return {"last_step": None, "last_image_time": None,
            "images_replicated": 0, "chunks_copied": 0, "bytes_copied": 0,
            "chunks_skipped": 0, "bytes_skipped": 0, "steps_pruned": 0,
            "errors": 0}


class ImageReplicator:
    """Asynchronous continuous image replication to standby clouds.

    Watches the primary service's committed images per registered app and
    ships each new image to every target in the app's policy. Per image,
    only chunks the standby store does not already hold cross the link
    (CAS-digest dedup — across steps *and* across apps sharing content);
    copies fan out over the data plane's upload workers under a
    ``ByteBudget`` in-flight cap and the policy's bandwidth throttle, and
    the standby-side commit order (chunks → manifest → flush → COMMITTED)
    guarantees standbys only ever expose fully replicated images.
    """

    def __init__(self, service, *, plane: Optional[DataPlaneConfig] = None,
                 tick_s: float = 0.02):
        self.service = service
        self.plane = plane or DataPlaneConfig()
        self.tick_s = tick_s
        self._targets: Dict[str, StandbyTarget] = {}
        self._watched: Dict[str, ReplicationPolicy] = {}
        self._throttles: Dict[str, _Throttle] = {}
        self._pairs: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._replicated_listeners: List[Any] = []
        self._lock = threading.RLock()
        self._sync_lock = threading.Lock()    # one sync pass at a time
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._budget = ByteBudget(self.plane.max_inflight_bytes,
                                  name="replication")
        self.images_replicated = 0
        self.sync_errors = 0

    # ---- registration --------------------------------------------------
    def add_target(self, target: StandbyTarget) -> None:
        with self._lock:
            self._targets[target.name] = target

    def target(self, name: str) -> StandbyTarget:
        with self._lock:
            if name not in self._targets:
                raise KeyError(f"unknown replication target {name!r}; "
                               f"have {sorted(self._targets)}")
            return self._targets[name]

    def watch(self, coord_id: str, policy: ReplicationPolicy) -> None:
        for name in policy.targets:
            self.target(name)                 # fail fast on a typo
        with self._lock:
            self._watched[coord_id] = policy
            self._throttles[coord_id] = _Throttle(policy.bandwidth_bps)
            for name in policy.targets:
                self._pairs.setdefault((coord_id, name), _pair_state())

    def unwatch(self, coord_id: str) -> None:
        with self._lock:
            self._watched.pop(coord_id, None)
            self._throttles.pop(coord_id, None)

    def watched(self) -> List[str]:
        with self._lock:
            return list(self._watched)

    def on_replicated(self, cb) -> None:
        """Subscribe to replication completions: ``cb(coord_id, target,
        step)`` fires after an image is fully COMMITTED on a standby.
        The GlobalScheduler keys cross-cloud backfill warmth on this —
        a job waiting for its replica becomes placeable the instant the
        replica commits, event-driven instead of polled."""
        self._replicated_listeners.append(cb)

    # ---- daemon --------------------------------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="replicator")
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def _loop(self) -> None:
        while not active_clock().wait(self._stop, self.tick_s):
            try:
                self.sync()
            except Exception as e:             # noqa: BLE001
                # one bad pass (e.g. a coord terminated mid-walk) must not
                # kill replication for every app; retried next tick
                with self._lock:
                    self.sync_errors += 1
                registry().inc("replication.daemon_errors",
                               note=f"{type(e).__name__}: {e}")

    # ---- replication ---------------------------------------------------
    def sync(self, coord_id: Optional[str] = None) -> None:
        """Replicate every pending committed image now (blocking until the
        current backlog drains). The daemon calls this each tick; tests,
        benchmarks and pre-failover drains call it directly."""
        with self._sync_lock:
            with self._lock:
                work = ([(coord_id, self._watched[coord_id])]
                        if coord_id is not None
                        else list(self._watched.items()))
            for cid, policy in work:
                try:
                    coord = self.service.db.get(cid)
                except KeyError:
                    self.unwatch(cid)          # terminated: stop replicating
                    continue
                for name in policy.targets:
                    try:
                        self._sync_pair(coord, policy, self.target(name))
                    except Exception as e:     # noqa: BLE001
                        with self._lock:
                            self._pairs[(cid, name)]["errors"] += 1
                            self.sync_errors += 1
                        registry().inc("replication.daemon_errors",
                                       note=f"{type(e).__name__}: {e}")

    def _sync_pair(self, coord: Coordinator, policy: ReplicationPolicy,
                   target: StandbyTarget) -> None:
        src = self.service.ckpt.store(coord.asr.policy.store)
        prefix = coord.ckpt_prefix
        src_steps = list_steps(src, prefix)
        dst_steps = set(list_steps(target.store, prefix))
        state = self._pairs[(coord.coord_id, target.name)]
        for s in src_steps:
            if s not in dst_steps:
                self._replicate_image(coord, target, src, prefix, s, state)
        if policy.prune_with_primary:
            stale = sorted(dst_steps - set(src_steps))
            for s in stale:
                target.store.delete_prefix(step_prefix(prefix, s))
                state["steps_pruned"] += 1
            if stale:
                ckpt_gc.sweep_orphans(target.store, prefix)
        # RPO bookkeeping on the coordinator itself (service dashboards)
        lag = self._lag(src, prefix, state)
        coord.metrics[f"replication_lag_s:{target.name}"] = lag

    def _replicate_image(self, coord: Coordinator, target: StandbyTarget,
                         src: ObjectStore, prefix: str, step: int,
                         state: Dict[str, Any]) -> None:
        with tracer().span("replication/ship", cat="replication",
                           trace_id=coord.trace_id,
                           args={"step": step, "target": target.name}) as span:
            self._replicate_image_inner(coord, target, src, prefix, step,
                                        state, span)

    def _replicate_image_inner(self, coord: Coordinator,
                               target: StandbyTarget, src: ObjectStore,
                               prefix: str, step: int,
                               state: Dict[str, Any], span) -> None:
        man = load_manifest(src, prefix, step)
        dst = target.store
        throttle = self._throttles.get(coord.coord_id) or _Throttle(None)
        unique = {c.key: c for li in man.leaves.values() for c in li.chunks}
        missing = []
        for key, c in unique.items():
            if dst.exists(key):                # already shipped (dedup)
                state["chunks_skipped"] += 1
                state["bytes_skipped"] += c.nbytes
            else:
                missing.append(c)

        def ship(c) -> None:
            self._budget.acquire(c.nbytes)
            try:
                data = src.get(c.key)
                throttle.debit(len(data))
                if dst.put_if_absent(c.key, data):
                    state["chunks_copied"] += 1
                    state["bytes_copied"] += len(data)
                else:                          # raced another lineage
                    state["chunks_skipped"] += 1
                    state["bytes_skipped"] += len(data)
            finally:
                self._budget.release(c.nbytes)

        workers = max(1, self.plane.upload_workers)
        if workers == 1 or len(missing) <= 1:
            for c in missing:
                ship(c)
        else:
            ex = shared_executor("up", workers)
            for fut in [ex.submit(ship, c) for c in missing]:
                fut.result()                   # join: every chunk durable
        # standby-side commit, exactly like the writer: manifest after all
        # chunks, COMMITTED after the manifest — a crash mid-replication
        # leaves an invisible partial image that the next pass completes
        sp = step_prefix(prefix, step)
        gang = man.metadata.get("gang")
        if gang:                               # per-rank sub-manifests ride
            for r in range(int(gang.get("ranks", 0))):   # along (diagnostic)
                try:
                    dst.put(f"{sp}/rank_{r}.json",
                            src.get(f"{sp}/rank_{r}.json"))
                except Exception:              # noqa: BLE001
                    pass                       # restore needs only the merge
        dst.put(f"{sp}/{MANIFEST}", src.get(f"{sp}/{MANIFEST}"))
        dst.flush()
        dst.put(f"{sp}/{COMMITTED}", b"1")
        dst.flush()
        state["last_step"] = step
        state["last_image_time"] = man.metadata.get("time")
        state["images_replicated"] += 1
        span.set("chunks_copied", len(missing))
        registry().inc("replication.images")
        with self._lock:
            self.images_replicated += 1
            listeners = list(self._replicated_listeners)
        for cb in listeners:
            try:
                cb(coord.coord_id, target.name, step)
            except Exception:              # noqa: BLE001
                pass                       # a bad listener must not stall sync

    # ---- queries -------------------------------------------------------
    def _lag(self, src: ObjectStore, prefix: str,
             state: Dict[str, Any]) -> float:
        """RPO in seconds: commit-time gap between the newest primary image
        and the newest fully replicated one (0 when in sync, inf when
        nothing has replicated yet)."""
        steps = list_steps(src, prefix)
        newest = steps[-1] if steps else None
        if newest is None or newest == state["last_step"]:
            return 0.0
        if state["last_image_time"] is None:
            return float("inf")
        t_new = load_manifest(src, prefix, newest).metadata.get("time")
        if t_new is None:
            return float("inf")
        return max(0.0, t_new - state["last_image_time"])

    def replication_stats(self, coord_id: str) -> Dict[str, Any]:
        """Per-target replication state for one app: last fully replicated
        step, image/second lag vs the newest primary image, budget
        compliance, and cumulative copy/skip counters."""
        with self._lock:
            policy = self._watched.get(coord_id)
        if policy is None:
            return {}
        coord = self.service.db.get(coord_id)
        src = self.service.ckpt.store(coord.asr.policy.store)
        prefix = coord.ckpt_prefix
        src_steps = list_steps(src, prefix)
        targets: Dict[str, Any] = {}
        for name in policy.targets:
            state = self._pairs[(coord_id, name)]
            last = state["last_step"]
            lag_images = len([s for s in src_steps
                              if last is None or s > last])
            rpo_s = self._lag(src, prefix, state)
            targets[name] = {
                **{k: v for k, v in state.items() if k != "last_image_time"},
                "lag_images": lag_images,
                "rpo_s": rpo_s,
                "within_budget": rpo_s <= policy.lag_budget_s,
            }
        return {"coord": coord_id,
                "trace_id": coord.trace_id,
                "policy": {"lag_budget_s": policy.lag_budget_s,
                           "bandwidth_bps": policy.bandwidth_bps,
                           "targets": list(policy.targets)},
                "targets": targets}

    def best_standby(self, coord_id: str
                     ) -> Tuple[Optional[StandbyTarget], Optional[int]]:
        """The standby holding the newest *fully replicated* (COMMITTED on
        the standby) image, and that step. Consults the standby stores
        directly — the primary store may already be unreachable."""
        with self._lock:
            policy = self._watched.get(coord_id)
        if policy is None:
            return None, None
        prefix = self.service.db.get(coord_id).ckpt_prefix
        best: Tuple[Optional[StandbyTarget], Optional[int]] = (None, None)
        for name in policy.targets:
            target = self.target(name)
            steps = list_steps(target.store, prefix)
            if steps and (best[1] is None or steps[-1] > best[1]):
                best = (target, steps[-1])
        return best


@dataclasses.dataclass
class FailoverResult:
    """One completed (or failed) cross-cloud failover."""
    src_id: str
    dst_id: Optional[str]
    target: Optional[str]                 # standby target name
    step: Optional[int]                   # image the standby restored from
    detection_s: Optional[float]          # primary RUNNING -> ERROR
    restart_s: Optional[float]            # failover start -> standby RUNNING
    mttr_s: Optional[float]               # primary ERROR -> standby RUNNING
    rpo_images: Optional[int]             # primary images newer than `step`
    chunks_reuploaded: int                # CAS objects written on the
                                          # standby during failover (== 0:
                                          # all content was pre-replicated)
    ok: bool = True
    error: Optional[str] = None
    # replication_stats snapshot taken at failover-decision time, pairing
    # each MTTR/RPO with the lag that produced it
    replication: Optional[Dict[str, Any]] = None


class FailoverController:
    """Detects the loss of a whole primary cloud and restarts the affected
    jobs on the best standby.

    Trigger (the watch loop): a replicated coordinator sits in ERROR, its
    old fleet is fully unreachable, and its backend reports zero capacity
    — i.e. recovery on the home cloud has conclusively failed *and* the
    cloud itself is gone (a plain VM crash never trips this: recovery
    replaces the VM long before ERROR). ``failover()`` can also be driven
    explicitly (operator-initiated evacuation).

    The standby coordinator adopts the primary's replicated checkpoint
    prefix (``Coordinator.ckpt_prefix_override``), so the restart reads
    chunks the replicator already shipped — zero re-uploads — and
    post-failover saves continue the same lineage on the standby store.
    """

    def __init__(self, primary, replicator: ImageReplicator, *,
                 poll_interval_s: float = 0.02,
                 retire_primary: bool = True,
                 restart_timeout_s: float = 60.0):
        self.primary = primary
        self.replicator = replicator
        self.poll_interval_s = poll_interval_s
        self.retire_primary = retire_primary
        self.restart_timeout_s = restart_timeout_s
        self.results: Dict[str, FailoverResult] = {}
        self.failovers = 0
        self._inflight: set = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---- daemon --------------------------------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="failover")
            self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def _loop(self) -> None:
        while not active_clock().wait(self._stop, self.poll_interval_s):
            for coord_id in self.replicator.watched():
                with self._lock:
                    if coord_id in self.results or coord_id in self._inflight:
                        continue
                try:
                    coord = self.primary.db.get(coord_id)
                except KeyError:
                    continue
                if self._cloud_down(coord):
                    try:
                        self.failover(coord_id)
                    except Exception as e:     # noqa: BLE001
                        with self._lock:
                            self.results[coord_id] = FailoverResult(
                                src_id=coord_id, dst_id=None, target=None,
                                step=None, detection_s=None, restart_s=None,
                                mttr_s=None, rpo_images=None,
                                chunks_reuploaded=0, ok=False, error=str(e))

    def _cloud_down(self, coord: Coordinator) -> bool:
        """Conclusive home-cloud loss: the job sits in ERROR (recovery
        exhausted), its fleet is dark (both the stale VM handles and the
        monitor's sticky whole-fleet-unreachable flag agree), the backend
        reports zero spare capacity, and no *other* coordinator of this
        service is demonstrably alive on the same backend. A healthy-but-
        full cloud with live peers therefore never trips this; with no
        peers to observe, ERROR + zero capacity is indistinguishable from
        an outage — and the job cannot run at home either way, so failing
        over is the availability-preserving choice."""
        if coord.state != CoordState.ERROR:
            return False
        if coord.vms and any(vm.reachable for vm in coord.vms):
            return False
        monitor = self.primary.apps.monitor
        if not monitor.fleet_unreachable(coord.coord_id):
            return False                       # e.g. ERROR from an app bug
        try:
            backend = self.primary.cloud.backend(coord.asr.backend)
            if backend.capacity() > 0:
                return False                   # the cloud can still recover
        except Exception:                      # noqa: BLE001
            pass                               # unreachable backend == down
        for peer in self.primary.db.list():
            if (peer.coord_id != coord.coord_id
                    and peer.asr.backend == coord.asr.backend
                    and peer.state == CoordState.RUNNING
                    and any(vm.reachable for vm in peer.vms)):
                return False                   # the cloud is alive, just full
        return True

    # ---- the failover itself -------------------------------------------
    def failover(self, coord_id: str) -> FailoverResult:
        # exactly-once per coordinator: an explicit (operator) call racing
        # the watch loop waits for the in-flight failover instead of
        # starting a second one — two standby restarts of the same job
        # would be a split brain
        while True:
            with self._lock:
                if coord_id in self.results:
                    return self.results[coord_id]
                if coord_id not in self._inflight:
                    self._inflight.add(coord_id)
                    break
            active_clock().sleep(0.002)
        try:
            result = self._failover(coord_id)
        finally:
            with self._lock:
                self._inflight.discard(coord_id)
        with self._lock:
            self.results[coord_id] = result
            self.failovers += 1
        return result

    def _failover(self, coord_id: str) -> FailoverResult:
        coord = self.primary.db.get(coord_id)
        t_error = self._last_transition(coord, "ERROR")
        t_down = self._last_transition(coord, "RESTARTING")
        t0 = active_clock().timestamp()
        try:
            repl_snapshot = self.replicator.replication_stats(coord_id)
        except Exception:                      # noqa: BLE001
            repl_snapshot = None               # primary store unreachable
        target, step = self.replicator.best_standby(coord_id)
        if target is None or step is None:
            raise RuntimeError(
                f"{coord_id}: no standby holds a fully replicated image")
        if target.service is None or target.backend is None:
            raise RuntimeError(
                f"standby {target.name!r} has no service/backend attached")
        prefix = coord.ckpt_prefix
        # the zero-reupload invariant, measured against the restored image
        # itself: chunks of that manifest NOT already on the standby are
        # what the failover would have to ship (0 == fully pre-replicated).
        # Deliberately not a before/after CAS count — the standby app
        # resumes periodic saves the instant it is RUNNING, which would
        # race new (unrelated) chunks into such a delta.
        man = load_manifest(target.store, prefix, step)
        chunk_keys = {c.key for li in man.leaves.values() for c in li.chunks}
        reuploads = sum(1 for k in chunk_keys
                        if not target.store.exists(k))

        dst = target.service
        new_asr = dataclasses.replace(
            coord.asr, backend=target.backend,
            n_vms=target.n_vms or coord.asr.n_vms)
        dst_coord = dst.db.create(new_asr)
        dst_coord.ckpt_prefix_override = prefix     # adopt the replica
        dst.restart_from(dst_coord.coord_id, step)
        dst.wait_for_state(dst_coord.coord_id, CoordState.RUNNING,
                           timeout=self.restart_timeout_s)
        t_up = active_clock().timestamp()

        rpo_images = self._rpo_images(coord, step)
        detection = (None if t_error is None or t_down is None
                     else max(0.0, t_error - t_down))
        mttr = None if t_error is None else max(0.0, t_up - t_error)
        result = FailoverResult(
            src_id=coord_id, dst_id=dst_coord.coord_id, target=target.name,
            step=step, detection_s=detection, restart_s=t_up - t0,
            mttr_s=mttr, rpo_images=rpo_images,
            chunks_reuploaded=reuploads,
            replication=repl_snapshot)
        coord.metrics["failover_mttr_s"] = mttr if mttr is not None else -1.0
        coord.metrics["failover_target"] = target.name
        dst_coord.metrics["failover_src"] = coord_id
        # the primary lineage is handed over: stop replicating it, and
        # (optionally) retire the dead coordinator without deleting its
        # images — the standby owns the lineage now, and the primary store
        # copy (if it survived the outage) remains a valid replica
        self.replicator.unwatch(coord_id)
        if self.retire_primary:
            try:
                self.primary.apps.terminate(coord_id, delete_images=False)
            except Exception:                  # noqa: BLE001
                pass                           # the cloud is down; best-effort
        return result

    @staticmethod
    def _last_transition(coord: Coordinator, state: str) -> Optional[float]:
        for t, s, *_ in reversed(coord.history):
            if s == state:
                return t
        return None

    def _rpo_images(self, coord: Coordinator, step: int) -> Optional[int]:
        """Primary images newer than the restored one — best-effort: the
        primary store may have died with the cloud."""
        try:
            store = self.primary.ckpt.store(coord.asr.policy.store)
            return len([s for s in list_steps(store, coord.ckpt_prefix)
                        if s > step])
        except Exception:                      # noqa: BLE001
            return None


# ---------------------------------------------------------------------------
# Seeded end-to-end scenario (failover smoke / benchmark / example substrate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FailoverScenarioResult:
    seed: int
    outage_at_s: float
    failover: FailoverResult
    primary_final_state: str
    standby_state: str
    restored_iteration: int               # iteration in the restored image
    primary_iteration: int                # where the primary actually was
    replication: Dict[str, Any]           # stats snapshot at outage time
    trace: List[Tuple]

    @property
    def iterations_lost(self) -> int:
        return max(0, self.primary_iteration - self.restored_iteration)


def run_failover_scenario(seed: int = 11, *, n_hosts: int = 8,
                          n_vms: int = 2, outage_at_s: float = 6.0,
                          period_s: float = 0.4, iter_time_s: float = 0.2,
                          state_mb: float = 0.05,
                          bandwidth_bps: Optional[float] = None,
                          continuous_replication: bool = True,
                          settle_timeout_s: float = 60.0
                          ) -> FailoverScenarioResult:
    """Primary + standby services on two simulated clouds with separate
    stores; continuous replication; a seeded whole-cloud outage of the
    primary; automatic failover to the standby. Deterministic in outcome
    from the seed (same trace contract as ``chaos.run_scenario``).

    continuous_replication=False stops replicating after the initial
    image — the lag then grows with every periodic save, so the failover
    measures a large-RPO restore (the MTTR-vs-lag axis of
    ``benchmarks/replication.py``).
    """
    from repro.ckpt.storage import InMemoryStore
    from repro.clusters import OpenStackBackend, SnoozeBackend
    from repro.core.application import SimulatedApp
    from repro.core.chaos import (ChaosController, FaultEvent, FaultKind,
                                  FaultSchedule)
    from repro.core.coordinator import ASR, CheckpointPolicy
    from repro.core.service import CACSService

    primary_backend = SnoozeBackend(n_hosts=n_hosts)
    standby_backend = OpenStackBackend(n_hosts=n_hosts)
    primary_store = InMemoryStore()
    standby_store = InMemoryStore()
    primary = CACSService({primary_backend.name: primary_backend},
                          {"default": primary_store})
    standby = CACSService({standby_backend.name: standby_backend},
                          {"default": standby_store})
    replicator = ImageReplicator(primary)
    replicator.add_target(StandbyTarget(
        "standby", store=standby_store, service=standby,
        backend=standby_backend.name, n_vms=n_vms))
    controller = FailoverController(primary, replicator)
    try:
        asr = ASR(name=f"failover-{seed}", n_vms=n_vms,
                  backend=primary_backend.name,
                  app_factory=lambda: SimulatedApp(iter_time_s=iter_time_s,
                                                   state_mb=state_mb),
                  policy=CheckpointPolicy(period_s=period_s, keep_last=3))
        cid = primary.submit(asr)
        primary.wait_for_state(cid, CoordState.RUNNING, timeout=60)
        primary.trigger_checkpoint(cid)    # a restore point always exists
        replicator.watch(cid, ReplicationPolicy(
            targets=("standby",), bandwidth_bps=bandwidth_bps))
        replicator.sync()                  # standby warm before the clock
        if continuous_replication:
            replicator.start()
        controller.start()

        schedule = FaultSchedule(seed=seed, events=[
            FaultEvent(at_s=outage_at_s, kind=FaultKind.CLOUD_OUTAGE)])
        chaos = ChaosController(primary, cid, primary_backend, schedule,
                                settle_timeout_s=settle_timeout_s,
                                failover=controller)
        primary_coord = primary.db.get(cid)
        chaos.run()
        if cid not in controller.results:
            raise RuntimeError("failover did not trigger "
                               f"(primary {primary_coord.state.value})")
        res = controller.results[cid]
        if not res.ok:
            raise RuntimeError(f"failover failed: {res.error}")

        # Freeze the standby before reading the restored image: the
        # resumed app checkpoints periodically under the adopted prefix,
        # and its keep_last GC would eventually prune res.step out from
        # under the restore below.
        standby.apps.stop_daemons()
        # RPO in iterations: what the restored image held vs where the
        # primary app actually was when the cloud died
        from repro.ckpt.reader import restore
        state, _ = restore(standby_store, primary_coord.ckpt_prefix,
                           res.step)
        dst_coord = standby.db.get(res.dst_id)
        return FailoverScenarioResult(
            seed=seed, outage_at_s=outage_at_s, failover=res,
            primary_final_state=primary_coord.state.value,
            standby_state=dst_coord.state.value,
            restored_iteration=int(state["iteration"]),
            primary_iteration=int(primary_coord.app.iteration),
            replication=res.replication or {},
            trace=[o.trace_key() for o in chaos.outcomes])
    finally:
        controller.stop()
        replicator.stop()
        standby.shutdown()
        primary.shutdown()
