"""Provision Manager (paper §4.2/§6.5): prepares a virtual cluster to run.

Faithfully models the paper's two optimizations and their limit:
  * parallel SSH connections — a thread pool;
  * connection re-use — the first command to a VM pays ``connect_s``,
    subsequent ones don't;
  * a configured maximum of concurrent SSH sessions (16 in the paper's
    setup) — beyond 16 VMs provisioning time grows again (Fig 3a).
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Iterable, Sequence

from repro.clusters.base import VMHandle
from repro.clusters.simulator import CostModel, sim_sleep

MAX_SSH_SESSIONS = 16

# Internal provisioning actions (paper §5.1: checkpoint dir creation,
# checkpointer install/config) + user-defined commands from the ASR.
INTERNAL_CMDS = ("mkdir -p /ckpt", "install-checkpoint-agent",
                 "configure-checkpoint-policy")


class ProvisionManager:
    def __init__(self, max_sessions: int = MAX_SSH_SESSIONS):
        self.max_sessions = max_sessions
        self._pool = cf.ThreadPoolExecutor(max_workers=max_sessions,
                                           thread_name_prefix="ssh")
        self._connected: set = set()
        self._lock = threading.Lock()

    def provision(self, vms: Sequence[VMHandle],
                  user_cmds: Iterable[str] = (),
                  cost: CostModel = CostModel()) -> float:
        """Run all provisioning commands on all VMs. Returns elapsed time."""
        cmds = list(INTERNAL_CMDS) + list(user_cmds)

        def one_vm(vm: VMHandle) -> None:
            with self._lock:
                new_conn = vm.vm_id not in self._connected
                self._connected.add(vm.vm_id)
            if new_conn:
                sim_sleep(cost.ssh_connect_s)
            for _ in cmds:
                sim_sleep(cost.ssh_cmd_s)

        t0 = time.monotonic()
        futures = [self._pool.submit(one_vm, vm) for vm in vms]
        for f in futures:
            f.result()
        return time.monotonic() - t0

    def forget(self, vms: Sequence[VMHandle]) -> None:
        with self._lock:
            for vm in vms:
                self._connected.discard(vm.vm_id)

    def close(self) -> None:
        self._pool.shutdown(wait=False)
