"""CACS service facade — the paper's REST resource model (Table 1).

Resources:
  coordinators:  GET /coordinators            -> list_coordinators()
                 POST /coordinators           -> submit(asr)
  coordinator:   GET /coordinators/:id        -> get_coordinator(id)
                 DELETE /coordinators/:id     -> delete_coordinator(id)
  checkpoints:   GET  .../:id/checkpoints      -> list_checkpoints(id)
                 POST .../:id/checkpoints      -> trigger_checkpoint(id) or
                                                  upload_checkpoint(id, ...)
  checkpoint:    GET  .../checkpoints/:step    -> get_checkpoint(id, step)
                 POST .../checkpoints/:step    -> restart_from(id, step)
                 DELETE .../checkpoints/:step  -> delete_checkpoint(id, step)

Requests are handled by a background thread pool (paper §6.5); the facade is
stateless over CoordinatorDB + object stores, so a crashed service instance
restarts with no loss (paper §6.4).

This module is the paper's §2 "checkpointing as a service" contract in one
class: non-invasive (any `core/application.py` Application is accepted),
cloud-agnostic (backends are named entries in the CloudManager registry,
§4.2), and the substrate for all four §2.2 use cases — long-running job
support (1), job swapping under over-subscription (2, via
`core/scheduler.py`), proactive suspend of degraded jobs (3, via
`core/monitoring.py`), and cross-cloud migration (4, via
`core/migration.py`). See README.md for the full paper→module map.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from repro.ckpt.plane import DataPlaneConfig
from repro.ckpt.storage import InMemoryStore, ObjectStore
from repro.clusters.base import ClusterBackend
from repro.core.app_manager import AppManager
from repro.core.checkpoint_manager import CheckpointManager
from repro.core.cloud_manager import CloudManager
from repro.core.coordinator import (ASR, Coordinator, CoordinatorDB,
                                    CoordState)
from repro.core.provision import ProvisionManager
from repro.sim.simtime import active_clock


class CACSService:
    def __init__(self, backends: Dict[str, ClusterBackend],
                 stores: Optional[Dict[str, ObjectStore]] = None,
                 db_store: Optional[ObjectStore] = None,
                 start_daemons: bool = True,
                 workers: int = 100,
                 ckpt_plane: Optional[DataPlaneConfig] = None,
                 lowperf=None):
        stores = stores or {"default": InMemoryStore()}
        self.db = CoordinatorDB(db_store)
        if db_store is not None:
            # restartability (paper §6.4): a service instance given a
            # persistent db store rehydrates its coordinator records (sans
            # live app/VMs) — their images and step history are intact, so
            # restart_from resumes them once an app factory is re-attached
            self.db.load()
        self.cloud = CloudManager(backends)
        self.provision = ProvisionManager()
        # service-wide checkpoint data-plane parallelism (swap-out, periodic
        # saves, restores and image ingest all ride it); per-app override
        # via CheckpointPolicy.plane
        self.ckpt = CheckpointManager(stores, plane=ckpt_plane)
        # lowperf: optional core.monitoring.LowPerfConfig enabling the
        # telemetry-driven throughput watchdog (None = liveness only)
        self.apps = AppManager(self.db, self.cloud, self.provision,
                               self.ckpt, workers=workers, lowperf=lowperf)
        # optional cross-cloud replication (core/replication.py); attached
        # via attach_replicator so standby wiring stays explicit
        self.replicator = None
        # optional cloud-spanning scheduler (core/scheduler.py); attached
        # via attach_scheduler so it is stopped with the service
        self.scheduler = None
        # route native failure notifications (Snooze path, §6.1)
        for backend in backends.values():
            if backend.supports_failure_notifications:
                backend.subscribe_failures(self._native_failure)
        if start_daemons:
            self.apps.start_checkpoint_daemon()

    def _native_failure(self, vm) -> None:
        coord_id = vm.host.owner
        if coord_id:
            self.apps.monitor.on_native_failure(coord_id)

    # ---- coordinators resource -----------------------------------------
    def list_coordinators(self) -> List[Dict[str, Any]]:
        return [c.to_dict() for c in self.db.list()]

    def submit(self, asr: ASR, block: bool = False) -> str:
        return self.apps.submit(asr, block=block).coord_id

    # ---- coordinator resource ------------------------------------------
    def get_coordinator(self, coord_id: str) -> Dict[str, Any]:
        return self.db.get(coord_id).to_dict()

    def delete_coordinator(self, coord_id: str) -> Dict[str, Any]:
        return self.apps.terminate(coord_id)

    # ---- checkpoints resource ------------------------------------------
    def list_checkpoints(self, coord_id: str) -> List[int]:
        return self.ckpt.list_images(self.db.get(coord_id))

    def trigger_checkpoint(self, coord_id: str, *,
                           blocking: bool = True) -> int:
        return self.apps.checkpoint_now(coord_id, blocking=blocking)

    def upload_checkpoint(self, coord_id: str, src_store: ObjectStore,
                          src_prefix: str, step: int) -> None:
        self.ckpt.upload_image(self.db.get(coord_id), src_store,
                               src_prefix, step)

    # ---- checkpoint resource -------------------------------------------
    def get_checkpoint(self, coord_id: str, step: int) -> Dict[str, Any]:
        return self.ckpt.image_info(self.db.get(coord_id), step)

    def restart_from(self, coord_id: str, step: Optional[int] = None) -> None:
        self.apps.restart_from(coord_id, step)

    def delete_checkpoint(self, coord_id: str, step: int) -> None:
        self.ckpt.delete_image(self.db.get(coord_id), step)

    # ---- replication (core/replication.py) ------------------------------
    def attach_replicator(self, replicator) -> None:
        """Register this service's ImageReplicator so replication state is
        queryable through the facade and shut down with the service."""
        self.replicator = replicator

    def replication_stats(self, coord_id: str) -> Dict[str, Any]:
        """Per-target replication lag / RPO / copy counters for one app
        ({} when no replicator is attached or the app is not replicated)."""
        if self.replicator is None:
            return {}
        return self.replicator.replication_stats(coord_id)

    # ---- scheduling (core/scheduler.py) ----------------------------------
    def attach_scheduler(self, scheduler) -> None:
        """Register this service's GlobalScheduler so it is shut down with
        the service and queryable through the facade."""
        self.scheduler = scheduler

    def scheduler_stats(self) -> Dict[str, Any]:
        """Queue depth / preemption / backfill counters of the attached
        scheduler ({} when none is attached)."""
        if self.scheduler is None:
            return {}
        return self.scheduler.stats()

    # ---- convenience -----------------------------------------------------
    def wait_for_state(self, coord_id: str, state: CoordState,
                       timeout: float = 30.0) -> Coordinator:
        # the safety deadline stays on the wall clock (bounds real test
        # time); the poll pacing goes through the installed clock so a
        # virtual-time run advances instead of wall-sleeping
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            coord = self.db.get(coord_id)
            if coord.state == state:
                return coord
            if coord.state == CoordState.ERROR and state != CoordState.ERROR:
                raise RuntimeError(
                    f"{coord_id} entered ERROR: {coord.error}")
            active_clock().sleep(0.005)
        raise TimeoutError(
            f"{coord_id} did not reach {state.value} in {timeout}s "
            f"(now {self.db.get(coord_id).state.value})")

    def shutdown(self) -> None:
        if self.scheduler is not None:
            self.scheduler.stop()
        if self.replicator is not None:
            self.replicator.stop()
        self.apps.stop_daemons()
        for coord in list(self.db.list()):
            try:
                if coord.state not in (CoordState.TERMINATED,):
                    self.apps.terminate(coord.coord_id)
            except Exception:                      # noqa: BLE001
                pass
        self.provision.close()
