"""Gang-consistent snapshots of multi-VM jobs: quiesce → drain → commit.

The paper's service claims support for "parallel and distributed
computations (e.g. over TCP or InfiniBand)", but a snapshot taken from one
coordinator is only consistent for one VM. This module supplies the
missing distributed cut, following the DMTCP coordinator protocol:

    phase QUIESCE  every rank is paused at an iteration boundary (no rank
                   is mid-send), acknowledged under a per-rank ack timeout
                   with bounded retry/backoff on ``active_clock()``;
    phase DRAIN    with all ranks paused the fabric's in-flight counters
                   are frozen; each rank's channel is drained and the
                   messages become part of the snapshot (channel state),
                   not of any rank's memory — the Chandy-Lamport marker
                   rule made concrete;
    phase SAVE     per-rank shards stream through the parallel data plane
                   into ONE gang image (ckpt/gang.py) …
    phase COMMIT   … which becomes visible atomically with a single
                   COMMITTED marker. All-or-nothing: any rank crash,
                   partition, straggler timeout or storage fault anywhere
                   before the marker aborts the epoch, releases every
                   rank, and leaves the previous committed image
                   untouched.

Every phase boundary probes every rank over the message transport itself
(``channel_probe``): a dead or partitioned rank fails the probe rather
than the barrier hanging on an ack that cannot arrive.

The demo workload (``GangApp``) is an N-rank message-passing computation
whose state carries its own consistency proof: column 1 of the global
state counts messages *sent* from each row, column 0 counts messages
*applied* to each row, and a cut is consistent iff

    sum(state[:,1]) == sum(state[:,0]) + rows(inbox)

— a lost or duplicated in-flight message breaks the equality
(``gang_invariant``). Restore reshards to any rank count: shards are
re-split by ``even_regions`` and drained messages are re-routed to the
rank that owns their target row under the new partition.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.clusters.simulator import TIME_SCALE, ChannelError, sim_sleep
from repro.obs.trace import tracer
from repro.sharding.specs import even_regions
from repro.sim.simtime import active_clock

# Leaf layout of a GangApp snapshot (what save_gang_image receives).
GANG_SHARDED = {"state": 0}
GANG_ROUTED = {"inbox": {"by": "state", "col": 2, "cols": 4}}
STATE_COLS = 2           # col 0: messages applied, col 1: messages sent


class GangBarrierError(RuntimeError):
    """A gang epoch aborted; ``reason`` is the replay-stable cause tag."""

    def __init__(self, msg: str, reason: str):
        super().__init__(msg)
        self.reason = reason


class GangStragglerError(GangBarrierError):
    def __init__(self, msg: str):
        super().__init__(msg, "straggler")


@dataclasses.dataclass(frozen=True)
class BarrierConfig:
    """Fault-tolerance knobs of the two-phase barrier.

    All durations are PAPER-calibrated seconds, the same axis as
    ``GangApp.iter_time_s`` and every simulator cost — so "a rank that
    cannot ack within ~3 iterations is a straggler" stays true under both
    the wall clock and the virtual clock."""
    ack_timeout_s: float = 1.0       # per-rank quiesce-ack wait
    ack_retries: int = 2             # extra waits before declaring straggler
    backoff_s: float = 0.25          # grows linearly per retry


class _Rank:
    """One rank's in-process runtime: state shard + worker thread."""

    def __init__(self, idx: int, vm: Any, row_off: int, n_rows: int):
        self.idx = idx
        self.vm = vm
        self.host_id = vm.host.host_id
        self.row_off = row_off
        self.state = np.zeros((n_rows, STATE_COLS), np.float64)
        self.iteration = 0
        self.seq = 0                         # per-rank send counter
        self.send_failures = 0
        self.lock = threading.Lock()
        self.pause_req = threading.Event()
        self.paused_evt = threading.Event()
        self.release_evt = threading.Event()
        self.pending: List[Tuple] = []       # drained, not yet applied
        self.thread: Optional[threading.Thread] = None

    def apply_rows(self, rows: Sequence[Sequence[float]]) -> None:
        """Deliver message rows (src, seq, dst_row, value) to this shard."""
        with self.lock:
            for m in rows:
                local = int(m[2]) - self.row_off
                if 0 <= local < self.state.shape[0]:
                    self.state[local, 0] += float(m[3])


class GangApp:
    """N-rank message-passing workload over the simulated fabric.

    Implements the ``Application`` protocol so AppManager hosts it like any
    job. The *global* problem size (``global_rows``) is fixed at submission;
    each start splits it over however many VMs the context carries
    (``even_regions``), which is what makes shrink-restore onto fewer
    survivors work without the app noticing.

    Every iteration a rank: delivers received messages, pays ``iter_time_s``
    (scaled by its host's slowdown — stragglers emerge naturally), and
    sends one message to the next rank targeting one of its peer's rows.
    """

    def __init__(self, global_rows: int = 16, n_iters: int = 1_000_000,
                 iter_time_s: float = 0.05,
                 barrier: Optional[BarrierConfig] = None):
        self.global_rows = global_rows
        self.n_iters = n_iters
        self.iter_time_s = iter_time_s
        self.barrier = barrier or BarrierConfig()
        self.ranks: List[_Rank] = []
        self.transport: Any = None
        self.ctx: Any = None
        self.restarts = 0
        self._stop = threading.Event()
        self._poisoned = False

    # -- Application protocol -------------------------------------------
    def start(self, ctx: Any, restore_state: Optional[Any]) -> None:
        self.ctx = ctx
        self.transport = getattr(ctx, "transport", None) or self.transport
        if self.transport is None:
            raise ValueError("GangApp needs a message transport "
                             "(ctx.transport; set by AppManager on "
                             "simulated backends)")
        n = len(ctx.vms)
        if n < 1:
            raise ValueError("GangApp needs at least one VM")
        if restore_state is not None and len(restore_state) != n:
            raise ValueError(f"restore carries {len(restore_state)} rank "
                             f"trees for {n} VMs")
        self._stop.clear()
        self._poisoned = False
        regions = even_regions(self.global_rows, n)
        self.ranks = []
        for r, (off, length) in enumerate(regions):
            rk = _Rank(r, ctx.vms[r], off, length)
            if restore_state is not None:
                tree = restore_state[r]
                rk.state = np.array(tree["state"], np.float64).reshape(
                    length, STATE_COLS)
                rk.iteration = int(tree["iteration"])
                # in-flight messages of the cut are *delivered* on restore:
                # applying them here is the receive the crash interrupted
                rk.apply_rows(np.asarray(tree.get("inbox", ()),
                                         np.float64).reshape(-1, 4))
            self.ranks.append(rk)
        if restore_state is not None:
            self.restarts += 1
        for rk in self.ranks:
            self.transport.channel_open(rk.host_id)
        for rk in self.ranks:
            rk.thread = threading.Thread(target=self._run_rank, args=(rk,),
                                         daemon=True)
            rk.thread.start()

    def _run_rank(self, rk: _Rank) -> None:
        clk = active_clock()
        n = len(self.ranks)
        while not self._stop.is_set():
            if rk.pause_req.is_set():        # quiesced at a boundary —
                rk.paused_evt.set()          # never mid-send
                while rk.pause_req.is_set() and not self._stop.is_set():
                    # paper-calibrated poll (×TIME_SCALE wall → 1 virtual
                    # second): a wall-tuned timeout here would race virtual
                    # time forward 200s per wake while the save phase does
                    # CPU-bound upload work, dwarfing the real barrier cost
                    clk.wait(rk.release_evt, 1.0 * TIME_SCALE)
                rk.paused_evt.clear()
                rk.release_evt.clear()
                continue
            if rk.iteration >= self.n_iters:
                clk.wait(rk.pause_req, 0.5)  # done: stay barrier-responsive
                continue
            rk.apply_rows(self.transport.channel_recv(rk.host_id))
            sim_sleep(self.iter_time_s * rk.vm.host.slowdown)
            if n > 1:
                peer = self.ranks[(rk.idx + 1) % n]
                dst_row = peer.row_off + rk.iteration % peer.state.shape[0]
                msg = (float(rk.idx), float(rk.seq), float(dst_row), 1.0)
                try:
                    self.transport.channel_send(rk.host_id, peer.host_id,
                                                msg)
                except ChannelError:
                    rk.send_failures += 1    # peer dead: message dropped
                else:                        # BEFORE it was ever in flight,
                    with rk.lock:            # so the sent-ledger (col 1)
                        src = rk.iteration % rk.state.shape[0]   # skips it
                        rk.state[src, 1] += 1.0
                    rk.seq += 1
            rk.iteration += 1

    def checkpoint_state(self) -> Dict[str, Any]:
        """Protocol fallback (NOT gang-consistent — use GangCoordinator)."""
        return {"iteration": self.min_iteration()}

    def healthy(self) -> bool:
        return not self._poisoned

    def stop(self) -> None:
        self._stop.set()
        for rk in self.ranks:
            rk.release_evt.set()
            if rk.thread is not None:
                rk.thread.join(timeout=5)
        if self.transport is not None:
            for rk in self.ranks:
                try:
                    self.transport.channel_close(rk.host_id)
                except Exception:
                    pass

    def is_done(self) -> bool:
        return bool(self.ranks) and self.min_iteration() >= self.n_iters

    def progress(self) -> float:
        return self.min_iteration() / max(self.n_iters, 1)

    # -- helpers ---------------------------------------------------------
    def min_iteration(self) -> int:
        return min((rk.iteration for rk in self.ranks), default=0)

    def poison(self) -> None:
        self._poisoned = True


def gang_invariant(rank_trees: Sequence[Dict[str, Any]]) -> Dict[str, float]:
    """Conservation check of a gang cut: every message ever sent is either
    applied to some row or sitting in some rank's drained inbox."""
    sent = applied = inflight = 0.0
    for t in rank_trees:
        st = np.asarray(t["state"], np.float64).reshape(-1, STATE_COLS)
        applied += float(st[:, 0].sum())
        sent += float(st[:, 1].sum())
        inflight += float(np.asarray(t.get("inbox", ()),
                                     np.float64).reshape(-1, 4)[:, 3].sum())
    return {"sent": sent, "applied": applied, "inflight": inflight,
            "consistent": float(sent == applied + inflight)}


class GangCoordinator:
    """Drives the fault-tolerant two-phase barrier over one GangApp.

    ``save_fn(step, rank_trees) -> manifest`` is the storage half
    (CheckpointManager.save_gang) — this class owns only the protocol.

    Chaos hooks: ``arm(phase, fn)`` registers a one-shot action executed
    deterministically when the barrier ENTERS that phase ("quiesce" /
    "drain" / "save" / "commit") — fault injection keyed to protocol
    position, not to a timing race, which is what makes the seeded chaos
    scenarios replay bit-for-bit.

    The barrier trace records wall-free tuples for the same reason.
    """

    PHASES = ("quiesce", "drain", "save", "commit")

    def __init__(self, app: GangApp, transport: Any,
                 save_fn: Callable[[int, List[Dict[str, Any]]], Any],
                 trace_id: str = ""):
        self.app = app
        self.transport = transport
        self.save_fn = save_fn
        self.trace_id = trace_id
        self.cfg = app.barrier
        self.epochs_started = 0
        self.epochs_committed = 0
        self.aborts = 0
        self.last_abort_reason: Optional[str] = None
        self._trace: List[tuple] = []
        self._armed: Dict[str, List[Callable[[], None]]] = {}
        self._lock = threading.Lock()

    def rebind(self, app: GangApp, transport: Any) -> None:
        """Point at the restarted app instance (same job, new VMs)."""
        self.app = app
        self.transport = transport
        self.cfg = app.barrier

    def arm(self, phase: str, fn: Callable[[], None]) -> None:
        if phase not in self.PHASES:
            raise ValueError(f"unknown barrier phase {phase!r}")
        self._armed.setdefault(phase, []).append(fn)

    def barrier_trace(self) -> List[tuple]:
        with self._lock:
            return list(self._trace)

    def _tr(self, step: int, tag: str, detail: str = "") -> None:
        """Append one wall-free trace tuple and mirror it into the span
        tracer. The local list stays the replay-exact source of truth
        (the tracer has a drop cap; barrier_trace() must not)."""
        self._trace.append((self.trace_id, step, tag, detail))
        tracer().event(f"gang/{tag}", cat="gang", trace_id=self.trace_id,
                       args={"step": step, "detail": detail})

    def stats(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id,
                "epochs_started": self.epochs_started,
                "epochs_committed": self.epochs_committed,
                "aborts": self.aborts,
                "last_abort_reason": self.last_abort_reason}

    # -- protocol --------------------------------------------------------
    def snapshot(self, step: int) -> Any:
        """One gang epoch. Returns the committed manifest, or raises
        GangBarrierError having released every surviving rank; a failed
        epoch leaves the previous committed image untouched (the commit
        marker is the only externally-visible effect)."""
        with self._lock, tracer().span(
                "gang/epoch", cat="gang", trace_id=self.trace_id,
                args={"step": step}):
            self.epochs_started += 1
            self._tr(step, "begin")
            try:
                with self._phase_span("quiesce", step):
                    self._enter("quiesce", step)
                    self._quiesce(step)
                with self._phase_span("drain", step):
                    self._enter("drain", step)
                    self._drain(step)
                with self._phase_span("save", step):
                    self._enter("save", step)
                    trees = self._collect()
                    manifest = self.save_fn(step, trees)
                with self._phase_span("commit", step):
                    self._enter("commit", step)
                    self.epochs_committed += 1
                    self._tr(step, "committed",
                             f"ranks={len(self.app.ranks)}")
                return manifest
            except GangBarrierError as e:
                self._abort(step, e.reason)
                raise
            except ChannelError as e:
                self._abort(step, "partition_or_crash")
                raise GangBarrierError(str(e), "partition_or_crash") from e
            except Exception as e:
                self._abort(step, "store_fault")
                raise GangBarrierError(str(e), "store_fault") from e
            finally:
                self._release()

    def _phase_span(self, phase: str, step: int):
        return tracer().span(f"gang/{phase}", cat="gang",
                             trace_id=self.trace_id, args={"step": step})

    def _enter(self, phase: str, step: int) -> None:
        self._tr(step, "phase", phase)
        for fn in self._armed.pop(phase, ()):   # one-shot, deterministic
            fn()

    def _probe(self, rk: _Rank) -> None:
        self.transport.channel_probe(rk.host_id)

    def _quiesce(self, step: int) -> None:
        clk = active_clock()
        for rk in self.app.ranks:
            rk.pause_req.set()
        # clk.wait takes wall-tuned timeouts; BarrierConfig is
        # paper-calibrated, so map through TIME_SCALE exactly like
        # sim_sleep does (under a SimClock the two cancel into virtual
        # seconds; under the wall clock they compress identically)
        for rk in self.app.ranks:
            for attempt in range(self.cfg.ack_retries + 1):
                acked = clk.wait(rk.paused_evt,
                                 self.cfg.ack_timeout_s * TIME_SCALE)
                # probe AFTER the wait: an in-process ack from a rank the
                # fabric can't reach is not an ack (partition semantics)
                self._probe(rk)
                if acked:
                    self._tr(step, "ack", f"r{rk.idx}/{attempt}")
                    break
                self._tr(step, "retry", f"r{rk.idx}/{attempt}")
                sim_sleep(self.cfg.backoff_s * (attempt + 1))
            else:
                raise GangStragglerError(
                    f"rank {rk.idx} missed {self.cfg.ack_retries + 1} "
                    f"quiesce acks of {self.cfg.ack_timeout_s}s")

    def _drain(self, step: int) -> None:
        # every rank is paused ⇒ the in-flight set is frozen; whatever is
        # in a channel now belongs to the cut as channel state
        for rk in self.app.ranks:
            self._probe(rk)
            rows = sorted(tuple(m) for m in
                          self.transport.channel_recv(rk.host_id))
            rk.pending = list(rows)
            self._tr(step, "drain", f"r{rk.idx}={len(rows)}")
        left = self.transport.channel_inflight(
            [rk.host_id for rk in self.app.ranks])
        if left:
            raise GangBarrierError(
                f"{left} messages still in flight after drain", "drain")

    def _collect(self) -> List[Dict[str, Any]]:
        it = self.app.min_iteration()
        trees = []
        for rk in self.app.ranks:
            inbox = np.array([list(m) for m in rk.pending],
                             np.float64).reshape(-1, 4)
            with rk.lock:
                trees.append({"state": rk.state.copy(), "iteration": it,
                              "inbox": inbox})
        return trees

    def _abort(self, step: int, reason: str) -> None:
        self.aborts += 1
        self.last_abort_reason = reason
        self._tr(step, "abort", reason)

    def _release(self) -> None:
        # commit or abort, drained messages were RECEIVED off the fabric:
        # deliver them so no message is lost to the live run either
        for rk in self.app.ranks:
            if rk.pending:
                rk.apply_rows(rk.pending)
                rk.pending = []
            rk.pause_req.clear()
            rk.release_evt.set()
