"""Deterministic fault injection for the recovery control plane.

The paper's core promise is *survival*: the service "detects when
long-running jobs either fail or incur exceptionally low performance, and
proactively suspends the job" (§1, §6.3). This module turns that claim into
a replayable, measurable scenario suite:

  * :class:`FaultSchedule` — a seeded, typed list of fault events (VM crash,
    host slowdown/straggler, app health-hook failure, transient storage
    put/get errors, monitor partition). Same seed → same schedule, always.
  * :class:`ChaosController` — applies a schedule to a live
    :class:`~repro.core.service.CACSService` running on the cluster
    simulator, on a virtual clock (wall time / ``TIME_SCALE``), waiting for
    each fault's recovery to settle so the resulting *event trace* —
    (fault, target, outcome, final state) per event, plus every simulator
    fault hook firing — replays identically from the seed.
  * per-fault :class:`FaultOutcome` — detection latency, restore time and
    end-to-end MTTR, measured from the coordinator's state history (the
    §6.3 case-1/case-2 split: VM failure → replace + restore; app failure →
    in-place restart; straggler → proactive suspend, then resume).

Fault classes and what each one proves:

  ``vm_crash``           IaaS host dies. Native backends (Snooze) notify
                         immediately; agent backends (OpenStack) detect via
                         the broadcast tree. Recovery: replace + restore.
  ``monitor_partition``  host alive but unreachable by the monitoring tree.
                         No native notification ever fires — only the
                         tree's consecutive-unreachable fallback catches it.
  ``app_failure``        the application health hook *raises* (a broken
                         user hook must read as an unhealthy app, not kill
                         the monitor thread). Recovery: in-place restart.
  ``host_slowdown``      straggler. Monitor z-scores it; the app manager
                         proactively suspends to stable storage; the
                         controller (or the GlobalScheduler) resumes it.
  ``storage_put_fault``  transient store error mid-save. The COMMITTED
                         protocol must leave the previous image loadable
                         and the torn step invisible.
  ``storage_get_fault``  transient store error mid-restore, injected under
                         an app failure. The recovery retry loop absorbs it.

Used by `tests/test_chaos.py` (replay determinism + recovery-race
regression suite), `benchmarks/fault_recovery.py` (MTTR per fault class ×
monitoring path) and `examples/fault_tolerance.py` (seeded storyline).
"""
from __future__ import annotations

import dataclasses
import enum
import random
from typing import Any, Dict, List, Optional, Tuple

from repro.ckpt.storage import ChaosStorageError, FaultyStore, InMemoryStore
from repro.clusters.simulator import TIME_SCALE
from repro.sim.simtime import active_clock
from repro.core.coordinator import ASR, CheckpointPolicy, CoordState


class FaultKind(str, enum.Enum):
    VM_CRASH = "vm_crash"
    HOST_SLOWDOWN = "host_slowdown"
    APP_FAILURE = "app_failure"
    STORAGE_PUT_FAULT = "storage_put_fault"
    STORAGE_GET_FAULT = "storage_get_fault"
    MONITOR_PARTITION = "monitor_partition"
    # whole-cloud outage: every host of the backend partitioned at once
    # AND allocation denied — unrecoverable on the home cloud by design;
    # the expected outcome is cross-cloud failover (core/replication.py),
    # not a same-cloud recovery cycle. Appended last so pre-existing
    # seeded schedules (rng.choice over the earlier kinds) replay
    # unchanged.
    CLOUD_OUTAGE = "cloud_outage"
    # gang-barrier faults: armed as one-shot hooks on the job's
    # GangCoordinator and fired at a protocol phase boundary — the fault
    # lands at an exact protocol position, not a timing race, which is
    # what makes mid-barrier chaos replayable. Each must abort the epoch
    # all-or-nothing: no torn gang image, previous image restorable,
    # every rank released. Appended after CLOUD_OUTAGE for the same
    # seed-replay reason.
    GANG_BARRIER_CRASH = "gang_barrier_crash"
    GANG_BARRIER_PARTITION = "gang_barrier_partition"
    GANG_BARRIER_STRAGGLER = "gang_barrier_straggler"
    GANG_BARRIER_PUT_FAULT = "gang_barrier_put_fault"


# kinds whose outcome is a full recovery cycle back to RUNNING
_RECOVERY_KINDS = (FaultKind.VM_CRASH, FaultKind.APP_FAILURE,
                   FaultKind.MONITOR_PARTITION, FaultKind.STORAGE_GET_FAULT)

# gang-barrier kinds: only meaningful for a gang job (asr.gang=True);
# settled by _settle_gang, never part of the default generate pool
GANG_KINDS = (FaultKind.GANG_BARRIER_CRASH, FaultKind.GANG_BARRIER_PARTITION,
              FaultKind.GANG_BARRIER_STRAGGLER,
              FaultKind.GANG_BARRIER_PUT_FAULT)

# kinds a single-cloud scenario can survive — the default pool for
# FaultSchedule.generate (CLOUD_OUTAGE needs a standby cloud to end well,
# and gang kinds need a gang job, so both must be opted into explicitly;
# keeping them out also keeps rng.choice draws identical for old seeds)
SINGLE_CLOUD_KINDS = tuple(k for k in FaultKind
                           if k is not FaultKind.CLOUD_OUTAGE
                           and k not in GANG_KINDS)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One typed fault, scheduled at a virtual-time offset."""
    at_s: float                  # virtual seconds after scenario start
    kind: FaultKind
    vm_index: int = 0            # which of the coordinator's VMs to hit
    slowdown: float = 20.0       # HOST_SLOWDOWN: step-time multiplier
    n_ops: int = 1               # STORAGE_*: how many ops fail
    n_vms: int = 1               # MONITOR_PARTITION: subtree size
    phase: str = "drain"         # GANG_BARRIER_*: protocol phase to hit

    def label(self) -> str:
        return f"{self.kind.value}@{self.at_s:.1f}s/vm{self.vm_index}"


@dataclasses.dataclass
class FaultSchedule:
    """A seeded, replayable fault storyline.

    ``generate`` derives everything from ``random.Random(seed)`` — no wall
    clock, no global state — so the same seed always yields the same
    events, which is the first half of the determinism contract (the
    second half is the controller waiting for each recovery to settle).
    """
    seed: int
    events: List[FaultEvent]

    @classmethod
    def generate(cls, seed: int, n_events: int = 5, *,
                 horizon_s: float = 40.0, n_vms: int = 4,
                 kinds: Tuple[FaultKind, ...] = SINGLE_CLOUD_KINDS,
                 min_gap_s: float = 2.0) -> "FaultSchedule":
        rng = random.Random(seed)
        times = sorted(rng.uniform(1.0, horizon_s) for _ in range(n_events))
        # enforce a minimum gap so two faults never target the same
        # recovery window (the controller settles between events anyway)
        for i in range(1, len(times)):
            times[i] = max(times[i], times[i - 1] + min_gap_s)
        events = []
        for t in times:
            kind = rng.choice(list(kinds))
            events.append(FaultEvent(
                at_s=round(t, 3), kind=kind,
                vm_index=rng.randrange(n_vms),
                slowdown=float(rng.choice((10.0, 20.0, 50.0))),
                # get faults must stay within the recovery retry budget
                n_ops=rng.randint(1, 2),
                n_vms=rng.randint(1, max(1, n_vms // 2))))
        return cls(seed=seed, events=events)

    @classmethod
    def storyline(cls, seed: int = 42, n_vms: int = 4) -> "FaultSchedule":
        """A curated multi-fault storyline touching every fault class, with
        seed-derived jitter on targets and timing."""
        rng = random.Random(seed)
        j = lambda: round(rng.uniform(0.0, 1.5), 3)      # noqa: E731
        v = lambda: rng.randrange(n_vms)                  # noqa: E731
        return cls(seed=seed, events=[
            FaultEvent(2.0 + j(), FaultKind.VM_CRASH, vm_index=v()),
            FaultEvent(8.0 + j(), FaultKind.STORAGE_PUT_FAULT, n_ops=2),
            FaultEvent(12.0 + j(), FaultKind.APP_FAILURE),
            FaultEvent(18.0 + j(), FaultKind.MONITOR_PARTITION,
                       vm_index=v(), n_vms=2),
            FaultEvent(24.0 + j(), FaultKind.STORAGE_GET_FAULT, n_ops=1),
            FaultEvent(30.0 + j(), FaultKind.HOST_SLOWDOWN, vm_index=v(),
                       slowdown=50.0),
        ])

    def describe(self) -> List[str]:
        return [e.label() for e in self.events]


@dataclasses.dataclass
class FaultOutcome:
    """What one injected fault did to the control plane (wall seconds)."""
    event: FaultEvent
    ok: bool
    final_state: str
    detection_s: Optional[float] = None   # inject → leave RUNNING
    restore_s: Optional[float] = None     # leave RUNNING → back up
    mttr_s: Optional[float] = None        # inject → back up (end to end)
    recoveries: int = 0
    detail: str = ""
    trace_id: str = ""                    # job trace id (deterministic)
    # which watchdog caught it: "telemetry" (low-performance EWMA),
    # "monitor" (liveness/straggler path), or "" (not detection-driven)
    detected_by: str = ""

    def trace_key(self) -> Tuple:
        """Wall-time-free identity of this outcome, for replay equality.

        Only the first detail token is part of the identity: for storage
        faults the trailing tokens record *which* save absorbed the fault
        (explicit trigger vs periodic daemon), which is scheduling, not
        outcome."""
        return (self.event.kind.value, self.event.vm_index, self.ok,
                self.final_state, self.detail.split(";")[0])


@dataclasses.dataclass
class ScenarioResult:
    seed: int
    trace: List[Tuple]                    # outcome trace keys, in order
    sim_faults: List[Tuple[str, str, float]]   # (kind, host_id, value)
    outcomes: List[FaultOutcome]
    final_state: str
    recoveries: int
    events_deduped: int
    partition_fallbacks: int

    @property
    def all_ok(self) -> bool:
        return all(o.ok for o in self.outcomes)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed, "trace": [list(t) for t in self.trace],
            "final_state": self.final_state, "recoveries": self.recoveries,
            "events_deduped": self.events_deduped,
            "partition_fallbacks": self.partition_fallbacks,
            "all_ok": self.all_ok,
            "outcomes": [{
                "fault": o.event.kind.value, "ok": o.ok,
                "final_state": o.final_state, "detail": o.detail,
                "trace_id": o.trace_id, "detected_by": o.detected_by,
                "detection_s": o.detection_s, "restore_s": o.restore_s,
                "mttr_s": o.mttr_s} for o in self.outcomes],
        }


class VirtualClock:
    """Paper-seconds view anchored at construction over the *installed*
    clock (repro.sim).  Under the default WallClock this is ``TIME_SCALE``
    wall seconds per virtual second, matching ``sim_sleep``'s compression
    (unchanged historical behavior); under a SimClock the virtual axis is
    already paper seconds, so sleeps jump instantly.  Event offsets in a
    schedule are paper-calibrated (virtual) seconds either way."""

    def __init__(self, time_scale: Optional[float] = None):
        self._clk = active_clock()
        # native seconds of the underlying clock per virtual second
        self.scale = self._clk.scale if time_scale is None else time_scale
        self._t0 = self._clk.now()

    def now(self) -> float:
        return (self._clk.now() - self._t0) / self.scale

    def sleep_until(self, t_virtual: float) -> None:
        delta = t_virtual - self.now()
        if delta > 0:
            self._clk.sleep_until(self._clk.now() + delta * self.scale)


class ChaosHealthHook:
    """Armable application health hook.

    Normally reports healthy; ``arm(n)`` makes the next *n* calls RAISE —
    the harshest form of "app health-hook failure" (a hook returning False
    is polite; real user hooks crash). The monitor must translate the
    raise into an app_failure report, not die."""

    def __init__(self):
        self._armed = 0

    def arm(self, n: int = 1) -> None:
        self._armed = max(0, int(n))

    def __call__(self) -> bool:
        if self._armed > 0:
            self._armed -= 1
            raise RuntimeError("injected health-hook failure")
        return True


class ChaosController:
    """Applies a FaultSchedule to one coordinator on a live service.

    Events run in virtual-time order; after each fault the controller
    waits for the recovery to settle (back to RUNNING, or SUSPENDED→
    resumed for stragglers) before the next event, which is what makes
    the outcome trace replayable. Detection/restore/MTTR are read from
    the coordinator's transition history (wall-clock timestamps)."""

    def __init__(self, service, coord_id: str, backend, schedule: FaultSchedule,
                 *, store: Optional[FaultyStore] = None,
                 hook: Optional[ChaosHealthHook] = None,
                 settle_timeout_s: float = 60.0,
                 resume_stragglers: bool = True,
                 failover=None, scheduler=None):
        self.service = service
        self.coord_id = coord_id
        self.backend = backend
        self.schedule = schedule
        self.store = store
        self.hook = hook
        self.settle_timeout_s = settle_timeout_s
        self.resume_stragglers = resume_stragglers
        # optional replication.FailoverController: cloud_outage events then
        # settle on the standby coming up instead of on primary recovery
        self.failover = failover
        # optional GlobalScheduler: kicked after every injection, and
        # cloud_outage then settles on the scheduler requeuing the job and
        # backfilling it onto a surviving cloud (same coordinator record,
        # unlike the FailoverController's standby-service restart)
        self.scheduler = scheduler
        self.outcomes: List[FaultOutcome] = []
        self.sim_faults: List[Tuple[str, str, float]] = []
        self._gang_heal = None         # undo for the current gang fault
        backend.sim.on_fault(
            lambda kind, host, value: self.sim_faults.append(
                (kind, host, value)))

    # ---- driving -------------------------------------------------------
    def run(self) -> List[FaultOutcome]:
        clock = VirtualClock()
        for ev in sorted(self.schedule.events, key=lambda e: e.at_s):
            clock.sleep_until(ev.at_s)
            self._apply(ev)
        return self.outcomes

    def _coord(self):
        return self.service.db.get(self.coord_id)

    def _wait(self, pred, timeout: Optional[float] = None) -> bool:
        # settle polling rides the installed clock: the deadline elapses in
        # virtual time under a SimClock (the old wall-clock loop was a
        # leak that kept chaos runs pinned to real seconds)
        clk = active_clock()
        deadline = clk.now() + clk.from_wall(timeout or self.settle_timeout_s)
        while clk.now() < deadline:
            if pred():
                return True
            clk.sleep(0.002)
        return False

    def _apply(self, ev: FaultEvent) -> None:
        coord = self._coord()
        if not self._wait(lambda: coord.state == CoordState.RUNNING):
            self.outcomes.append(FaultOutcome(
                ev, ok=False, final_state=coord.state.value,
                detail="not RUNNING at inject time",
                trace_id=coord.trace_id))
            return
        h0 = len(coord.history)
        rec0 = coord.recoveries
        t_inj = active_clock().timestamp()
        try:
            apply = getattr(self, f"_inject_{ev.kind.value}")
            detail = apply(ev, coord) or ""
        except Exception as e:                     # noqa: BLE001
            self.outcomes.append(FaultOutcome(
                ev, ok=False, final_state=coord.state.value,
                detail=f"inject failed: {type(e).__name__}",
                trace_id=coord.trace_id))
            return
        if self.scheduler is not None:
            self.scheduler.kick("chaos")
        self._settle(ev, coord, h0, rec0, t_inj, detail)

    # ---- injectors (one per fault class) --------------------------------
    def _inject_vm_crash(self, ev: FaultEvent, coord) -> str:
        vm = coord.vms[ev.vm_index % len(coord.vms)]
        self.backend.sim.fail_host(vm.host.host_id)
        return "crash"

    def _inject_monitor_partition(self, ev: FaultEvent, coord) -> str:
        n = max(1, min(ev.n_vms, len(coord.vms)))
        start = ev.vm_index % len(coord.vms)
        for i in range(n):
            vm = coord.vms[(start + i) % len(coord.vms)]
            self.backend.sim.partition_host(vm.host.host_id)
        return f"partition:{n}"

    def _inject_app_failure(self, ev: FaultEvent, coord) -> str:
        if self.hook is not None:
            self.hook.arm(1)
            return "hook-raise"
        app = coord.app
        if hasattr(app, "poison"):
            app.poison()
            return "poison"
        raise ValueError("no ChaosHealthHook and app has no poison()")

    def _inject_cloud_outage(self, ev: FaultEvent, coord) -> str:
        self.backend.sim.cloud_outage()
        return "outage"

    def _inject_host_slowdown(self, ev: FaultEvent, coord) -> str:
        vm = coord.vms[ev.vm_index % len(coord.vms)]
        self.backend.sim.degrade_host(vm.host.host_id, ev.slowdown)
        return f"slowdown:{ev.slowdown:g}"

    def _inject_storage_put_fault(self, ev: FaultEvent, coord) -> str:
        if self.store is None:
            raise ValueError("storage faults need a FaultyStore")
        self.store.arm_put_errors(ev.n_ops)
        return f"put-faults:{ev.n_ops}"

    def _gang_ctl(self):
        g = self.service.apps.gang(self.coord_id)
        if g is None:
            raise ValueError("gang faults need a gang job (asr.gang=True) "
                             "with at least one snapshot taken")
        return g

    def _inject_gang_barrier_crash(self, ev: FaultEvent, coord) -> str:
        g = self._gang_ctl()
        hid = coord.vms[ev.vm_index % len(coord.vms)].host.host_id
        g.arm(ev.phase, lambda: self.backend.sim.fail_host(hid))
        return f"crash@{ev.phase}"

    def _inject_gang_barrier_partition(self, ev: FaultEvent, coord) -> str:
        g = self._gang_ctl()
        hid = coord.vms[ev.vm_index % len(coord.vms)].host.host_id
        g.arm(ev.phase, lambda: self.backend.sim.partition_host(hid))
        return f"partition@{ev.phase}"

    def _inject_gang_barrier_straggler(self, ev: FaultEvent, coord) -> str:
        # a degrade armed at quiesce entry would land too late — the rank
        # checks the pause flag before each sleep and would still ack in
        # time. Degrade now and let the rank ENTER its slowed iteration
        # before the settle phase raises the barrier; only a slowdown
        # that outsleeps the whole ack budget (timeout × retries +
        # backoffs) then reads as a straggler.
        self._gang_ctl()                   # validate: gang job, primed
        hid = coord.vms[ev.vm_index % len(coord.vms)].host.host_id
        self.backend.sim.degrade_host(hid, ev.slowdown)
        active_clock().paper_sleep(1.0)
        self._gang_heal = lambda: self.backend.sim.degrade_host(hid, 1.0)
        return f"straggler:{ev.slowdown:g}"

    def _inject_gang_barrier_put_fault(self, ev: FaultEvent, coord) -> str:
        if self.store is None:
            raise ValueError("storage faults need a FaultyStore")
        g = self._gang_ctl()
        rank = ev.vm_index % len(coord.vms)
        scope = f"{coord.ckpt_prefix}/cas/r{rank}-"
        g.arm("save", lambda: self.store.arm_put_errors(ev.n_ops,
                                                        key_prefix=scope))
        return f"put-faults:r{rank}x{ev.n_ops}"

    def _inject_storage_get_fault(self, ev: FaultEvent, coord) -> str:
        if self.store is None:
            raise ValueError("storage faults need a FaultyStore")
        # a get fault only bites on a restore path: pair it with an app
        # failure so the recovery's restore absorbs it via retries
        self.store.arm_get_errors(ev.n_ops)
        if self.hook is not None:
            self.hook.arm(1)
        elif hasattr(coord.app, "poison"):
            coord.app.poison()
        return f"get-faults:{ev.n_ops}"

    # ---- settlement + measurement ---------------------------------------
    def _settle(self, ev: FaultEvent, coord, h0: int, rec0: int,
                t_inj: float, detail: str) -> None:
        if ev.kind == FaultKind.STORAGE_PUT_FAULT:
            self._settle_put_fault(ev, coord, detail)
            return
        if ev.kind == FaultKind.CLOUD_OUTAGE:
            self._settle_cloud_outage(ev, coord, h0, t_inj, detail)
            return
        if ev.kind in GANG_KINDS:
            self._settle_gang(ev, coord, h0, rec0, t_inj, detail)
            return
        detected_by = ""
        if ev.kind == FaultKind.HOST_SLOWDOWN:
            ok_end = self._wait(
                lambda: coord.state == CoordState.SUSPENDED)
            # which watchdog pulled the trigger: the suspend reason rides
            # on the SUSPENDED history entry ("low_performance" = the
            # telemetry EWMA detector, "straggler" = liveness heartbeat)
            reason = next((r[2] for r in coord.history[h0:]
                           if r[1] == "SUSPENDED" and len(r) > 2 and r[2]),
                          "")
            detected_by = ("telemetry" if reason == "low_performance"
                           else ("monitor" if reason else ""))
            if ok_end and self.resume_stragglers:
                self.service.apps.resume(self.coord_id, block=True)
                ok_end = coord.state == CoordState.RUNNING
        else:
            ok_end = self._wait(
                lambda: (coord.recoveries > rec0
                         and coord.state == CoordState.RUNNING))
        detection, restore, mttr = self._measure(ev, coord, h0, t_inj)
        self.outcomes.append(FaultOutcome(
            ev, ok=bool(ok_end), final_state=coord.state.value,
            detection_s=detection, restore_s=restore, mttr_s=mttr,
            recoveries=coord.recoveries, detail=detail,
            trace_id=coord.trace_id, detected_by=detected_by))

    def _settle_cloud_outage(self, ev: FaultEvent, coord, h0: int,
                             t_inj: float, detail: str) -> None:
        """A whole-cloud outage must fail conclusively on the home cloud
        (recovery exhausts into ERROR — no capacity exists), and, when a
        FailoverController is attached, end with the job RUNNING on a
        standby cloud. MTTR is then injection → standby RUNNING."""
        def primary_failed() -> bool:
            return any(s == "ERROR" for _, s, *_ in coord.history[h0:])
        ok = self._wait(primary_failed)
        t_error = next((t for t, s, *_ in coord.history[h0:]
                        if s == "ERROR"), None)
        detection = (None if t_error is None
                     else max(0.0, t_error - t_inj))
        restore = mttr = None
        if self.scheduler is not None and self.failover is None:
            # scheduler-managed job: the GlobalScheduler requeues it off
            # the dead cloud and backfills it onto a surviving one —
            # settle on the SAME coordinator coming back up
            got = self._wait(lambda: coord.state == CoordState.RUNNING)
            ok = ok and got
            if got:
                detail += f";backfill={coord.asr.backend}"
                t_up = next((t for t, s, *_ in reversed(coord.history)
                             if s == "RUNNING"), None)
                restore = (None if t_error is None or t_up is None
                           else max(0.0, t_up - t_error))
                mttr = None if t_up is None else max(0.0, t_up - t_inj)
        elif self.failover is not None:
            got = self._wait(lambda: self.coord_id in self.failover.results)
            res = self.failover.results.get(self.coord_id)
            ok = ok and got and res is not None and res.ok
            if res is not None and res.ok:
                detail += f";standby={res.target};step={res.step}"
                restore = res.restart_s
                mttr = None if detection is None or res.mttr_s is None \
                    else detection + res.mttr_s
            elif res is not None:
                detail += f";failover_error={res.error}"
        self.outcomes.append(FaultOutcome(
            ev, ok=bool(ok), final_state=coord.state.value,
            detection_s=detection, restore_s=restore, mttr_s=mttr,
            recoveries=coord.recoveries, detail=detail,
            trace_id=coord.trace_id))

    def _settle_gang(self, ev: FaultEvent, coord, h0: int, rec0: int,
                     t_inj: float, detail: str) -> None:
        """Armed gang faults fire inside the next snapshot's barrier:
        trigger it, prove the epoch aborted all-or-nothing (the torn step
        stays invisible, the previous committed gang image is still
        restorable at full rank count), then prove the plane heals — for
        crash/partition through the normal recovery cycle (replace +
        gang restore), otherwise by the very next snapshot committing."""
        g = self._gang_ctl()
        aborts0, commits0 = g.aborts, g.epochs_committed
        latest0 = self.service.ckpt.latest(coord)
        snapshot_failed = False
        try:
            self.service.trigger_checkpoint(self.coord_id)
        except Exception:                      # noqa: BLE001
            snapshot_failed = True
        if self.store is not None:
            self.store.disarm()
        heal, self._gang_heal = self._gang_heal, None
        ok = snapshot_failed and g.aborts == aborts0 + 1
        note = f"abort={g.last_abort_reason}"
        try:
            latest1 = self.service.ckpt.latest(coord)
            if latest1 != latest0:
                ok, note = False, note + ";torn image visible"
            elif latest0 is not None:
                n = len(coord.vms) or coord.asr.n_vms
                self.service.ckpt.load_gang(coord, latest0, n_ranks=n)
        except Exception as e:                 # noqa: BLE001
            ok, note = False, note + f";restore failed: {type(e).__name__}"
        if ev.kind in (FaultKind.GANG_BARRIER_CRASH,
                       FaultKind.GANG_BARRIER_PARTITION):
            # the fabric fault outlives the barrier: the monitor must now
            # drive a normal recovery cycle off the intact previous image
            got = self._wait(lambda: (coord.recoveries > rec0
                                      and coord.state == CoordState.RUNNING))
            ok = ok and got
            if not got:
                note += ";recovery failed"
        else:
            if heal is not None:
                heal()
            # healing a degraded host does not shorten a slow sleep the
            # rank already entered (its duration was computed at sleep
            # start), so the first resnapshot may still hit a stale
            # straggler — retry across that drain window
            err: Optional[Exception] = None
            for _ in range(4):
                try:
                    self.service.trigger_checkpoint(self.coord_id)
                    err = None
                    break
                except Exception as e:         # noqa: BLE001
                    err = e
                    active_clock().paper_sleep(5.0)
            if err is not None:
                ok, note = (False,
                            note + f";resnapshot failed: {type(err).__name__}")
            elif g.epochs_committed <= commits0:
                ok, note = False, note + ";resnapshot did not commit"
        detection, restore, mttr = self._measure(ev, coord, h0, t_inj)
        self.outcomes.append(FaultOutcome(
            ev, ok=bool(ok), final_state=coord.state.value,
            detection_s=detection, restore_s=restore, mttr_s=mttr,
            recoveries=coord.recoveries,
            detail=f"{detail};{note}", trace_id=coord.trace_id))

    def _settle_put_fault(self, ev: FaultEvent, coord, detail: str) -> None:
        """A save must fail without tearing anything: force a checkpoint
        into the armed faults, then prove the newest COMMITTED image still
        restores and a later save succeeds."""
        save_failed = False
        try:
            self.service.trigger_checkpoint(self.coord_id)
        except (ChaosStorageError, IOError):
            save_failed = True
        self.store.disarm()
        ok = True
        note = "previous image intact"
        try:
            latest = self.service.ckpt.latest(coord)
            if latest is not None:
                self.service.ckpt.load(coord, latest)
            # the plane must be healthy again: next save commits
            step = self.service.trigger_checkpoint(self.coord_id)
            if latest is not None and step <= latest:
                ok, note = False, "step counter regressed"
        except Exception as e:                     # noqa: BLE001
            ok, note = False, f"restore failed: {type(e).__name__}"
        self.outcomes.append(FaultOutcome(
            ev, ok=ok, final_state=coord.state.value,
            recoveries=coord.recoveries,
            detail=f"{detail};save_failed={save_failed};{note}",
            trace_id=coord.trace_id))

    def _measure(self, ev: FaultEvent, coord, h0: int, t_inj: float):
        """Detection / restore / MTTR from the coordinator history.

        Definitions (docs/architecture.md "Failure model & recovery"):
          * detection  = inject → first RESTARTING (for stragglers: the
            SUSPENDED transition — i.e. including the swap-out write);
          * restore    = that transition → the next RUNNING;
          * MTTR       = inject → back to RUNNING (or SUSPENDED when the
            controller does not resume stragglers)."""
        hist = coord.history[h0:]
        t_detect = t_up = None
        for t, state, *_ in hist:
            if t_detect is None and state in ("RESTARTING", "SUSPENDED"):
                t_detect = t
            elif t_detect is not None and state == "RUNNING":
                t_up = t
                break
        if ev.kind == FaultKind.HOST_SLOWDOWN and not self.resume_stragglers:
            t_up = t_detect
        detection = None if t_detect is None else max(0.0, t_detect - t_inj)
        restore = (None if t_detect is None or t_up is None
                   else max(0.0, t_up - t_detect))
        mttr = None if t_up is None else max(0.0, t_up - t_inj)
        return detection, restore, mttr


def run_scenario(schedule: FaultSchedule, *, backend_cls=None,
                 n_hosts: int = 16, n_vms: int = 4, period_s: float = 0.0,
                 iter_time_s: float = 0.4, state_mb: float = 0.05,
                 keep_last: int = 3, settle_timeout_s: float = 60.0,
                 store_latency_s: float = 0.0,
                 resume_stragglers: bool = True) -> ScenarioResult:
    """Bring up a single-app service on a fresh simulator, drive the
    schedule through it, tear everything down, return the result.

    The service runs with periodic checkpointing off by default
    (``period_s=0``) so storage-fault events interleave deterministically
    with the controller's explicit checkpoints; pass a period to run the
    daemon as well (the storyline example does)."""
    from repro.clusters import OpenStackBackend, SnoozeBackend  # noqa: F401
    from repro.core.application import SimulatedApp
    from repro.core.service import CACSService

    backend_cls = backend_cls or SnoozeBackend
    backend = backend_cls(n_hosts=n_hosts)
    store = FaultyStore(InMemoryStore(latency_s=store_latency_s))
    svc = CACSService({backend.name: backend}, {"default": store})
    # host_slowdown is detected through TELEMETRY (the throughput-EWMA
    # watchdog), not liveness: the straggler heartbeat check is disabled
    # outright and the low-performance detector enabled with chaos-paced
    # polls (0.01 wall-tuned = 1 paper-second apart) and a short warmup
    # so a fault landing a few seconds in still sees a clean baseline
    from repro.core.monitoring import LowPerfConfig
    svc.apps.monitor.straggler_threshold = float("inf")
    svc.apps.monitor.poll_interval_s = 0.01
    svc.apps.monitor.lowperf = LowPerfConfig(warmup_samples=2)
    hook = ChaosHealthHook()
    asr = ASR(name=f"chaos-{schedule.seed}", n_vms=n_vms,
              backend=backend.name,
              app_factory=lambda: SimulatedApp(iter_time_s=iter_time_s,
                                               state_mb=state_mb),
              policy=CheckpointPolicy(period_s=period_s,
                                      keep_last=keep_last),
              health_hook=hook)
    cid = svc.submit(asr)
    try:
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=60)
        svc.trigger_checkpoint(cid)        # a restore point always exists
        ctrl = ChaosController(svc, cid, backend, schedule, store=store,
                               hook=hook, settle_timeout_s=settle_timeout_s,
                               resume_stragglers=resume_stragglers)
        outcomes = ctrl.run()
        coord = svc.db.get(cid)
        return ScenarioResult(
            seed=schedule.seed,
            trace=[o.trace_key() for o in outcomes],
            sim_faults=list(ctrl.sim_faults),
            outcomes=outcomes,
            final_state=coord.state.value,
            recoveries=coord.recoveries,
            events_deduped=svc.apps.events_deduped,
            partition_fallbacks=svc.apps.monitor.partition_fallbacks)
    finally:
        svc.shutdown()


def run_gang_scenario(schedule: FaultSchedule, *, n_hosts: int = 8,
                      n_vms: int = 4, min_vms: int = 2,
                      global_rows: int = 16, iter_time_s: float = 0.05,
                      keep_last: int = 3,
                      settle_timeout_s: float = 60.0) -> ScenarioResult:
    """Gang variant of :func:`run_scenario`: one multi-VM gang job
    (``asr.gang=True``) on a fresh simulator, with a first committed gang
    image taken before the schedule runs — GANG_BARRIER_* events arm
    their hooks on the job's GangCoordinator and fire inside the next
    snapshot's barrier."""
    from repro.clusters import SnoozeBackend
    from repro.core.gang import GangApp
    from repro.core.service import CACSService

    backend = SnoozeBackend(n_hosts=n_hosts)
    store = FaultyStore(InMemoryStore())
    svc = CACSService({backend.name: backend}, {"default": store})
    asr = ASR(name=f"gang-{schedule.seed}", n_vms=n_vms,
              backend=backend.name,
              app_factory=lambda: GangApp(global_rows=global_rows,
                                          iter_time_s=iter_time_s),
              policy=CheckpointPolicy(period_s=0.0, keep_last=keep_last),
              gang=True, min_vms=min_vms,
              # the scenario measures the BARRIER's straggler handling;
              # the monitor's proactive swap-out would race it (two
              # policies fighting over the same degraded host)
              straggler_action="ignore")
    cid = svc.submit(asr)
    try:
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=60)
        svc.trigger_checkpoint(cid)    # first committed gang image exists
        ctrl = ChaosController(svc, cid, backend, schedule, store=store,
                               settle_timeout_s=settle_timeout_s)
        outcomes = ctrl.run()
        coord = svc.db.get(cid)
        return ScenarioResult(
            seed=schedule.seed,
            trace=[o.trace_key() for o in outcomes],
            sim_faults=list(ctrl.sim_faults),
            outcomes=outcomes,
            final_state=coord.state.value,
            recoveries=coord.recoveries,
            events_deduped=svc.apps.events_deduped,
            partition_fallbacks=svc.apps.monitor.partition_fallbacks)
    finally:
        svc.shutdown()
