"""Application abstraction hosted by CACS.

The service is application-agnostic (the paper's key requirement): anything
implementing this protocol can be checkpointed, swapped, and migrated. Two
implementations ship:
  * ``SimulatedApp``  — synthetic workload with configurable state size
    (stands in for the paper's dmtcp1 / NAS-LU targets; used by benchmarks).
  * ``TrainerApp``    — a real JAX training job (repro.train.trainer), the
    2026 analogue of a long-running MPI application.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional, Protocol, runtime_checkable

import numpy as np

from repro.ckpt.snapshot import ReadySnapshot, SnapshotHandle
from repro.clusters.simulator import sim_sleep


@runtime_checkable
class Application(Protocol):
    """CACS application contract.

    Staged-snapshot extension (optional): an application may additionally
    implement ``snapshot_async(step=None, codec=None) -> SnapshotHandle``
    — capture a consistent snapshot in microseconds (pin immutable state
    references under its lock) and defer materialization (device→host
    copy, or device-side encode when ``codec`` selects a lossy image) to
    ``handle.resolve()`` on the checkpoint writer thread. The control
    plane always goes through ``snapshot_of``, which falls back to
    wrapping the synchronous ``checkpoint_state`` for applications that
    don't implement it (``SimulatedApp``, gang ranks), so implementing
    the extension is purely a performance choice.
    """

    def start(self, ctx: "AppContext", restore_state: Optional[Any]) -> None:
        """Begin (or resume) execution. Non-blocking."""

    def checkpoint_state(self) -> Any:
        """Pytree snapshot of application state (step-consistent)."""

    def healthy(self) -> bool:
        """User-defined health hook (paper §6.3)."""

    def stop(self) -> None:
        """Stop execution (state remains queryable until discarded)."""

    def is_done(self) -> bool: ...

    def progress(self) -> float: ...


def snapshot_of(app: Any, *, step: Optional[int] = None,
                codec: Optional[str] = None) -> SnapshotHandle:
    """Capture a staged snapshot of ``app`` (the control plane's one entry
    point for cutting application state).

    Applications implementing the staged extension return in microseconds
    with materialization deferred to ``resolve()``; legacy applications
    are wrapped in a ``ReadySnapshot`` around the synchronous
    ``checkpoint_state()`` — identical timing and bytes to the old path.
    ``codec`` is a hint for device-side encode ("int8"): apps that can't
    honor it (or lossless-only apps) simply ignore it — the image codec
    is chosen by the save, not here.
    """
    fn = getattr(app, "snapshot_async", None)
    if fn is not None:
        return fn(step=step, codec=codec)
    return ReadySnapshot(app.checkpoint_state(), step=step)


class AppContext:
    """What the service hands an application at start time."""

    def __init__(self, coord_id: str, vms, service=None):
        self.coord_id = coord_id
        self.vms = vms
        self.service = service


class SimulatedApp:
    """Iterative synthetic workload.

    Each iteration sleeps ``iter_time_s`` (scaled by the slowest host's
    ``slowdown`` — stragglers stretch it) and mutates an ndarray state of
    ``state_mb`` megabytes, like a time-stepping MPI solver. Health can be
    poisoned via ``poison()`` to exercise the paper's "application failure"
    recovery path (restart-in-place, §6.3 case 2).
    """

    def __init__(self, n_iters: int = 1_000_000, iter_time_s: float = 0.2,
                 state_mb: float = 1.0):
        self.n_iters = n_iters
        self.iter_time_s = iter_time_s
        self.state_elems = max(1, int(state_mb * 1024 * 1024 / 8))
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._poisoned = False
        self.iteration = 0
        self.state = np.zeros(self.state_elems, np.float64)
        self.ctx: Optional[AppContext] = None
        self.restarts = 0

    # -- Application protocol -------------------------------------------
    def start(self, ctx: AppContext, restore_state: Optional[Any]) -> None:
        self.ctx = ctx
        if restore_state is not None:
            with self._lock:
                self.iteration = int(restore_state["iteration"])
                self.state = np.array(restore_state["state"], np.float64)
                self.restarts += 1
        self._stop.clear()
        self._poisoned = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.is_set() and self.iteration < self.n_iters:
            slowdown = 1.0
            if self.ctx is not None and self.ctx.vms:
                slowdown = max(vm.host.slowdown for vm in self.ctx.vms)
            sim_sleep(self.iter_time_s * slowdown)
            with self._lock:
                self.state[self.iteration % self.state_elems] += 1.0
                self.iteration += 1

    def checkpoint_state(self) -> Dict[str, Any]:
        with self._lock:
            return {"iteration": self.iteration, "state": self.state.copy()}

    def healthy(self) -> bool:
        return not self._poisoned

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)

    def is_done(self) -> bool:
        return self.iteration >= self.n_iters

    def progress(self) -> float:
        return self.iteration / max(self.n_iters, 1)

    # -- test hooks -------------------------------------------------------
    def poison(self) -> None:
        self._poisoned = True
