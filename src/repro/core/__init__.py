"""CACS — Cloud-Agnostic Checkpointing Service (the paper's contribution).

Public surface:
  * ``CACSService``       — REST-style facade (paper Table 1)
  * ``ASR``               — Application Submission Request (paper §5.1)
  * ``GlobalScheduler``   — cloud-spanning job swapping / over-subscription
                            (use case 2): preemption, aging, cross-cloud
                            backfill over replicated images
  * ``migration``         — clone / migrate / cloudify (paper §5.3, §7.3)
"""
from repro.core.application import (Application, AppContext, SimulatedApp,
                                    snapshot_of)
from repro.core.chaos import (GANG_KINDS, ChaosController, ChaosHealthHook,
                              FaultEvent, FaultKind, FaultOutcome,
                              FaultSchedule, ScenarioResult,
                              run_gang_scenario, run_scenario)
from repro.core.coordinator import (ASR, CheckpointPolicy, Coordinator,
                                    CoordinatorDB, CoordState,
                                    InvalidTransition)
from repro.core.gang import (BarrierConfig, GangApp, GangBarrierError,
                             GangCoordinator, GangStragglerError,
                             gang_invariant)
from repro.core.migration import clone, cloudify, migrate, MigrationResult
from repro.core.replication import (FailoverController, FailoverResult,
                                    FailoverScenarioResult, ImageReplicator,
                                    ReplicationPolicy, StandbyTarget,
                                    run_failover_scenario)
from repro.core.scheduler import (GlobalScheduler, JobSpec, PlacementWeights,
                                  WorkloadTrace)
from repro.core.service import CACSService

__all__ = [
    "Application", "AppContext", "SimulatedApp", "snapshot_of",
    "ASR", "CheckpointPolicy", "Coordinator", "CoordinatorDB", "CoordState",
    "InvalidTransition",
    "ChaosController", "ChaosHealthHook", "FaultEvent", "FaultKind",
    "FaultOutcome", "FaultSchedule", "ScenarioResult", "run_scenario",
    "GANG_KINDS", "run_gang_scenario",
    "BarrierConfig", "GangApp", "GangBarrierError", "GangCoordinator",
    "GangStragglerError", "gang_invariant",
    "clone", "cloudify", "migrate", "MigrationResult",
    "FailoverController", "FailoverResult", "FailoverScenarioResult",
    "ImageReplicator", "ReplicationPolicy", "StandbyTarget",
    "run_failover_scenario",
    "GlobalScheduler", "JobSpec", "PlacementWeights", "WorkloadTrace",
    "CACSService",
]
