"""Serving launcher: batched greedy generation, optionally CACS-managed
(a suspended serving job resumes mid-generation from its KV-cache image).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--managed", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.managed:
        from repro.ckpt import InMemoryStore
        from repro.clusters import LocalBackend
        from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
        from repro.serve.engine import ServeApp
        svc = CACSService({"local": LocalBackend(1)},
                          {"default": InMemoryStore()})
        asr = ASR(name=f"serve-{cfg.name}", n_vms=1, backend="local",
                  app_factory=lambda: ServeApp(
                      cfg, batch=args.batch, prompt_len=args.prompt_len,
                      n_tokens=args.tokens,
                      cache_len=args.prompt_len + args.tokens),
                  policy=CheckpointPolicy(period_s=1.0, keep_last=2))
        cid = svc.submit(asr)
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=600)
        coord = svc.db.get(cid)
        while not coord.app.is_done():
            time.sleep(1.0)
            print(f"generated {coord.app.generated}/{args.tokens}")
        print("tokens:", coord.app.checkpoint_state()["tokens_out"][:, :16])
        svc.shutdown()
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import build_model
    from repro.serve.engine import Engine

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    cache_len=args.prompt_len + args.tokens)
    rng = np.random.Generator(np.random.PCG64(0))
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.monotonic()
    out = engine.generate({"tokens": jnp.asarray(prompt)}, args.tokens)
    dt = time.monotonic() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(np.asarray(out[:, :16]))


if __name__ == "__main__":
    main()
