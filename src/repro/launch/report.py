"""Assemble EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TB"


def fmt_s(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def load(dir_: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compile | bytes/dev (args+temp) | "
           "collective bytes/dev | status |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("tag"):
            continue
        mem = r.get("memory_analysis", {})
        live = mem.get("argument_size_in_bytes", 0)
        temp = mem.get("temp_size_in_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', '—')}s | "
            f"{fmt_bytes(live)} + {fmt_bytes(temp)} | "
            f"{fmt_bytes(r['collectives']['total_bytes'])} | OK |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute | memory (raw / fused / flash) | "
           "collective | bound | MODEL_FLOPS | useful ratio | "
           "roofline frac (raw / flash) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r.get("tag"):
            continue
        ro = r["roofline"]
        mem = fmt_s(ro["memory_s"])
        if "memory_fused_s" in ro:
            mem += (f" / {fmt_s(ro['memory_fused_s'])} / "
                    f"{fmt_s(ro['memory_flash_s'])}")
        frac = f"{100*ro['roofline_fraction']:.2f}%"
        if "roofline_fraction_flash" in ro:
            frac += f" / {100*ro['roofline_fraction_flash']:.2f}%"
        dom = ro.get("dominant_flash", ro["dominant"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{mem} | {fmt_s(ro['collective_s'])} | "
            f"**{dom}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_flops_ratio']:.3f} | {frac} |")
    return "\n".join(out)


def pick_hillclimb(rows: List[Dict], mesh: str = "16x16") -> str:
    cand = [r for r in rows if r["mesh"] == mesh and not r.get("tag")]
    if not cand:
        return ""
    worst = min(cand, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(cand, key=lambda r: (r["roofline"]["collective_s"]
                                    / max(r["roofline"]["step_bound_s"],
                                          1e-12)))
    return (f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
            f"({100*worst['roofline']['roofline_fraction']:.2f}%)\n"
            f"most collective-bound:   {coll['arch']}/{coll['shape']} "
            f"(coll {fmt_s(coll['roofline']['collective_s'])} vs bound "
            f"{fmt_s(coll['roofline']['step_bound_s'])})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "pick"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.section in ("all", "dryrun"):
        print("## Dry-run matrix\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline (single-pod 16x16)\n")
        print(roofline_table(rows))
        print()
    if args.section in ("all", "pick"):
        print("## Hillclimb candidates\n")
        print(pick_hillclimb(rows))


if __name__ == "__main__":
    main()
