"""Inject generated dry-run/roofline tables into EXPERIMENTS.md markers.

    PYTHONPATH=src python -m repro.launch.inject_tables
"""
from __future__ import annotations

from repro.launch.report import (dryrun_table, load, pick_hillclimb,
                                 roofline_table)


def main() -> None:
    baseline_rows = load("experiments/dryrun")
    v2_rows = load("experiments/dryrun_v2")

    dr = dryrun_table(baseline_rows)
    ro = roofline_table(v2_rows)
    pick = pick_hillclimb(load("experiments/dryrun"))

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLE -->", ro)
    text = text.replace(
        "<!-- PICK_NOTE -->",
        "### Hillclimb-candidate selection (from the baseline sweep)\n\n"
        "```\n" + pick + "\n```\n")
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("tables injected:",
          f"{len(baseline_rows)} baseline rows, {len(v2_rows)} v2 rows")


if __name__ == "__main__":
    main()
