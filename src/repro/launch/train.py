"""Training launcher.

Two modes:
  * ``--managed``  — submit the job to a CACS service instance (checkpoint
    policy, health monitoring, failure recovery all owned by the service —
    the paper's deployment model).
  * raw           — plain loop with an AsyncCheckpointer (for debugging).

On real hardware this process runs once per host; on this CPU container it
drives a single-device run (the multi-pod path is exercised by dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-period", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--codec", default="raw",
                    choices=["raw", "zlib", "int8", "int8+zlib"])
    ap.add_argument("--managed", action="store_true",
                    help="run under a CACS service instance")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke-test config")
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.train.trainer import TrainerApp

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.managed:
        from repro.ckpt import LocalFSStore
        from repro.clusters import LocalBackend
        from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
        svc = CACSService({"local": LocalBackend(n_hosts=1)},
                          {"default": LocalFSStore(args.ckpt_dir)})
        asr = ASR(name=f"train-{cfg.name}", n_vms=1, backend="local",
                  app_factory=lambda: TrainerApp(
                      cfg, global_batch=args.batch, seq_len=args.seq,
                      n_steps=args.steps),
                  policy=CheckpointPolicy(period_s=args.ckpt_period,
                                          codec=args.codec, keep_last=3))
        cid = svc.submit(asr)
        svc.wait_for_state(cid, CoordState.RUNNING, timeout=600)
        print(f"coordinator {cid} RUNNING")
        coord = svc.db.get(cid)
        while not coord.app.is_done():
            time.sleep(2.0)
            print(f"step={coord.app.current_step} loss={coord.app.last_loss:.4f} "
                  f"ckpts={svc.list_checkpoints(cid)}")
        svc.shutdown()
        return

    # raw loop
    import jax
    from repro.ckpt import AsyncCheckpointer, LocalFSStore, latest_step, restore
    from repro.data.pipeline import TokenPipeline
    from repro.models import build_model
    from repro.train import AdamWConfig, init_state, make_train_step

    model = build_model(cfg)
    opt = AdamWConfig(total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt))
    store = LocalFSStore(args.ckpt_dir)
    pipeline = TokenPipeline(cfg, args.batch, args.seq)
    ck = AsyncCheckpointer(store, f"raw/{cfg.name}", codec=args.codec)

    if args.resume and latest_step(store, f"raw/{cfg.name}") is not None:
        snap, man = restore(store, f"raw/{cfg.name}")
        state = snap["state"]
        pipeline.load_state_dict(snap["data"])
        print(f"resumed from step {man.step}")
    else:
        state = init_state(model, jax.random.PRNGKey(0))

    last_ckpt = time.monotonic()
    while int(state["step"]) < args.steps:
        batch = pipeline.next()
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        s = int(state["step"])
        if s % 10 == 0:
            print(f"step={s} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
        if time.monotonic() - last_ckpt > args.ckpt_period:
            ck.save(s, {"state": state, "data": pipeline.state_dict()})
            last_ckpt = time.monotonic()
    ck.save(int(state["step"]),
            {"state": state, "data": pipeline.state_dict()})
    ck.close()
    print("done")


if __name__ == "__main__":
    main()
