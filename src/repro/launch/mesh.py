"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state): single-pod = 16x16 (256 chips, TPU v5e pod), multi-pod =
2x16x16 (512 chips). The dry-run forces 512 host devices via XLA_FLAGS
before any jax import (see dryrun.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"before importing jax (dryrun.py does this)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(
        dev_array, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")
                   ) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    import numpy as np
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(
        dev_array, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
