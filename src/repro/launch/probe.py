import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Perf-iteration probe: compile a depth-2 unrolled cell and print the top
# collectives + cost numbers — the dry-run equivalent of a profiler trace.

import argparse      # noqa: E402
import json          # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--fsdp", choices=["on", "off"])
    ap.add_argument("--seq-shard", choices=["on", "off"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    from repro.launch import analysis
    from repro.launch.lowering import _compile_cell, build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    fsdp = None if args.fsdp is None else args.fsdp == "on"
    seq_shard = None if args.seq_shard is None else args.seq_shard == "on"
    cell = build_cell(args.arch, args.shape, mesh, depth_groups=args.depth,
                      remat=not args.no_remat, fsdp=fsdp,
                      seq_shard=seq_shard)
    with mesh:
        lowered = cell.jitted.lower(*cell.args)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    if args.save_hlo:
        with open(args.save_hlo, "w") as f:
            f.write(hlo)
    cost = compiled.cost_analysis()
    coll = analysis.collective_bytes(hlo)
    print(json.dumps({
        "flops": cost.get("flops"),
        "bytes": cost.get("bytes accessed"),
        "collectives": {k: v for k, v in coll.items() if v},
    }, indent=1))
    print("\ntop collectives (bytes, op, op_name):")
    for nbytes, op, meta in analysis.top_collectives(hlo, args.top):
        print(f"  {nbytes/1e6:10.1f}MB  {op:20s} {meta}")


if __name__ == "__main__":
    main()
