# NOTE: repro.launch.dryrun must be imported FIRST in a fresh process (it
# sets XLA_FLAGS for 512 host devices). This package init intentionally
# imports nothing.
