import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the production meshes need 512 placeholder
# devices (2 pods x 16 x 16). Everything else imports below.

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, \
    shape_applicable  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             remat: bool = True, fsdp=None, seq_shard=None,
             tag: str = "", full_compile: bool = True) -> dict:
    from repro.launch.lowering import lower_and_analyze
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell_args = dict(arch=arch, shape=shape, remat=remat, fsdp=fsdp,
                     seq_shard=seq_shard)
    result = lower_and_analyze(cell_args, mesh, full_compile=full_compile)
    if tag:
        result["tag"] = tag
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape}_{mesh_tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def run_all(out_dir: str, multi_pod_list, jobs_filter=None) -> int:
    """Drive every (arch x shape x mesh) cell in a subprocess each (compile
    state isolation; a crashing cell doesn't take down the sweep)."""
    failures = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            ok, why = shape_applicable(cfg, SHAPES[shape_name])
            if not ok:
                print(f"SKIP  {arch:28s} {shape_name:12s} {why}")
                continue
            for mp in multi_pod_list:
                mesh_tag = "2x16x16" if mp else "16x16"
                if jobs_filter and (arch, shape_name, mesh_tag) not in jobs_filter:
                    continue
                path = os.path.join(
                    out_dir, f"{arch}_{shape_name}_{mesh_tag}.json")
                if os.path.exists(path):
                    print(f"HAVE  {arch:28s} {shape_name:12s} {mesh_tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out", out_dir]
                if mp:
                    cmd.append("--multi-pod")
                t0 = time.monotonic()
                r = subprocess.run(cmd, capture_output=True, text=True)
                dt = time.monotonic() - t0
                if r.returncode != 0:
                    failures += 1
                    print(f"FAIL  {arch:28s} {shape_name:12s} {mesh_tag} "
                          f"({dt:.0f}s)\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
                else:
                    print(f"OK    {arch:28s} {shape_name:12s} {mesh_tag} "
                          f"({dt:.0f}s)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on both meshes, "
                         "one subprocess per cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default="",
                    help="e.g. save_moe (selective remat)")
    ap.add_argument("--fsdp", choices=["on", "off"])
    ap.add_argument("--seq-shard", choices=["on", "off"])
    ap.add_argument("--tag", default="", help="variant tag for perf runs")
    ap.add_argument("--quick", action="store_true",
                    help="skip the full-depth compile (perf iterations)")
    args = ap.parse_args()

    if args.all:
        failures = run_all(args.out, multi_pod_list=[False, True])
        sys.exit(1 if failures else 0)

    fsdp = None if args.fsdp is None else args.fsdp == "on"
    seq_shard = None if args.seq_shard is None else args.seq_shard == "on"
    remat = args.remat_policy or (not args.no_remat)
    result = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                      remat=remat, fsdp=fsdp,
                      seq_shard=seq_shard, tag=args.tag,
                      full_compile=not args.quick)
    # the assignment's required proofs:
    head = {k: result.get(k) for k in
            ("arch", "shape", "mesh", "lower_s", "compile_s")}
    print(json.dumps(head))
    if "memory_analysis" in result:
        print("memory_analysis:", json.dumps(result["memory_analysis"]))
    print("cost_analysis: flops/device=%.3e bytes/device=%.3e"
          % (result["flops_per_device"], result["bytes_per_device"]))
    print("collectives:", json.dumps(result["collectives"]))
    print("roofline:", json.dumps(result["roofline"]))


if __name__ == "__main__":
    main()
