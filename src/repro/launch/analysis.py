"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per DESIGN/EXPERIMENTS §Roofline:
  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = collective_bytes_per_device / ICI_link_bandwidth

cost_analysis() reports the per-device (post-SPMD) program, so the terms are
directly per-chip. Collective bytes are NOT in cost_analysis — they are
parsed from the optimized HLO text (every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute result buffer).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_LINE_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-reduce-start|all-gather-start|collective-permute-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\(")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective type (+ op counts).

    Two adjustments so the CPU-compiled HLO reflects TPU link traffic:
      * XLA:CPU *promotes* bf16 all-reduces to f32 (``clone_promoted``
        reduction computations); TPU runs them native bf16 — promoted ARs
        are counted at half width.
      * ``total_link_bytes`` weights all-reduce x2 (a ring AR moves
        ~2x the buffer: reduce-scatter + all-gather phases), others x1 —
        that is what the ICI link actually carries.
    """
    out: Dict[str, int] = {f"{op}_bytes": 0 for op in _COLL_OPS}
    counts: Dict[str, int] = {f"{op}_count": 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        nbytes = _type_bytes(m.group("type"))
        if "clone_promoted" in line and "f32[" in m.group("type"):
            nbytes //= 2            # undo CPU-only bf16->f32 AR promotion
        out[f"{op}_bytes"] += nbytes
        counts[f"{op}_count"] += 1
    total = sum(out.values())
    link = (2 * out["all-reduce_bytes"] + out["all-gather_bytes"]
            + out["reduce-scatter_bytes"] + out["all-to-all_bytes"]
            + out["collective-permute_bytes"])
    return {**out, **counts, "total_bytes": total,
            "total_link_bytes": link}


_DEF_RE = re.compile(r"%(\S+?) = ((?:\([^=]*?\)|\S+)) ([a-z][a-z0-9-]*)\(([^)]*)")

_HEAVY_OPS = frozenset({
    "dot", "convolution", "gather", "scatter", "scatter-add",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "dynamic-slice", "dynamic-update-slice", "sort",
})


def fused_memory_bytes(hlo_text: str,
                       score_trailing: Optional[Tuple[int, int]] = None,
                       ) -> Dict[str, float]:
    """TPU-fusion-adjusted HBM traffic estimate.

    The CPU backend fuses far less than TPU, so cost_analysis's
    "bytes accessed" over-counts elementwise chains. This model counts only
    *fusion-boundary-forcing* ops (dots, gathers/scatters, collectives,
    dynamic slices): result bytes + operand bytes (operands resolved via
    the def table).

    ``score_trailing``: if given (e.g. (S, T)), tensors whose trailing dims
    match attention scores are additionally excluded in the ``flash``
    variant — modeling the Pallas flash kernel that keeps them in VMEM.
    """
    defs: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
    heavy: list = []
    for m in _DEF_RE.finditer(hlo_text):
        name, ty, op, operands = m.groups()
        nbytes = _type_bytes(ty)
        dims: Tuple[int, ...] = ()
        sm = _SHAPE_RE.search(ty)
        if sm and sm.group(2):
            dims = tuple(int(d) for d in sm.group(2).split(","))
        defs[name] = (nbytes, dims)
        if op in _HEAVY_OPS:
            heavy.append((op, nbytes, dims, operands))

    def is_score(dims: Tuple[int, ...]) -> bool:
        return (score_trailing is not None and len(dims) >= 2
                and dims[-2:] == tuple(score_trailing))

    total = 0.0
    total_flash = 0.0
    opnd_re = re.compile(r"%(\S+?)[,)\s]")
    for op, nbytes, dims, operands in heavy:
        opnd_bytes = [defs.get(om.group(1), (0, ()))
                      for om in opnd_re.finditer(operands + ")")]
        if op == "dynamic-update-slice":
            # in-place aliased on TPU: traffic = the update operand only
            upd = opnd_bytes[1][0] if len(opnd_bytes) > 1 else 0
            total += upd
            total_flash += upd
            continue
        moved = nbytes
        moved_flash = 0 if is_score(dims) else nbytes
        for ob, odims in opnd_bytes:
            moved += ob
            moved_flash += 0 if is_score(odims) else ob
        total += moved
        total_flash += moved_flash
    return {"fused_bytes": total, "fused_flash_bytes": total_flash}


def top_collectives(hlo_text: str, k: int = 15):
    """The k largest collective ops with sizes + op_name metadata — the
    dry-run 'profile' used by the §Perf hillclimb."""
    items = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        nbytes = _type_bytes(m.group("type"))
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', line)
        if mm:
            meta = mm.group(1)[-120:]
        items.append((nbytes, m.group("op"), meta))
    items.sort(reverse=True)
    return items[:k]


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) or 2·N_active·D
    (prefill/decode) + attention context terms."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    # attention layers and their effective context
    n_attn, eff_ctx = 0, 0.0
    from repro.models.transformer import build_group
    blocks, n_groups = build_group(cfg)
    for blk in blocks:
        if blk.kind == "attn":
            w = blk.spec.window
            ctx = min(S, w) if w else S
            n_attn += n_groups
            eff_ctx += n_groups * ctx
    H, hd = cfg.n_heads, cfg.head_dim
    if shape.kind == "train":
        D = B * S
        dense = 6.0 * N * D
        attn = 6.0 * B * S * eff_ctx * H * hd    # causal fwd+bwd (12*0.5)
        return dense + attn
    if shape.kind == "prefill":
        D = B * S
        return 2.0 * N * D + 2.0 * B * S * eff_ctx * H * hd
    # decode: one token over a full context
    return 2.0 * N * B + 4.0 * B * eff_ctx * H * hd


def roofline(cost: Dict[str, float], coll: Dict[str, int],
             cfg: ArchConfig, shape: ShapeConfig,
             n_chips: int,
             fused: Optional[Dict[str, float]] = None) -> Dict[str, float]:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total_link_bytes", coll["total_bytes"]))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * n_chips
    out = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": max(terms, key=terms.get),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "step_bound_s": max(terms.values()),
        # fraction of roofline: useful work per second at the bound vs peak
        "roofline_fraction": (
            (mf / n_chips / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    }
    if fused is not None:
        # TPU-fusion-adjusted memory terms (see fused_memory_bytes):
        #   fused  — elementwise chains fuse; dots/gathers/collectives move
        #   flash  — additionally, score-shaped tensors stay in VMEM
        #            (the Pallas flash/decode kernels' contribution)
        t_mf = fused["fused_bytes"] / HBM_BW
        t_mfl = fused["fused_flash_bytes"] / HBM_BW
        terms_f = {"compute": t_compute, "memory": t_mfl,
                   "collective": t_coll}
        out.update({
            "memory_fused_s": t_mf,
            "memory_flash_s": t_mfl,
            "dominant_flash": max(terms_f, key=terms_f.get),
            "step_bound_flash_s": max(terms_f.values()),
            "roofline_fraction_flash": (
                (mf / n_chips / PEAK_FLOPS) / max(terms_f.values())
                if max(terms_f.values()) > 0 else 0.0),
        })
    return out
