"""Cell construction for the multi-pod dry-run: (arch x shape x mesh) ->
jitted+sharded computation and abstract inputs, then lower/compile/analyze.

No jax device-state side effects at import; callers (dryrun.py) configure
XLA_FLAGS before importing anything jax-touching.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import analysis
from repro.models.model import Model, build_model
from repro.sharding.specs import (MeshAxes, activation_sharding, make_axes,
                                  param_specs)
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_state, make_train_step, state_dims


def _shardify(tree_sds: Any, dims_tree: Any,
              mesh: jax.sharding.Mesh, axes: MeshAxes) -> Any:
    """Attach NamedShardings (from logical dims) to a ShapeDtypeStruct tree."""
    specs = param_specs(dims_tree, tree_sds, axes)
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree_sds, specs)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ArchConfig
    kind: str
    jitted: Any
    args: Tuple[Any, ...]


def _with_depth(cfg: ArchConfig, k_groups: int) -> ArchConfig:
    """Same arch with the layer stack truncated to k scan groups (and the
    encoder scaled proportionally) — used for cost extrapolation."""
    from repro.models.transformer import build_group
    _, n_groups = build_group(cfg)
    group_size = cfg.n_layers // n_groups
    changes: Dict[str, Any] = {"n_layers": k_groups * group_size}
    if cfg.encoder is not None:
        unit = max(1, cfg.encoder.n_layers // n_groups)
        changes["encoder"] = dataclasses.replace(
            cfg.encoder, n_layers=k_groups * unit)
    return dataclasses.replace(cfg, **changes)


def build_cell(arch: str, shape_name: str, mesh: jax.sharding.Mesh, *,
               remat: bool = True,
               fsdp: Optional[bool] = None,
               seq_shard: Optional[bool] = None,
               depth_groups: Optional[int] = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    if depth_groups is not None:
        cfg = _with_depth(cfg, depth_groups)
    # Cost probes unroll the stack: XLA cost_analysis counts while-loop
    # bodies once, so the probe depths must not hide layers inside a scan.
    model = build_model(cfg, unroll=depth_groups is not None)
    use_fsdp = cfg.use_fsdp if fsdp is None else fsdp
    if seq_shard is None:
        # Megatron-style sequence sharding between blocks: default ON for
        # train and prefill of attention archs (§Perf iteration D: -53%
        # memory, -28% collective on internlm2/train_4k) — but OFF for
        # recurrent stacks (ssm/xlstm): the sequential scan needs the full
        # sequence locally, and S-sharding it cost jamba a 12x collective
        # regression (§Perf iteration D2, refuted for hybrids).
        seq_shard = (shape.kind in ("prefill", "train")
                     and cfg.ssm is None and cfg.xlstm is None)
    axes = make_axes(mesh, use_fsdp=use_fsdp, seq_shard=seq_shard)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        st_sds = jax.eval_shape(
            lambda: init_state(model, jax.random.PRNGKey(0)))
        st_sh = _shardify(st_sds, state_dims(model), mesh, axes)
        batch_sds = _shardify(model.batch_struct(B, S),
                              model.batch_dims(), mesh, axes)
        opt = AdamWConfig()
        grad_specs = param_specs(model.param_dims(),
                                 st_sds["params"], axes)
        step_fn = make_train_step(model, opt, axes=axes, remat=remat,
                                  grad_specs=grad_specs)
        jitted = jax.jit(step_fn, donate_argnums=(0,),
                         out_shardings=(
                             jax.tree.map(lambda s: s.sharding, st_sh),
                             None))
        return Cell(arch, shape, cfg, "train", jitted, (st_sh, batch_sds))

    params_sds = _shardify(model.abstract_params(), model.param_dims(),
                           mesh, axes)

    if shape.kind == "prefill":
        batch = model.batch_struct(B, S)
        batch.pop("targets")
        bdims = model.batch_dims()
        bdims.pop("targets")
        batch_sds = _shardify(batch, bdims, mesh, axes)

        def prefill_fn(params, batch):
            with activation_sharding(axes):
                return model.prefill(params, batch, cache_len=S)

        jitted = jax.jit(prefill_fn)
        return Cell(arch, shape, cfg, "prefill", jitted,
                    (params_sds, batch_sds))

    # decode: one new token against a cache of size seq_len
    cache_sds = _shardify(model.abstract_cache(B, S), model.cache_dims(),
                          mesh, axes)
    tok_spec = P(axes.dp) if B % _axes_size(axes, axes.dp) == 0 else P()
    token_sds = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
    pos_sds = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(mesh, P()))

    def serve_step(params, cache, token, pos):
        with activation_sharding(axes):
            return model.decode_step(params, cache, token, pos)

    jitted = jax.jit(
        serve_step, donate_argnums=(1,),
        out_shardings=(None, jax.tree.map(lambda s: s.sharding, cache_sds)))
    return Cell(arch, shape, cfg, "decode", jitted,
                (params_sds, cache_sds, token_sds, pos_sds))


def _axes_size(axes: MeshAxes, ax) -> int:
    import math
    ax_t = ax if isinstance(ax, tuple) else (ax,)
    return math.prod(axes.size(a) for a in ax_t)


class SkipCell(Exception):
    pass


def _compile_cell(cell: Cell, mesh: jax.sharding.Mesh,
                  save_hlo: Optional[str] = None):
    t0 = time.monotonic()
    with mesh:
        lowered = cell.jitted.lower(*cell.args)
    t1 = time.monotonic()
    compiled = lowered.compile()
    t2 = time.monotonic()

    mem = compiled.memory_analysis()
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)
    cost = compiled.cost_analysis() or {}
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))}
    hlo = compiled.as_text()
    coll = analysis.collective_bytes(hlo)
    S = cell.shape.seq_len
    score_trailing = (S, S) if cell.kind in ("train", "prefill") else (1, S)
    fused = analysis.fused_memory_bytes(hlo, score_trailing=score_trailing)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return {
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "cost": cost,
        "memory_analysis": mem_fields,
        "collectives": coll,
        "fused": fused,
    }


def lower_and_analyze(cell_args: Dict[str, Any], mesh: jax.sharding.Mesh,
                      *, save_hlo: Optional[str] = None,
                      full_compile: bool = True) -> Dict[str, Any]:
    """Full analysis of one (arch x shape x mesh) cell.

    1. FULL-depth lower+compile — the dry-run pass/fail proof and the
       memory analysis (buffer sizes account for loop state correctly).
    2. Depth-1 and depth-2 compiles — XLA's cost_analysis counts a while
       (scan) body ONCE regardless of trip count, so per-step FLOPs/bytes/
       collective bytes are linearly extrapolated from two depths:
       ``total(G) = c(1) + (G - 1) * (c(2) - c(1))``.
    """
    arch, shape_name = cell_args["arch"], cell_args["shape"]
    bkw = {k: v for k, v in cell_args.items() if k not in ("arch", "shape")}
    n_chips = mesh.devices.size

    from repro.models.transformer import build_group
    cfg_full = get_config(arch)
    _, n_groups = build_group(cfg_full)

    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": n_chips,
        "params": cfg_full.param_count(),
        "active_params": cfg_full.active_param_count(),
        "n_groups": n_groups,
    }

    cell_full = build_cell(arch, shape_name, mesh, **bkw)
    out["kind"] = cell_full.kind
    if full_compile:
        full = _compile_cell(cell_full, mesh, save_hlo)
        out.update({
            "lower_s": full["lower_s"],
            "compile_s": full["compile_s"],
            "memory_analysis": full["memory_analysis"],
            "collectives_raw": full["collectives"],
        })

    # cost extrapolation via depth-1 / depth-2 compiles
    c1 = _compile_cell(build_cell(arch, shape_name, mesh, depth_groups=1,
                                  **bkw), mesh)
    c2 = _compile_cell(build_cell(arch, shape_name, mesh, depth_groups=2,
                                  **bkw), mesh)

    def extrap(v1: float, v2: float) -> float:
        return v1 + (n_groups - 1) * (v2 - v1)

    flops_dev = extrap(c1["cost"].get("flops", 0.0),
                       c2["cost"].get("flops", 0.0))
    bytes_dev = extrap(c1["cost"].get("bytes accessed", 0.0),
                       c2["cost"].get("bytes accessed", 0.0))
    coll = {k: (extrap(c1["collectives"][k], c2["collectives"][k])
                if k.endswith("_bytes") or k == "total_bytes"
                else extrap(c1["collectives"][k], c2["collectives"][k]))
            for k in c1["collectives"]}
    fused = {k: extrap(c1["fused"][k], c2["fused"][k]) for k in c1["fused"]}

    cost = {"flops": flops_dev, "bytes accessed": bytes_dev}
    roof = analysis.roofline(cost, coll, cell_full.cfg, cell_full.shape,
                             n_chips, fused=fused)
    out.update({
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "roofline": roof,
        "extrapolation": {"depth1": c1["cost"], "depth2": c2["cost"],
                          "n_groups": n_groups},
    })
    return out
