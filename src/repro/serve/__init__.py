from repro.serve.engine import Engine, ServeApp

__all__ = ["Engine", "ServeApp"]
