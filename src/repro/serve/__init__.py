from repro.serve.engine import Engine, ServeApp
from repro.serve.fleet import FleetController
from repro.serve.workload import FleetPolicy, RequestTrace, Router

__all__ = ["Engine", "ServeApp", "FleetController", "FleetPolicy",
           "RequestTrace", "Router"]
