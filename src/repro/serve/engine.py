"""Serving engine: batched prefill + decode with donated caches.

Also hosts ``ServeApp`` — a CACS-managed inference job whose checkpoint
state is {params, KV/SSM caches, generated tokens}: suspending a *serving*
job mid-generation and resuming it elsewhere (even on another "cloud") is
the paper's job-swapping use case applied to inference.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.snapshot import DeferredSnapshot, SnapshotHandle
from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model
from repro.obs.telemetry import SampleView, registry, unique_name
from repro.sim.simtime import active_clock


class Engine:
    def __init__(self, model: Model, params: Any, *, cache_len: int = 256):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))

    def prefill(self, batch: Dict[str, jax.Array]):
        return self._prefill(self.params, batch)

    def decode(self, cache, token, pos):
        return self._decode(self.params, cache, token, pos)

    def generate(self, batch: Dict[str, jax.Array], n_tokens: int,
                 *, greedy: bool = True) -> jax.Array:
        """Prefill the prompt then decode n_tokens greedily. Returns
        [B, n_tokens] int32."""
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend is not None \
                and self.model.cfg.family != "encdec":
            prompt_len += self.model.cfg.frontend_len
        logits, cache = self.prefill(batch)
        out = []
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(token)
        for i in range(1, n_tokens):
            pos = jnp.int32(prompt_len + i - 1)
            logits, cache = self.decode(cache, token, pos)
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(token)
        return jnp.concatenate(out, axis=1)


class ServeApp:
    """CACS-hosted batched-serving job (checkpointable mid-generation)."""

    def __init__(self, cfg: ArchConfig, *, batch: int = 2,
                 prompt_len: int = 16, n_tokens: int = 64,
                 cache_len: int = 128, seed: int = 0,
                 token_delay_s: float = 0.0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.prompt_len = prompt_len
        self.n_tokens = n_tokens
        self.cache_len = cache_len
        self.seed = seed
        self.token_delay_s = token_delay_s   # rate-limit (tests/demos)
        self.params: Any = None
        self.cache: Any = None
        self.tokens_out: List[np.ndarray] = []
        self.generated = 0
        self._last_token = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        # signaled whenever the donated-cache slot refills (or the decode
        # loop dies): _capture blocks on this instead of polling the clock
        self._cond = threading.Condition(self._lock)
        # first decode-loop exception; healthy() flips False on it
        self._failure: Optional[BaseException] = None
        # seconds decode was blocked per snapshot pin: registry histogram
        # is the store; ckpt_stalls (below) is a read-only view
        self._stall_hist = registry().histogram(
            unique_name("serve.ckpt_stall_s"))
        self.restarts = 0

    def _build(self):
        if self.params is None:
            self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self.engine = Engine(self.model, self.params,
                             cache_len=self.cache_len)

    def start(self, ctx, restore_state: Optional[Any]) -> None:
        self._build()
        if restore_state is not None:
            with self._lock:
                self.params = restore_state["params"]
                self.cache = restore_state["cache"]
                self.generated = int(restore_state["generated"])
                self._last_token = jnp.asarray(restore_state["last_token"])
                self.tokens_out = [np.asarray(restore_state["tokens_out"])] \
                    if self.generated else []
            self.engine = Engine(self.model, self.params,
                                 cache_len=self.cache_len)
            self.restarts += 1
        self._stop.clear()
        self._failure = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        if self.cache is None:
            rng = np.random.Generator(np.random.PCG64(self.seed))
            prompt = rng.integers(
                0, self.cfg.vocab_size, (self.batch, self.prompt_len)
            ).astype(np.int32)
            logits, cache = self.engine.prefill({"tokens": jnp.asarray(prompt)})
            token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            with self._cond:
                self.cache = cache
                self._last_token = token
                self.tokens_out.append(np.asarray(token))
                self.generated = 1
                self._cond.notify_all()
        clock = active_clock()
        while not self._stop.is_set() and self.generated < self.n_tokens:
            if self.token_delay_s:
                clock.sleep(self.token_delay_s)
            pos = jnp.int32(self.prompt_len + self.generated - 1)
            # NOTE: cache is donated; keep the swap atomic wrt checkpointing
            with self._lock:
                cache, token = self.cache, self._last_token
                self.cache = None
            try:
                logits, new_cache = self.engine.decode(cache, token, pos)
            except BaseException as e:             # noqa: BLE001
                # Restore the surrendered slot: leaving it None would make
                # every _capture (snapshot_async, suspend) block forever on
                # a dead loop. The pre-decode cache is the last consistent
                # state (best-effort — if the jitted call got far enough to
                # consume the donated buffer, a later restore re-reads the
                # newest committed image instead), so a suspend issued
                # after the fault still swaps out cleanly.
                with self._cond:
                    self.cache = cache
                    self._failure = e
                    self._cond.notify_all()
                registry().inc("serve.decode_failures",
                               note=f"{type(e).__name__}: {e}")
                return
            token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            with self._cond:
                self.cache = jax.block_until_ready(new_cache)
                self._last_token = token
                self.tokens_out.append(np.asarray(token))
                self.generated += 1
                self._cond.notify_all()

    def _capture(self) -> Dict[str, Any]:
        """Pin a consistent snapshot under the lock (waits out the window
        where the donated cache is surrendered to an in-flight decode).
        Params/tokens are references (never donated, immutable); the KV
        cache is **copied on device** — the very next decode step donates
        the live buffer, so a pinned reference would read as "Array has
        been deleted" by the time the writer thread encodes it. The copy
        is dispatch-only (async), so the pin stall stays in microseconds.

        Blocks on a condition variable signaled when the slot refills —
        never on the installed clock: a virtual-time poll here would race
        the SimClock forward while the decode runs in wall time (the same
        retime hazard the gang barrier's paused-rank poll had). The wait
        timeout is only a wall-clock backstop against a decode thread that
        dies without notifying."""
        with self._cond:
            while self.cache is None:
                if self._failure is not None:
                    raise RuntimeError(
                        "serve decode loop failed with the donated cache "
                        "unrecoverable") from self._failure
                self._cond.wait(timeout=0.1)
            return {
                "params": self.params,
                "cache": jax.tree_util.tree_map(
                    lambda x: jnp.array(x, copy=True)
                    if isinstance(x, jax.Array) else x, self.cache),
                "generated": self.generated,
                "last_token": self._last_token,
                "tokens_out": list(self.tokens_out),
            }

    @staticmethod
    def _materialize(snap: Dict[str, Any], batch: int) -> Dict[str, Any]:
        out = dict(snap)
        out["tokens_out"] = (np.concatenate(snap["tokens_out"], axis=1)
                             if snap["tokens_out"]
                             else np.zeros((batch, 0), np.int32))
        return out

    def checkpoint_state(self) -> Dict[str, Any]:
        return self._materialize(self._capture(), self.batch)

    def snapshot_async(self, *, step: Optional[int] = None,
                       codec: Optional[str] = None) -> SnapshotHandle:
        """Staged snapshot: capture pins params/cache/token references
        (token-latency stall only while a decode holds the donated
        cache); the concat + any host copies run at ``resolve()`` on the
        writer thread. The KV cache stays lossless regardless of
        ``codec`` — quantizing it would perturb the generated stream,
        and suspend/resume guarantees the tokens are unchanged."""
        clock = active_clock()
        t0 = clock.now()
        snap = self._capture()
        self._stall_hist.observe(clock.now() - t0)
        return DeferredSnapshot(
            lambda: self._materialize(snap, self.batch),
            step=snap["generated"] if step is None else step)

    @property
    def ckpt_stalls(self) -> SampleView:
        """Per-snapshot pin stalls, as a list-like view over the registry
        histogram (len()/indexing kept for existing callers)."""
        return SampleView(self._stall_hist)

    def healthy(self) -> bool:
        return self._failure is None

    def stop(self, join_s: float = 60.0) -> bool:
        """Stop the decode loop. Returns True when the thread LEAKED —
        the join timed out on a wedged decode (e.g. a hung device call).
        Leaks are counted in the ``serve.stop_timeouts`` registry counter
        with the last decode error as the note, so a fleet teardown that
        silently strands threads is visible in one telemetry snapshot."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        thread = self._thread
        if thread is None:
            return False
        thread.join(timeout=join_s)
        if thread.is_alive():
            registry().inc(
                "serve.stop_timeouts",
                note=f"decode thread wedged after {join_s}s "
                     f"(last_error={self._failure!r})")
            return True
        return False

    def is_done(self) -> bool:
        return self.generated >= self.n_tokens

    def progress(self) -> float:
        return self.generated / max(self.n_tokens, 1)
