"""Checkpoint-backed serving fleet: the paper's job-swapping story at
user scale (ROADMAP "Checkpoint-backed serving fleet").

A :class:`FleetController` manages N ServeApp replicas of one model as
ordinary GlobalScheduler jobs:

* **scale OUT** — a new replica is submitted with
  ``GlobalScheduler.submit(adopt_prefix=<seed>)``: its cold start
  *restores the shared seed image straight from CAS* (prefix adoption —
  zero chunk re-uploads, the replica's own prefix stays empty), and the
  wall/virtual time from submit to RUNNING is recorded as the replica's
  **cold-start latency** — a registry histogram plus a per-job gauge
  under the job's trace_id (``coord.<trace_id>.coldstart_s``) and a
  ``fleet/coldstart`` trace event. Replicas parked by an earlier
  scale-in are preferred over fresh submits (their suspend image resumes
  warmer than the seed).
* **scale IN** — idle replicas are *suspended* through the standard
  swap-out path (their mid-generation state goes to stable storage) and
  flagged ``fleet_parked`` so the scheduler's queue pass hands their
  hosts to batch work instead of auto-resuming them.
* **routing** — a deterministic least-outstanding :class:`Router`
  (serve/workload.py) spreads requests over live replicas.

The controller is deliberately *driven* (``autoscale_step()``), not a
daemon: the benchmark and tests pace it explicitly on the installed
clock, so seeded scenarios replay exactly.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.ckpt.writer import save_checkpoint
from repro.core.coordinator import ASR, CheckpointPolicy, CoordState
from repro.obs.telemetry import registry, unique_name
from repro.obs.trace import tracer
from repro.serve.workload import FleetPolicy, Router
from repro.sim.simtime import active_clock


class FleetController:
    """Suspend/restore autoscaler for one model's serving replicas."""

    def __init__(self, service, scheduler, *, name: str,
                 replica_factory: Callable[[], Any],
                 seed_prefix: Optional[str] = None,
                 policy: FleetPolicy = FleetPolicy(),
                 backend: str = "", store: str = "default",
                 priority: int = 5, clouds: tuple = (),
                 swap_codec: Optional[str] = None):
        self.service = service
        self.scheduler = scheduler
        self.name = name
        self.replica_factory = replica_factory
        self.seed_prefix = seed_prefix or f"fleet/{name}/seed"
        self.policy = policy
        self.backend = backend or next(iter(service.cloud.backends()))
        self.store_name = store
        self.priority = priority
        self.clouds = clouds
        self.swap_codec = swap_codec
        self.router = Router()
        self._replicas: List[str] = []           # every coord_id, in order
        self._pending: Dict[str, float] = {}     # coord_id -> scale-out t0
        self._fresh: set = set()                 # pending first-time starts
        self._last_busy: Dict[str, float] = {}   # coord_id -> last activity
        self._next_idx = 0
        self._last_scale = float("-inf")
        self._cold_hist = registry().histogram(
            unique_name(f"fleet.{name}.coldstart_s"))
        self.coldstarts = 0
        self.coldstart_reuploads = 0             # must stay 0 (adoption)
        self.parks = 0
        self.unparks = 0

    # ------------------------------------------------------------------
    # seed lineage
    # ------------------------------------------------------------------
    def publish_seed(self, state: Any, *, step: int = 1,
                     codec: str = "raw") -> None:
        """Commit the shared warm image every replica adopts on cold
        start (e.g. a prefilled ServeApp's checkpoint_state). One CAS
        upload serves the whole fleet for its lifetime."""
        save_checkpoint(self.service.ckpt.store(self.store_name),
                        self.seed_prefix, step, state, codec=codec,
                        metadata={"fleet": self.name, "seed": True})

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def replicas(self) -> List[str]:
        return list(self._replicas)

    def live(self) -> List[str]:
        out = []
        for cid in self._replicas:
            try:
                if self.service.db.get(cid).state == CoordState.RUNNING:
                    out.append(cid)
            except KeyError:
                pass
        return out

    def parked(self) -> List[str]:
        out = []
        for cid in self._replicas:
            try:
                coord = self.service.db.get(cid)
            except KeyError:
                continue
            if (coord.state == CoordState.SUSPENDED
                    and coord.metrics.get("fleet_parked")):
                out.append(cid)
        return out

    def _asr(self) -> ASR:
        idx = self._next_idx
        self._next_idx += 1
        return ASR(name=f"{self.name}-r{idx:03d}", n_vms=1,
                   backend=self.backend,
                   app_factory=self.replica_factory,
                   policy=CheckpointPolicy(period_s=0.0,
                                           store=self.store_name,
                                           swap_codec=self.swap_codec),
                   priority=self.priority, clouds=self.clouds)

    # ------------------------------------------------------------------
    # scale out (unpark first, else adopt the seed lineage)
    # ------------------------------------------------------------------
    def scale_out(self, n: int = 1) -> List[str]:
        started: List[str] = []
        for _ in range(n):
            if len(self._replicas) - len(self.parked()) \
                    >= self.policy.max_replicas and not self.parked():
                break
            t0 = active_clock().now()
            parked = self.parked()
            if parked:
                cid = parked[0]
                coord = self.service.db.get(cid)
                coord.metrics["fleet_parked"] = 0
                coord.metrics["queued_at_v"] = t0
                self.service.db.persist(coord)
                self.unparks += 1
                self.scheduler.nudge("fleet_unpark")
            else:
                cid = self.scheduler.submit(
                    self._asr(), adopt_prefix=self.seed_prefix)
                self._replicas.append(cid)
                self._fresh.add(cid)
            self._pending[cid] = t0
            started.append(cid)
        return started

    def wait_live(self, coord_ids: Optional[List[str]] = None,
                  timeout: float = 60.0) -> None:
        """Block until the given (default: all pending) replicas are
        RUNNING, then close out their cold-start measurements."""
        for cid in list(coord_ids or self._pending):
            self.service.wait_for_state(cid, CoordState.RUNNING, timeout)
            self.note_running(cid)

    def note_running(self, coord_id: str) -> None:
        """Finalize one replica's cold start: latency into the registry
        histogram AND the job's trace_id-scoped gauge, plus the
        zero-re-upload audit (object count under the replica's own
        prefix — adoption means the restore wrote nothing)."""
        t0 = self._pending.pop(coord_id, None)
        if t0 is None:
            return
        coord = self.service.db.get(coord_id)
        now = active_clock().now()
        cold = max(0.0, now - t0)
        coord.metrics["coldstart_s"] = cold      # -> coord.<trace_id> gauge
        self._cold_hist.observe(cold)
        # zero-re-upload audit, first-time starts only: an adopted cold
        # start writes nothing under its own prefix (an *unparked* replica
        # legitimately owns its suspend image — not a re-upload)
        own_objects = 0
        if coord_id in self._fresh:
            self._fresh.discard(coord_id)
            store = self.service.ckpt.store(self.store_name)
            own_objects = len(store.list(coord.ckpt_prefix + "/"))
            self.coldstart_reuploads += own_objects
        self.coldstarts += 1
        tracer().event("fleet/coldstart", cat="serve",
                       trace_id=coord.trace_id,
                       args={"fleet": self.name, "coldstart_s": cold,
                             "own_objects": own_objects})
        self.router.add(coord_id)
        self._last_busy[coord_id] = now

    # ------------------------------------------------------------------
    # scale in (suspend + park)
    # ------------------------------------------------------------------
    def _idle_for(self, coord_id: str, now: float) -> float:
        if self.router.outstanding(coord_id) > 0:
            return 0.0
        return now - self._last_busy.get(coord_id, now)

    def scale_in(self, n: int = 1, *, force: bool = False) -> List[str]:
        """Park up to ``n`` idle replicas (never below min_replicas).
        ``force`` skips the idle-age check (tests / drain)."""
        now = active_clock().now()
        live = self.live()
        idle = sorted((cid for cid in live
                       if force or self._idle_for(cid, now)
                       >= self.policy.scale_in_idle_s),
                      key=lambda c: -self._idle_for(c, now))
        out: List[str] = []
        for cid in idle:
            if len(live) - len(out) <= self.policy.min_replicas:
                break
            if len(out) >= n:
                break
            coord = self.service.db.get(cid)
            self.router.remove(cid)
            # flag BEFORE the suspend commits: the instant SUSPENDED is
            # visible the scheduler's next pass would otherwise resume it
            coord.metrics["fleet_parked"] = 1
            try:
                self.service.apps.suspend(cid, reason="fleet_scale_in")
            except Exception:              # noqa: BLE001
                coord.metrics["fleet_parked"] = 0
                self.router.add(cid)       # lost a race; still serving
                continue
            self.parks += 1
            registry().inc(f"fleet.{self.name}.parks")
            out.append(cid)
        return out

    # ------------------------------------------------------------------
    # routing + autoscaling
    # ------------------------------------------------------------------
    def route(self) -> Optional[str]:
        rid = self.router.route()
        if rid is not None:
            self._last_busy[rid] = active_clock().now()
        return rid

    def complete(self, replica_id: str) -> None:
        self.router.complete(replica_id)
        self._last_busy[replica_id] = active_clock().now()

    def autoscale_step(self) -> int:
        """One evaluation: scale out when outstanding load per live
        replica exceeds ``target_inflight``, scale in when replicas sit
        idle past ``scale_in_idle_s``. Returns +n/-n replicas changed."""
        now = active_clock().now()
        if now - self._last_scale < self.policy.cooldown_s:
            return 0
        live = self.live()
        n_live = max(1, len(live))
        per = self.router.outstanding() / n_live
        if (per > self.policy.target_inflight
                and len(live) < self.policy.max_replicas):
            changed = len(self.scale_out(1))
            if changed:
                self._last_scale = now
            return changed
        idle = [cid for cid in live
                if self._idle_for(cid, now) >= self.policy.scale_in_idle_s]
        if idle and len(live) > self.policy.min_replicas:
            changed = len(self.scale_in(1))
            if changed:
                self._last_scale = now
            return -changed
        return 0

    def stats(self) -> Dict[str, Any]:
        return {
            "replicas": len(self._replicas),
            "live": len(self.live()),
            "parked": len(self.parked()),
            "coldstarts": self.coldstarts,
            "coldstart_reuploads": self.coldstart_reuploads,
            "parks": self.parks,
            "unparks": self.unparks,
            "routed": self.router.routed,
        }
