"""Core model layers: norms, RoPE, GQA attention, MLP variants.

Everything is pure-jnp (the "reference path"): on TPU the attention inner
loops are replaced by the Pallas kernels in ``repro.kernels`` (see
``repro.models.transformer.ATTN_IMPL``); on CPU and for the dry-run the
reference path is lowered by XLA directly.

Parameters are plain pytrees of jnp arrays. Each builder also records the
*logical dims* of every leaf (e.g. ``("embed", "q_dim")``) in a parallel
pytree — ``repro.sharding.specs`` maps logical dims to mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
Dims = Any


class ParamBuilder:
    """Collects (param, logical-dims) pairs with a split PRNG key."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.dims: Dict[str, Any] = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape: Tuple[int, ...], dims: Tuple[Optional[str], ...],
            init: str = "normal", scale: Optional[float] = None) -> None:
        assert len(shape) == len(dims), (name, shape, dims)
        if init == "zeros":
            p = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            p = jnp.ones(shape, self.dtype)
        else:
            if scale is None:
                fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
                scale = 1.0 / math.sqrt(fan_in)
            p = (jax.random.normal(self._next(), shape, jnp.float32)
                 * scale).astype(self.dtype)
        self.params[name] = p
        self.dims[name] = dims

    def sub(self, name: str, builder_fn) -> None:
        b = ParamBuilder(self._next(), self.dtype)
        builder_fn(b)
        self.params[name] = b.params
        self.dims[name] = b.dims

    def build(self) -> Tuple[Params, Dims]:
        return self.params, self.dims


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm, dtype-preserving in BOTH directions.

    Plain autodiff of an f32-variance rmsnorm promotes the residual-stream
    cotangent to f32, which then rides through every backward dot and turns
    the per-layer dx all-reduces into f32 (2x bytes) — measured in §Perf
    iteration C. The custom VJP keeps [B,S,d] tangents in the compute
    dtype; only the row reductions run in f32.
    """
    return _rms_fwd(x, w, eps)[0]


def _rms_fwd(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    r = jax.lax.rsqrt(var + eps)                      # f32 [..., 1]
    y = x * r.astype(x.dtype) * w
    return y, (x, w, r)


def _rms_bwd(eps, res, dy):
    x, w, r = res
    dt = x.dtype
    d = x.shape[-1]
    s = dy * w                                        # compute dtype
    dot = jnp.sum(x * s, axis=-1, keepdims=True,
                  dtype=jnp.float32)                  # f32 [..., 1]
    coef = (r ** 3 * dot / d).astype(dt)              # [..., 1]
    dx = s * r.astype(dt) - x * coef
    dw_full = dy * x * r.astype(dt)
    dw = jnp.sum(dw_full.reshape(-1, d), axis=0,
                 dtype=jnp.float32).astype(w.dtype)
    return dx, dw


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable).

    Angles are computed in f32 (tiny [S,hd/2] tables); the rotation itself
    runs in the compute dtype — no full-tensor f32 round-trip.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles).astype(x.dtype)                   # [...,S,1,hd/2]
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ---------------------------------------------------------------------------
# Attention (reference path). Grouped-query form: KV heads are never
# materialized q_per_kv times.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  q_positions: Optional[jax.Array] = None,
                  kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """q: [B,S,Hq,hd]; k,v: [B,T,Hkv,hd] -> [B,S,Hq,hd].

    ``window`` (if set) restricts attention to the last ``window`` keys
    relative to each query (sliding-window / local attention).
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, hd)
    # scores stay in the compute dtype; softmax reductions accumulate f32
    # (§Perf iteration B — the f32 [S,T] materializations dominated bytes)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / math.sqrt(hd)

    if q_positions is None:
        q_positions = jnp.arange(S)
    if kv_positions is None:
        kv_positions = jnp.arange(T)
    rel = q_positions[:, None] - kv_positions[None, :]       # [S,T]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    neg = jnp.asarray(NEG_INF, scores.dtype)
    scores = jnp.where(mask[None, None, None], scores, neg)
    m = jax.lax.stop_gradient(
        jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)                                  # compute dtype
    denom = jnp.sum(p, axis=-1, keepdims=True,
                    dtype=jnp.float32).astype(p.dtype)
    probs = p / jnp.maximum(denom, jnp.asarray(1e-30, p.dtype))
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, Hq, hd)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float
    norm_eps: float
    window: Optional[int] = None        # sliding window, None = full
    causal: bool = True
    cross: bool = False                 # cross-attention (enc-dec)
    use_rope: bool = True


def attn_init(b: ParamBuilder, spec: AttnSpec) -> None:
    d, H, Hkv, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim
    b.add("norm", (d,), ("embed_nt",), init="ones")
    b.add("wq", (d, H, hd), ("embed", "heads", "head_dim"))
    b.add("wk", (d, Hkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wv", (d, Hkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wo", (H, hd, d), ("heads", "head_dim", "embed"),
          scale=1.0 / math.sqrt(H * hd))


def _proj(x: jax.Array, w: jax.Array) -> jax.Array:
    """[B,S,d] @ [d,H,hd] -> [B,S,H,hd]."""
    return jnp.einsum("bsd,dhk->bshk", x, w)


def _out_proj(o: jax.Array, w: jax.Array) -> jax.Array:
    """[B,S,H,hd] @ [H,hd,d] -> [B,S,d]."""
    return jnp.einsum("bshk,hkd->bsd", o, w)


def attn_qkv(p: Params, spec: AttnSpec, x: jax.Array,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    h = rmsnorm(x, p["norm"], spec.norm_eps)
    q, k, v = _proj(h, p["wq"]), _proj(h, p["wk"]), _proj(h, p["wv"])
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def attn_apply(p: Params, spec: AttnSpec, x: jax.Array, *,
               positions: jax.Array,
               memory: Optional[Tuple[jax.Array, jax.Array]] = None) -> jax.Array:
    """Self- (or cross-, if ``memory``) attention with residual."""
    if spec.cross:
        assert memory is not None
        mk, mv = memory
        h = rmsnorm(x, p["norm"], spec.norm_eps)
        q = _proj(h, p["wq"])
        out = attention_ref(q, mk, mv, causal=False)
    else:
        q, k, v = attn_qkv(p, spec, x, positions)
        out = attention_ref(q, k, v, causal=spec.causal, window=spec.window,
                            q_positions=positions, kv_positions=positions)
    return x + _out_proj(out, p["wo"])


def attn_prefill(p: Params, spec: AttnSpec, x: jax.Array, *,
                 positions: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like attn_apply but also returns the KV cache."""
    q, k, v = attn_qkv(p, spec, x, positions)
    out = attention_ref(q, k, v, causal=spec.causal, window=spec.window,
                        q_positions=positions, kv_positions=positions)
    return x + _out_proj(out, p["wo"]), {"k": k, "v": v}


def attn_decode(p: Params, spec: AttnSpec, x: jax.Array,
                cache: Dict[str, jax.Array], pos: jax.Array,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: [B,1,d]; cache k/v: [B,S_max,Hkv,hd]; pos scalar."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    h = rmsnorm(x, p["norm"], spec.norm_eps)
    q, k, v = _proj(h, p["wq"]), _proj(h, p["wk"]), _proj(h, p["wv"])
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    from repro.sharding.specs import active_axis_size, constrain
    tp = active_axis_size("tp")
    if tp > 1 and spec.n_kv_heads % tp != 0 and spec.head_dim % tp == 0:
        # KV cache is head_dim-sharded (kv_heads don't divide TP). Align
        # the (tiny) q/k/v the same way, or SPMD all-gathers the ENTIRE
        # cache at the score einsum — §Perf decode iteration E measured
        # 2.1GB/layer cache all-gathers vs 134MB score all-reduces.
        q = constrain(q, ("dp", None, None, "tp"))
        k = constrain(k, ("dp", None, None, "tp"))
        v = constrain(v, ("dp", None, None, "tp"))
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    kv_positions = jnp.arange(ck.shape[1])
    # slots beyond pos are masked by the causal relation on positions
    out = attention_ref(q, ck, cv, causal=True, window=spec.window,
                        q_positions=positions[0], kv_positions=kv_positions)
    return x + _out_proj(out, p["wo"]), {"k": ck, "v": cv}


def cross_attn_decode(p: Params, spec: AttnSpec, x: jax.Array,
                      memory: Tuple[jax.Array, jax.Array]) -> jax.Array:
    mk, mv = memory
    h = rmsnorm(x, p["norm"], spec.norm_eps)
    q = _proj(h, p["wq"])
    out = attention_ref(q, mk, mv, causal=False)
    return x + _out_proj(out, p["wo"])


def cross_attn_memory(p: Params, spec: AttnSpec,
                      enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Precompute K/V of the encoder output for cross-attention."""
    return _proj(enc_out, p["wk"]), _proj(enc_out, p["wv"])


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPSpec:
    d_model: int
    d_ff: int
    act: str                       # swiglu | squared_relu | gelu
    norm_eps: float


def mlp_init(b: ParamBuilder, spec: MLPSpec) -> None:
    d, f = spec.d_model, spec.d_ff
    b.add("norm", (d,), ("embed_nt",), init="ones")
    if spec.act == "swiglu":
        b.add("wg", (d, f), ("embed", "ff"))
        b.add("wu", (d, f), ("embed", "ff"))
    else:
        b.add("wu", (d, f), ("embed", "ff"))
    b.add("wd", (f, d), ("ff", "embed"), scale=1.0 / math.sqrt(f))


def mlp_core(p: Params, spec: MLPSpec, h: jax.Array) -> jax.Array:
    """The un-normed, un-residualed FFN body (shared with MoE experts)."""
    if spec.act == "swiglu":
        return (jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]
    if spec.act == "squared_relu":
        return jnp.square(jax.nn.relu(h @ p["wu"])) @ p["wd"]
    if spec.act == "gelu":
        return jax.nn.gelu(h @ p["wu"]) @ p["wd"]
    raise ValueError(spec.act)


def mlp_apply(p: Params, spec: MLPSpec, x: jax.Array) -> jax.Array:
    h = rmsnorm(x, p["norm"], spec.norm_eps)
    return x + mlp_core(p, spec, h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(b: ParamBuilder, vocab: int, d_model: int, tie: bool) -> None:
    b.add("embedding", (vocab, d_model), ("vocab", "embed"), scale=0.02)
    if not tie:
        b.add("unembed", (d_model, vocab), ("embed", "vocab"),
              scale=1.0 / math.sqrt(d_model))


def embed_apply(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype)


def unembed_apply(p: Params, x: jax.Array, tie: bool) -> jax.Array:
    # Logits stay in the compute dtype (bf16 for the large-vocab archs —
    # materializing f32 [B,S,V] would dominate HBM); the loss upcasts inside
    # its reductions, which XLA fuses.
    if tie:
        return jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"])
