"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, true recurrence).

TPU adaptation notes (DESIGN.md §2):
  * mLSTM trains in a chunked linear-attention form: quadratic only within
    CHUNK-sized tiles, recurrent [B,H,hd,hd] state across tiles — the same
    HBM->VMEM blocking a TPU kernel would use. We omit the paper's global
    max-stabilizer across chunks (input gate pre-activations are clipped
    instead); f32 accumulation keeps this exact within bf16 tolerance.
  * sLSTM has head-recurrent weights (h_{t-1} enters the gates), which the
    paper itself notes prevents parallelization — it is computed with
    ``lax.scan`` over time, with the standard exp-gating stabilizer state m.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.layers import ParamBuilder, rmsnorm

Params = Any
CHUNK = 128
ICLIP = 8.0          # clip on input-gate pre-activation (stabilizer stand-in)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLSTMSpec:
    d_model: int
    n_heads: int
    cfg: XLSTMConfig
    norm_eps: float

    @property
    def d_inner(self) -> int:
        return int(self.cfg.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def mlstm_init(b: ParamBuilder, spec: MLSTMSpec) -> None:
    d, dm, H, W = spec.d_model, spec.d_inner, spec.n_heads, spec.cfg.conv_width
    b.add("norm", (d,), ("embed_nt",), init="ones")
    b.add("up_proj", (d, 2 * dm), ("embed", "xl_inner"))
    b.add("conv_w", (W, dm), (None, "xl_inner_nt"), scale=1.0 / math.sqrt(W))
    b.add("conv_b", (dm,), ("xl_inner_nt",), init="zeros")
    b.add("wq", (dm, dm), ("xl_inner", "xl_inner2"))
    b.add("wk", (dm, dm), ("xl_inner", "xl_inner2"))
    b.add("wv", (dm, dm), ("xl_inner", "xl_inner2"))
    b.add("w_i", (dm, H), ("xl_inner", None), scale=0.02)
    b.add("w_f", (dm, H), ("xl_inner", None), scale=0.02)
    b.add("b_i", (H,), (None,), init="zeros")
    b.add("b_f", (H,), (None,), init="ones")
    b.add("w_o", (dm, dm), ("xl_inner", "xl_inner2"))
    b.add("down_proj", (dm, d), ("xl_inner", "embed"),
          scale=1.0 / math.sqrt(dm))


def _mlstm_qkvgates(p: Params, spec: MLSTMSpec, x: jax.Array,
                    conv_state=None):
    """x: [B,S,d] -> q,k,v [B,S,H,hd], log_i/log_f [B,S,H], o, z, conv_state."""
    from repro.models.ssm import _causal_conv
    B, S, _ = x.shape
    H, hd = spec.n_heads, spec.head_dim
    h0 = rmsnorm(x, p["norm"], spec.norm_eps)
    xu, z = jnp.split(h0 @ p["up_proj"], 2, axis=-1)
    xc, conv_state = _causal_conv(xu, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(B, S, H, hd)
    k = ((xc @ p["wk"]) / math.sqrt(hd)).reshape(B, S, H, hd)
    v = (xu @ p["wv"]).reshape(B, S, H, hd)
    log_i = jnp.clip((xc @ p["w_i"] + p["b_i"]).astype(jnp.float32),
                     -ICLIP, ICLIP)                           # [B,S,H]
    log_f = jax.nn.log_sigmoid((xc @ p["w_f"] + p["b_f"]).astype(jnp.float32))
    o = jax.nn.sigmoid(xu @ p["w_o"])                         # [B,S,dm]
    return q, k, v, log_i, log_f, o, z, conv_state


def _mlstm_forward(p: Params, spec: MLSTMSpec, x: jax.Array,
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    B, S, d = x.shape
    H, hd = spec.n_heads, spec.head_dim
    q, k, v, log_i, log_f, o, z, conv_state = _mlstm_qkvgates(p, spec, x)

    nc = max(1, S // CHUNK)
    Q = S // nc
    assert nc * Q == S

    resh = lambda t: jnp.moveaxis(t.reshape(B, nc, Q, *t.shape[2:]), 1, 0)
    qf, kf, vf = (resh(t.astype(jnp.float32)) for t in (q, k, v))
    lif, lff = resh(log_i), resh(log_f)

    def chunk(carry, inp):
        C_prev, n_prev = carry                                # [B,H,hd,hd],[B,H,hd]
        qc, kc, vc, li, lf = inp
        L = jnp.cumsum(lf, axis=1)                            # [B,Q,H]
        # intra-chunk decay matrix D[t,s] = exp(L_t - L_s + li_s), s <= t
        Dlog = L[:, :, None, :] - L[:, None, :, :] + li[:, None, :, :]
        tri = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        Dm = jnp.where(tri[None, :, :, None], jnp.exp(Dlog), 0.0)  # [B,t,s,H]
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * Dm
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vc)
        n_intra = jnp.sum(scores, axis=2)                     # [B,Q,H] = q·n (intra)
        # inter-chunk contribution
        eL = jnp.exp(L)                                       # [B,Q,H]
        y_inter = jnp.einsum("bthd,bhde->bthe", qc, C_prev) * eL[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qc, n_prev) * eL  # [B,Q,H]
        # state update
        Ltot = L[:, -1]                                       # [B,H]
        w = jnp.exp(Ltot[:, None] - L + li)                   # [B,Q,H]
        C_new = (C_prev * jnp.exp(Ltot)[..., None, None]
                 + jnp.einsum("bshd,bshe,bsh->bhde", kc, vc, w))
        n_new = (n_prev * jnp.exp(Ltot)[..., None]
                 + jnp.einsum("bshd,bsh->bhd", kc, w))
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)   # [B,Q,H]
        h = (y_intra + y_inter) / denom[..., None]            # [B,Q,H,hd]
        return (C_new, n_new), h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    (C_f, n_f), hs = jax.lax.scan(chunk, (C0, n0), (qf, kf, vf, lif, lff))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, -1).astype(x.dtype)
    out = ((h * o) * jax.nn.silu(z)) @ p["down_proj"]
    return x + out, {"C": C_f, "n": n_f, "conv": conv_state}


def mlstm_apply(p: Params, spec: MLSTMSpec, x: jax.Array) -> jax.Array:
    return _mlstm_forward(p, spec, x)[0]


def mlstm_prefill(p: Params, spec: MLSTMSpec, x: jax.Array,
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    return _mlstm_forward(p, spec, x)


def mlstm_cache_init(spec: MLSTMSpec, batch: int, dtype) -> Dict[str, Any]:
    H, hd, W = spec.n_heads, spec.head_dim, spec.cfg.conv_width
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, spec.d_inner), dtype),
    }


def mlstm_decode(p: Params, spec: MLSTMSpec, x: jax.Array,
                 cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    B = x.shape[0]
    q, k, v, log_i, log_f, o, z, conv_state = _mlstm_qkvgates(
        p, spec, x, cache["conv"])
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # [B,H,hd]
    i_g = jnp.exp(log_i[:, 0])[..., None]                     # [B,H,1]
    f_g = jnp.exp(log_f[:, 0])[..., None]
    C_new = f_g[..., None] * cache["C"] + i_g[..., None] * (
        kf[..., :, None] * vf[..., None, :])                  # [B,H,hd,hd]
    n_new = f_g * cache["n"] + i_g * kf
    y = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)), 1.0)
    h = (y / denom[..., None]).reshape(B, 1, -1).astype(x.dtype)
    out = ((h * o) * jax.nn.silu(z)) @ p["down_proj"]
    return x + out, {"C": C_new, "n": n_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLSTMSpec:
    d_model: int
    n_heads: int
    norm_eps: float

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return ((int(4 * self.d_model / 3) + 63) // 64) * 64


def slstm_init(b: ParamBuilder, spec: SLSTMSpec) -> None:
    d, H, hd = spec.d_model, spec.n_heads, spec.head_dim
    b.add("norm", (d,), ("embed_nt",), init="ones")
    b.add("wx", (d, 4 * d), ("embed", "xl_inner"))            # z,i,f,o fused
    b.add("r", (4, H, hd, hd), (None, None, None, None), scale=1.0 / math.sqrt(hd))
    b.add("bias", (4 * d,), ("xl_inner_nt",), init="zeros")
    b.add("wff_u", (d, spec.d_ff), ("embed", "ff"))
    b.add("wff_d", (spec.d_ff, d), ("ff", "embed"),
          scale=1.0 / math.sqrt(spec.d_ff))


def _slstm_cell(p: Params, spec: SLSTMSpec, xw: jax.Array, state):
    """One step. xw: [B, 4d] (precomputed x projections + bias)."""
    B = xw.shape[0]
    H, hd, d = spec.n_heads, spec.head_dim, spec.d_model
    c, n, h, m = state                                        # each [B, d] (f32)
    hh = h.reshape(B, H, hd)
    rz, ri, rf, ro = (jnp.einsum("bhd,hde->bhe", hh, p["r"][j]).reshape(B, d)
                      for j in range(4))
    z_r, i_r, f_r, o_r = jnp.split(xw, 4, axis=-1)
    z = jnp.tanh(z_r + rz)
    i_log = jnp.clip(i_r + ri, -ICLIP, ICLIP)
    f_log = jax.nn.log_sigmoid(f_r + rf)
    o = jax.nn.sigmoid(o_r + ro)
    m_new = jnp.maximum(f_log + m, i_log)
    i_p = jnp.exp(i_log - m_new)
    f_p = jnp.exp(f_log + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, h_new, m_new


def _slstm_forward(p: Params, spec: SLSTMSpec, x: jax.Array,
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    B, S, d = x.shape
    h0 = rmsnorm(x, p["norm"], spec.norm_eps)
    xw = (h0 @ p["wx"] + p["bias"]).astype(jnp.float32)       # [B,S,4d]

    def step(state, xw_t):
        state = _slstm_cell(p, spec, xw_t, state)
        return state, state[2]

    init = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))
    (c, n, hl, m), hs = jax.lax.scan(step, init, jnp.moveaxis(xw, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                # [B,S,d]
    x = x + h
    # post-block gelu FFN (xLSTM sLSTM block uses a 4/3 up-projection MLP)
    hf = rmsnorm(x, p["norm"], spec.norm_eps)
    out = x + jax.nn.gelu(hf @ p["wff_u"]) @ p["wff_d"]
    return out, {"c": c, "n": n, "h": hl, "m": m}


def slstm_apply(p: Params, spec: SLSTMSpec, x: jax.Array) -> jax.Array:
    return _slstm_forward(p, spec, x)[0]


def slstm_prefill(p: Params, spec: SLSTMSpec, x: jax.Array,
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    return _slstm_forward(p, spec, x)


def slstm_cache_init(spec: SLSTMSpec, batch: int, dtype) -> Dict[str, Any]:
    d = spec.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in ("c", "n", "h", "m")}


def slstm_decode(p: Params, spec: SLSTMSpec, x: jax.Array,
                 cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    h0 = rmsnorm(x, p["norm"], spec.norm_eps)
    xw = (h0[:, 0] @ p["wx"] + p["bias"]).astype(jnp.float32)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, spec, xw, state)
    x = x + h[:, None].astype(x.dtype)
    hf = rmsnorm(x, p["norm"], spec.norm_eps)
    out = x + jax.nn.gelu(hf @ p["wff_u"]) @ p["wff_d"]
    return out, {"c": c, "n": n, "h": h, "m": m}
