"""Mixture-of-Experts layer with capacity-based, gather/scatter dispatch.

Design notes (TPU adaptation):
  * Dispatch uses integer gathers/scatters (argsort-free slot assignment via
    cumulative per-expert counts), NOT one-hot matmuls — so HLO FLOPs reflect
    real compute and the roofline's MODEL_FLOPS/HLO_FLOPS ratio stays honest.
  * Tokens are grouped per batch example; expert capacity is per example:
    ``C = ceil(S * top_k * capacity_factor / E)``. Overflowing tokens are
    dropped (standard Switch/GShard semantics).
  * Experts are sharded over the ``ep`` mesh axis; the [B,S,d] -> [B,E,C,d]
    resharding is the MoE all-to-all, inserted by GSPMD from the sharding
    constraints below.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import MoEConfig
from repro.models.layers import MLPSpec, ParamBuilder, mlp_core, rmsnorm
from repro.sharding.specs import constrain

Params = Any


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    cfg: MoEConfig
    act: str
    norm_eps: float
    d_ff_shared: int = 0           # >0: llama4-style shared expert


def moe_capacity(seq: int, cfg: MoEConfig) -> int:
    c = math.ceil(seq * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(4, ((c + 3) // 4) * 4)


def moe_init(b: ParamBuilder, spec: MoESpec) -> None:
    d, m = spec.d_model, spec.cfg
    b.add("norm", (d,), ("embed_nt",), init="ones")
    b.add("router", (d, m.num_experts), ("embed_nt", "experts_nt"),
          scale=0.02)
    mult_gate = spec.act == "swiglu"
    if mult_gate:
        b.add("we_g", (m.num_experts, d, m.d_ff), ("experts", "moe_embed", "moe_ff"))
    b.add("we_u", (m.num_experts, d, m.d_ff), ("experts", "moe_embed", "moe_ff"))
    b.add("we_d", (m.num_experts, m.d_ff, d), ("experts", "moe_ff", "moe_embed"),
          scale=1.0 / math.sqrt(m.d_ff))
    if spec.d_ff_shared > 0:
        if mult_gate:
            b.add("ws_g", (d, spec.d_ff_shared), ("embed", "ff"))
        b.add("ws_u", (d, spec.d_ff_shared), ("embed", "ff"))
        b.add("ws_d", (spec.d_ff_shared, d), ("ff", "embed"),
              scale=1.0 / math.sqrt(spec.d_ff_shared))


def _expert_ffn(p: Params, act: str, x_e: jax.Array) -> jax.Array:
    """x_e: [B, E, C, d] -> [B, E, C, d], per-expert weights [E, d, f]."""
    if act == "swiglu":
        g = jnp.einsum("becd,edf->becf", x_e, p["we_g"])
        u = jnp.einsum("becd,edf->becf", x_e, p["we_u"])
        h = jax.nn.silu(g) * u
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("becd,edf->becf", x_e, p["we_u"])))
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", x_e, p["we_u"]))
    return jnp.einsum("becf,efd->becd", h, p["we_d"])


def moe_apply(p: Params, spec: MoESpec, x: jax.Array,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (x + moe(x), aux_loss)."""
    m = spec.cfg
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    C = moe_capacity(S, m)
    dt = x.dtype

    h = rmsnorm(x, p["norm"], spec.norm_eps)

    # --- routing: matmul in compute dtype, softmax in f32 ------------------
    # (an f32 [B,S,d] cast of h here sends f32 cotangents back through the
    # whole MoE block — §Perf MoE iteration)
    logits = (h @ p["router"].astype(dt)).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, K)              # [B,S,K]
    if K > 1:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    # --- slot assignment (order: s-major, k-minor) -------------------------
    flat_idx = expert_idx.reshape(B, S * K)                  # [B, SK]
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)    # [B, SK, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot                # count before me
    pos = jnp.take_along_axis(pos, flat_idx[..., None], axis=-1)[..., 0]  # [B,SK]
    keep = pos < C
    slot = jnp.where(keep, flat_idx * C + pos, E * C)        # E*C = drop slot

    # --- dispatch: scatter token index, gather token features -------------
    binx = jnp.arange(B)[:, None]
    token_src = jnp.zeros((B, E * C + 1), jnp.int32).at[
        binx, slot].set(jnp.arange(1, S * K + 1)[None, :], mode="drop")
    token_src = token_src[:, :E * C]                         # [B, EC]; 0=empty
    src_s = jnp.clip((token_src - 1) // K, 0, S - 1)
    x_e = jnp.take_along_axis(h, src_s[..., None], axis=1)   # [B, EC, d]
    x_e = x_e * (token_src > 0)[..., None].astype(dt)
    x_e = x_e.reshape(B, E, C, d)
    # The MoE all-to-all boundary: tokens move dp-sharded -> ep-sharded.
    # (No-op outside an activation_sharding context.) Named so the
    # "save_moe" remat policy can pin it — full remat re-executes this
    # reshard in the backward pass (§Perf MoE iteration).
    x_e = constrain(x_e, ("dp", "ep", None, None))
    x_e = checkpoint_name(x_e, "moe_dispatch")

    # --- expert compute ----------------------------------------------------
    y_e = _expert_ffn(p, spec.act, x_e).reshape(B, E * C, d)
    y_e = checkpoint_name(y_e, "moe_expert_out")

    # --- combine: gather back to token order -------------------------------
    # Pull y_e back to dp-sharded token order BEFORE the gather (one clean
    # ep->dp reshard instead of SPMD improvising per-op), and keep the
    # whole combine in the compute dtype — f32 gates promoted the entire
    # [B,S,d] combine chain to f32 (§Perf MoE iteration).
    y_e = constrain(y_e.reshape(B, E, C, d), ("dp", None, None, None))
    y_e = y_e.reshape(B, E * C, d)
    slot_c = jnp.clip(slot, 0, E * C - 1)
    y_tok = jnp.take_along_axis(y_e, slot_c[..., None], axis=1)  # [B,SK,d]
    scale = (keep.astype(jnp.float32)
             * gates.reshape(B, S * K)).astype(dt)[..., None]
    y_tok = y_tok * scale
    if K == 1:
        y = y_tok.reshape(B, S, d)
    else:
        y = y_tok.reshape(B, S, K, d).sum(axis=2)

    # --- shared expert ------------------------------------------------------
    if spec.d_ff_shared > 0:
        shared = {"wg": p.get("ws_g"), "wu": p["ws_u"], "wd": p["ws_d"]}
        y = y + mlp_core(shared, MLPSpec(spec.d_model, spec.d_ff_shared,
                                         spec.act, spec.norm_eps), h)

    # --- load-balancing aux loss (Switch-style) ----------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac_tokens * mean_probs) * E

    return x + y, aux
