"""Composable decoder stacks built from block templates.

An architecture is compiled (at trace time) into a *group program*: an
ordered list of ``Block`` templates covering one period of the arch's layer
pattern (e.g. jamba: ``[attn+mlp, mamba+moe, mamba+mlp, ...]`` — 8 layers;
gemma3: 5 sliding-window + 1 global). The full stack is a ``jax.lax.scan``
over ``n_groups`` stacked copies of the group params, so compile time is
independent of depth (96-layer nemotron lowers as fast as 12-layer xlstm).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.sharding.specs import constrain

Params = Any


@dataclasses.dataclass(frozen=True)
class Block:
    kind: str            # attn | cross_attn | mlp | moe | mamba | mlstm | slstm
    name: str
    spec: Any


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def build_group(cfg: ArchConfig) -> Tuple[List[Block], int]:
    """One period of the layer pattern + how many times it repeats."""
    if cfg.xlstm is not None:
        gs = cfg.xlstm.slstm_every
        assert cfg.n_layers % gs == 0
        blocks: List[Block] = []
        for j in range(gs):
            if j == gs - 1:
                blocks.append(Block("slstm", f"l{j}_slstm",
                                    X.SLSTMSpec(cfg.d_model, cfg.n_heads,
                                                cfg.norm_eps)))
            else:
                blocks.append(Block("mlstm", f"l{j}_mlstm",
                                    X.MLSTMSpec(cfg.d_model, cfg.n_heads,
                                                cfg.xlstm, cfg.norm_eps)))
        return blocks, cfg.n_layers // gs

    gs = 1
    if cfg.attn_pattern == "local_global":
        gs = _lcm(gs, cfg.local_global_ratio + 1)
    if cfg.attn_every > 1:
        gs = _lcm(gs, cfg.attn_every)
    if cfg.moe is not None:
        gs = _lcm(gs, cfg.moe.every)
    assert cfg.n_layers % gs == 0, (cfg.name, cfg.n_layers, gs)

    blocks = []
    for j in range(gs):
        # --- token mixer ------------------------------------------------
        if cfg.attn_every > 1 and (j % cfg.attn_every) != 0:
            blocks.append(Block("mamba", f"l{j}_mamba",
                                S.MambaSpec(cfg.d_model, cfg.ssm, cfg.norm_eps)))
        else:
            window = None
            if cfg.attn_pattern == "local_global":
                r = cfg.local_global_ratio
                if (j % (r + 1)) != r:        # last of each sub-period = global
                    window = cfg.local_window
            blocks.append(Block("attn", f"l{j}_attn", L.AttnSpec(
                cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                cfg.rope_theta, cfg.norm_eps, window=window)))
            if cfg.encoder is not None:
                blocks.append(Block("cross_attn", f"l{j}_xattn", L.AttnSpec(
                    cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                    cfg.rope_theta, cfg.norm_eps, cross=True, use_rope=False)))
        # --- channel mixer ------------------------------------------------
        if cfg.moe is not None and (j % cfg.moe.every) == cfg.moe.every - 1:
            blocks.append(Block("moe", f"l{j}_moe", M.MoESpec(
                cfg.d_model, cfg.moe, cfg.mlp_act, cfg.norm_eps,
                d_ff_shared=cfg.d_ff if cfg.moe.shared_expert else 0)))
        elif cfg.d_ff > 0:
            blocks.append(Block("mlp", f"l{j}_mlp", L.MLPSpec(
                cfg.d_model, cfg.d_ff, cfg.mlp_act, cfg.norm_eps)))
    return blocks, cfg.n_layers // gs


def build_encoder_group(cfg: ArchConfig) -> Tuple[List[Block], int]:
    e = cfg.encoder
    blocks = [
        Block("attn", "enc_attn", L.AttnSpec(
            cfg.d_model, e.n_heads, e.n_kv_heads, cfg.head_dim,
            cfg.rope_theta, cfg.norm_eps, causal=False)),
        Block("mlp", "enc_mlp", L.MLPSpec(cfg.d_model, e.d_ff, cfg.mlp_act,
                                          cfg.norm_eps)),
    ]
    return blocks, e.n_layers


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(b: L.ParamBuilder, blk: Block) -> None:
    if blk.kind in ("attn", "cross_attn"):
        L.attn_init(b, blk.spec)
    elif blk.kind == "mlp":
        L.mlp_init(b, blk.spec)
    elif blk.kind == "moe":
        M.moe_init(b, blk.spec)
    elif blk.kind == "mamba":
        S.mamba_init(b, blk.spec)
    elif blk.kind == "mlstm":
        X.mlstm_init(b, blk.spec)
    elif blk.kind == "slstm":
        X.slstm_init(b, blk.spec)
    else:
        raise ValueError(blk.kind)


def init_stack(key: jax.Array, blocks: List[Block], n_groups: int,
               dtype) -> Params:
    """Stacked params: every leaf gets a leading [n_groups] dim."""
    def one_group(k):
        b = L.ParamBuilder(k, dtype)
        for blk in blocks:
            b.sub(blk.name, lambda bb, blk=blk: _init_block(bb, blk))
        return b.params

    return jax.vmap(one_group)(jax.random.split(key, n_groups))


def stack_dims(blocks: List[Block]) -> Any:
    """Logical-dims tree matching ``init_stack`` (computed abstractly —
    no full-size allocation; safe for 340B configs)."""
    holder: Dict[str, Any] = {}

    def capture():
        db: Dict[str, Any] = {}
        outs = []
        for blk in blocks:
            b2 = L.ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
            _init_block(b2, blk)
            db[blk.name] = b2.dims
            outs.append(b2.params)
        holder["dims"] = db
        return outs

    jax.eval_shape(capture)
    return jax.tree.map(lambda d: ("layers",) + tuple(d), holder["dims"],
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------

def stack_forward(params_stack: Params, blocks: List[Block], x: jax.Array,
                  positions: jax.Array, *, enc_out: Optional[jax.Array] = None,
                  remat: bool = True, unroll: bool = False,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Scan the group program over the stacked params. Returns (x, moe_aux).

    ``unroll=True`` replaces the scan with a Python loop — used by the
    dry-run's cost probes (XLA cost_analysis counts while bodies once).
    """

    def body(carry, p_g):
        x, aux = carry
        for blk in blocks:
            p = p_g[blk.name]
            if blk.kind == "attn":
                x = L.attn_apply(p, blk.spec, x, positions=positions)
            elif blk.kind == "cross_attn":
                mem = L.cross_attn_memory(p, blk.spec, enc_out)
                x = L.attn_apply(p, blk.spec, x, positions=positions,
                                 memory=mem)
            elif blk.kind == "mlp":
                x = L.mlp_apply(p, blk.spec, x)
            elif blk.kind == "moe":
                x, a = M.moe_apply(p, blk.spec, x)
                aux = aux + a
            elif blk.kind == "mamba":
                x = S.mamba_apply(p, blk.spec, x)
            elif blk.kind == "mlstm":
                x = X.mlstm_apply(p, blk.spec, x)
            elif blk.kind == "slstm":
                x = X.slstm_apply(p, blk.spec, x)
            x = constrain(x, ("dp", "sp", None))
        return (x, aux), None

    if remat == "save_moe":
        # selective remat: keep the MoE boundary tensors so the backward
        # pass does not re-execute the dp<->ep reshard collectives
        policy = jax.checkpoint_policies.save_only_these_names(
            "moe_dispatch", "moe_expert_out")
        body_fn = jax.checkpoint(body, policy=policy)
    elif remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        n = jax.tree.leaves(params_stack)[0].shape[0]
        for i in range(n):
            p_g = jax.tree.map(lambda t, i=i: t[i], params_stack)
            carry, _ = body_fn(carry, p_g)
        return carry
    (x, aux), _ = jax.lax.scan(body_fn, carry, params_stack)
    return x, aux


# ---------------------------------------------------------------------------
# Prefill (returns decode caches) and decode
# ---------------------------------------------------------------------------

def stack_prefill(params_stack: Params, blocks: List[Block], x: jax.Array,
                  positions: jax.Array, *,
                  enc_out: Optional[jax.Array] = None,
                  cache_len: Optional[int] = None, unroll: bool = False,
                  ) -> Tuple[jax.Array, Params]:
    """Forward + per-layer cache construction. cache_len pads KV caches."""

    def body(x, p_g):
        caches: Dict[str, Any] = {}
        for blk in blocks:
            p = p_g[blk.name]
            if blk.kind == "attn":
                x, c = L.attn_prefill(p, blk.spec, x, positions=positions)
                if cache_len is not None and cache_len > c["k"].shape[1]:
                    pad = cache_len - c["k"].shape[1]
                    c = {kk: jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                         for kk, vv in c.items()}
                caches[blk.name] = c
            elif blk.kind == "cross_attn":
                mk, mv = L.cross_attn_memory(p, blk.spec, enc_out)
                x = L.attn_apply(p, blk.spec, x, positions=positions,
                                 memory=(mk, mv))
                caches[blk.name] = {"mk": mk, "mv": mv}
            elif blk.kind == "mlp":
                x = L.mlp_apply(p, blk.spec, x)
            elif blk.kind == "moe":
                x, _ = M.moe_apply(p, blk.spec, x)
            elif blk.kind == "mamba":
                x, c = S.mamba_prefill(p, blk.spec, x)
                caches[blk.name] = c
            elif blk.kind == "mlstm":
                x, c = X.mlstm_prefill(p, blk.spec, x)
                caches[blk.name] = c
            elif blk.kind == "slstm":
                x, c = X.slstm_prefill(p, blk.spec, x)
                caches[blk.name] = c
            x = constrain(x, ("dp", "sp", None))
        return x, caches

    if unroll:
        n = jax.tree.leaves(params_stack)[0].shape[0]
        caches = []
        for i in range(n):
            p_g = jax.tree.map(lambda t, i=i: t[i], params_stack)
            x, c = body(x, p_g)
            caches.append(c)
        cache_stack = jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
        return x, cache_stack
    return jax.lax.scan(body, x, params_stack)


def stack_decode(params_stack: Params, blocks: List[Block], x: jax.Array,
                 cache_stack: Params, pos: jax.Array, *,
                 unroll: bool = False) -> Tuple[jax.Array, Params]:
    """One-token decode through the stack. x: [B,1,d]."""

    def body(x, inp):
        p_g, c_g = inp
        new_c: Dict[str, Any] = {}
        for blk in blocks:
            p = p_g[blk.name]
            if blk.kind == "attn":
                x, c = L.attn_decode(p, blk.spec, x, c_g[blk.name], pos)
                new_c[blk.name] = c
            elif blk.kind == "cross_attn":
                mem = (c_g[blk.name]["mk"], c_g[blk.name]["mv"])
                x = L.cross_attn_decode(p, blk.spec, x, mem)
                new_c[blk.name] = c_g[blk.name]
            elif blk.kind == "mlp":
                x = L.mlp_apply(p, blk.spec, x)
            elif blk.kind == "moe":
                x, _ = M.moe_apply(p, blk.spec, x)
            elif blk.kind == "mamba":
                x, c = S.mamba_decode(p, blk.spec, x, c_g[blk.name])
                new_c[blk.name] = c
            elif blk.kind == "mlstm":
                x, c = X.mlstm_decode(p, blk.spec, x, c_g[blk.name])
                new_c[blk.name] = c
            elif blk.kind == "slstm":
                x, c = X.slstm_decode(p, blk.spec, x, c_g[blk.name])
                new_c[blk.name] = c
        return x, new_c

    if unroll:
        n = jax.tree.leaves(params_stack)[0].shape[0]
        caches = []
        for i in range(n):
            inp = jax.tree.map(lambda t, i=i: t[i],
                               (params_stack, cache_stack))
            x, c = body(x, inp)
            caches.append(c)
        new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
        return x, new_cache
    x, new_cache = jax.lax.scan(body, x, (params_stack, cache_stack))
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache construction + logical dims (for sharding)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, blocks: List[Block], n_groups: int,
               batch: int, cache_len: int, dtype,
               enc_len: int = 0) -> Params:
    """Zero-initialized decode cache (capacity ``cache_len``)."""
    def one(blk: Block):
        if blk.kind == "attn":
            sp = blk.spec
            shape = (n_groups, batch, cache_len, sp.n_kv_heads, sp.head_dim)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if blk.kind == "cross_attn":
            sp = blk.spec
            shape = (n_groups, batch, enc_len, sp.n_kv_heads, sp.head_dim)
            return {"mk": jnp.zeros(shape, dtype), "mv": jnp.zeros(shape, dtype)}
        if blk.kind == "mamba":
            c = S.mamba_cache_init(blk.spec, batch, dtype)
        elif blk.kind == "mlstm":
            c = X.mlstm_cache_init(blk.spec, batch, dtype)
        elif blk.kind == "slstm":
            c = X.slstm_cache_init(blk.spec, batch, dtype)
        else:
            return None
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_groups,) + t.shape), c)

    caches = {blk.name: one(blk) for blk in blocks}
    return {k: v for k, v in caches.items() if v is not None}


def cache_dims(blocks: List[Block]) -> Any:
    """Logical dims tree matching ``init_cache`` output."""
    out: Dict[str, Any] = {}
    for blk in blocks:
        if blk.kind in ("attn",):
            d = ("layers", "batch", "kvseq", "kv_heads", "head_dim")
            out[blk.name] = {"k": d, "v": d}
        elif blk.kind == "cross_attn":
            d = ("layers", "batch", "kvseq", "kv_heads", "head_dim")
            out[blk.name] = {"mk": d, "mv": d}
        elif blk.kind == "mamba":
            out[blk.name] = {"h": ("layers", "batch", "ssm_inner", None),
                             "conv": ("layers", "batch", None, "ssm_inner")}
        elif blk.kind == "mlstm":
            out[blk.name] = {"C": ("layers", "batch", None, "head_dim", None),
                             "n": ("layers", "batch", None, "head_dim"),
                             "conv": ("layers", "batch", None, "xl_inner")}
        elif blk.kind == "slstm":
            d = ("layers", "batch", "embed_nt")
            out[blk.name] = {k: d for k in ("c", "n", "h", "m")}
    return out
