from repro.models.model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
