"""Model factory: ArchConfig -> Model (init / loss / prefill / decode).

The Model is the unit the rest of the system operates on:
  * the trainer builds ``train_step`` from ``model.loss``;
  * the serve engine builds ``prefill`` / ``decode_step``;
  * the CACS checkpoint service snapshots ``{params, opt_state, data_state}``
    pytrees produced here;
  * the dry-run lowers ``train_step``/``serve_step`` from
    ``jax.eval_shape`` results — full-size configs are never materialized.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding.specs import constrain

Params = Any


def _pad_vocab(v: int) -> int:
    return ((v + 255) // 256) * 256


@jax.custom_vjp
def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Masked token CE. targets: int32, -1 = ignore.

    Dtype-preserving with a custom VJP: every [B,S,V]-shaped tensor (exp,
    softmax, one-hot, d_logits) stays in the compute dtype; only scalar/
    [B,S] reductions run in f32. §Perf iterations B1/B2 measured plain
    autodiff materializing 4-6 f32 [B,S,V] tensors per step (the f32
    cotangent of the f32-accumulated V-reduction broadcasts before the
    downcast) — this VJP removes all of them.
    """
    return _ce_fwd(logits, targets)[0]


def _ce_fwd(logits, targets):
    m = jnp.max(logits, axis=-1, keepdims=True)          # compute dtype
    ex = jnp.exp(logits - m)                             # compute dtype
    sumexp = jnp.sum(ex, axis=-1, dtype=jnp.float32)     # f32 [B,S]
    lse = m[..., 0].astype(jnp.float32) + jnp.log(sumexp)
    tgt = jnp.clip(targets, 0, logits.shape[-1] - 1)
    tl = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = lse - tl.astype(jnp.float32)
    mask = (targets >= 0).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / n
    return loss, (ex, sumexp, tgt, mask, n)


def _ce_bwd(res, g):
    ex, sumexp, tgt, mask, n = res
    dt = ex.dtype
    inv = (1.0 / sumexp).astype(dt)[..., None]           # [B,S,1]
    scale = (g * mask / n).astype(dt)[..., None]         # [B,S,1]
    onehot = jax.nn.one_hot(tgt, ex.shape[-1], dtype=dt)
    d_logits = (ex * inv - onehot) * scale               # compute dtype
    return d_logits, None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    unroll: bool = False     # python-loop the stack (dry-run cost probes)

    def __post_init__(self):
        self.blocks, self.n_groups = T.build_group(self.cfg)
        if self.cfg.encoder is not None:
            self.enc_blocks, self.enc_groups = T.build_encoder_group(self.cfg)
        else:
            self.enc_blocks, self.enc_groups = None, 0
        self.dtype = jnp.dtype(self.cfg.dtype)
        self.vocab_padded = _pad_vocab(self.cfg.vocab_size)

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_stack, k_enc = jax.random.split(key, 3)
        eb = L.ParamBuilder(k_embed, self.dtype)
        L.embed_init(eb, self.vocab_padded, cfg.d_model, cfg.tie_embeddings)
        eb.add("final_norm", (cfg.d_model,), ("embed_nt",), init="ones")
        stack = T.init_stack(k_stack, self.blocks, self.n_groups, self.dtype)
        params = {"embed": eb.params, "stack": stack}
        if self.enc_blocks is not None:
            enc_stack = T.init_stack(k_enc, self.enc_blocks,
                                     self.enc_groups, self.dtype)
            enb = L.ParamBuilder(k_enc, self.dtype)
            enb.add("final_norm", (cfg.d_model,), ("embed_nt",), init="ones")
            params["encoder"] = {"stack": enc_stack, **enb.params}
        return params

    def param_dims(self) -> Any:
        """Logical-dims pytree matching ``init`` output (no allocation)."""
        cfg = self.cfg
        dims_embed = {"embedding": ("vocab", "embed"),
                      "final_norm": ("embed_nt",)}
        if not cfg.tie_embeddings:
            dims_embed["unembed"] = ("embed", "vocab")
        dims = {"embed": dims_embed, "stack": T.stack_dims(self.blocks)}
        if self.enc_blocks is not None:
            dims["encoder"] = {"stack": T.stack_dims(self.enc_blocks),
                               "final_norm": ("embed_nt",)}
        return dims

    def abstract_params(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------
    # Shared embedding / frontend handling
    # ------------------------------------------------------------------
    def _embed_tokens(self, params: Params, tokens: jax.Array) -> jax.Array:
        return L.embed_apply(params["embed"], tokens, self.dtype)

    def _encoder_forward(self, params: Params, frames: jax.Array,
                         remat: bool) -> jax.Array:
        enc = params["encoder"]
        positions = jnp.arange(frames.shape[1])
        x = constrain(frames.astype(self.dtype), ("dp", "sp", None))
        x, _ = T.stack_forward(enc["stack"], self.enc_blocks, x, positions,
                               remat=remat, unroll=self.unroll)
        return L.rmsnorm(x, enc["final_norm"], self.cfg.norm_eps)

    def _inputs(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = True,
                ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
        """-> (x [B,S,d], positions [S], enc_out or None)."""
        cfg = self.cfg
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encoder_forward(params, batch["frames"], remat)
            x = self._embed_tokens(params, batch["tokens"])
        elif cfg.frontend is not None:           # vlm: prepend patch embeds
            tx = self._embed_tokens(params, batch["tokens"])
            fe = batch["patch_embeds"].astype(self.dtype)
            x = jnp.concatenate([fe, tx], axis=1)
        else:
            x = self._embed_tokens(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        return x, positions, enc_out

    # ------------------------------------------------------------------
    # Training loss
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array], *,
             remat=True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, positions, enc_out = self._inputs(params, batch, remat)
        x = constrain(x, ("dp", "sp", None))
        x, aux = T.stack_forward(params["stack"], self.blocks, x, positions,
                                 enc_out=enc_out, remat=remat,
                                 unroll=self.unroll)
        x = L.rmsnorm(x, params["embed"]["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], x, cfg.tie_embeddings)
        logits = constrain(logits, ("dp", None, "tp"))
        ce = cross_entropy(logits, batch["targets"])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "moe_aux": aux}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jax.Array], *,
                cache_len: Optional[int] = None,
                ) -> Tuple[jax.Array, Params]:
        """Run the prompt; returns (last-position logits [B,V], cache)."""
        cfg = self.cfg
        x, positions, enc_out = self._inputs(params, batch, remat=False)
        x = constrain(x, ("dp", "sp", None))
        x, cache = T.stack_prefill(params["stack"], self.blocks, x, positions,
                                   enc_out=enc_out, cache_len=cache_len,
                                   unroll=self.unroll)
        x = L.rmsnorm(x[:, -1:], params["embed"]["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], x, cfg.tie_embeddings)
        return logits[:, 0], cache

    def decode_step(self, params: Params, cache: Params, token: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        """token: [B,1] int32; pos: scalar int32. -> (logits [B,V], cache)."""
        cfg = self.cfg
        x = self._embed_tokens(params, token)
        x, cache = T.stack_decode(params["stack"], self.blocks, x, cache, pos,
                                  unroll=self.unroll)
        x = L.rmsnorm(x, params["embed"]["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], x, cfg.tie_embeddings)
        return logits[:, 0], cache

    # ------------------------------------------------------------------
    # Caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int) -> Params:
        enc_len = self.cfg.frontend_len if self.cfg.family == "encdec" else 0
        return T.init_cache(self.cfg, self.blocks, self.n_groups, batch,
                            cache_len, self.dtype, enc_len=enc_len)

    def abstract_cache(self, batch: int, cache_len: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len))

    def cache_dims(self) -> Any:
        return T.cache_dims(self.blocks)

    # ------------------------------------------------------------------
    # Batch construction (synthetic shapes; the data pipeline mirrors this)
    # ------------------------------------------------------------------
    def batch_struct(self, global_batch: int, seq_len: int) -> Dict[str, Any]:
        """ShapeDtypeStructs for one training batch."""
        cfg = self.cfg
        B, S = global_batch, seq_len
        sds = jax.ShapeDtypeStruct
        if cfg.family == "encdec":
            return {
                "frames": sds((B, cfg.frontend_len, cfg.d_model), self.dtype),
                "tokens": sds((B, S), jnp.int32),
                "targets": sds((B, S), jnp.int32),
            }
        if cfg.frontend is not None:
            F = cfg.frontend_len
            return {
                "patch_embeds": sds((B, F, cfg.d_model), self.dtype),
                "tokens": sds((B, S - F), jnp.int32),
                "targets": sds((B, S), jnp.int32),
            }
        return {"tokens": sds((B, S), jnp.int32),
                "targets": sds((B, S), jnp.int32)}

    def batch_dims(self) -> Dict[str, Tuple]:
        cfg = self.cfg
        out = {"tokens": ("batch", None), "targets": ("batch", None)}
        if cfg.family == "encdec":
            out["frames"] = ("batch", None, None)
        elif cfg.frontend is not None:
            out["patch_embeds"] = ("batch", None, None)
        return out


def build_model(cfg: ArchConfig, *, unroll: bool = False) -> Model:
    return Model(cfg, unroll=unroll)
