"""Mamba-1 selective SSM block (jamba's recurrent layer).

TPU adaptation: the selective scan is *chunked* — ``lax.scan`` over chunks of
``CHUNK`` steps with an in-chunk ``associative_scan``. This bounds live
buffers to [B, CHUNK, d_inner, N] (VMEM/HBM friendly) while keeping the
parallel form's O(log CHUNK) depth; the sequential carry between chunks is a
single [B, d_inner, N] state. Decode is a 1-step recurrence on that state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import ParamBuilder, rmsnorm

Params = Any
CHUNK = 256


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    cfg: SSMConfig
    norm_eps: float

    @property
    def d_inner(self) -> int:
        return self.cfg.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))


def mamba_init(b: ParamBuilder, spec: MambaSpec) -> None:
    d, di, R, N = spec.d_model, spec.d_inner, spec.dt_rank, spec.cfg.d_state
    W = spec.cfg.d_conv
    b.add("norm", (d,), ("embed_nt",), init="ones")
    b.add("in_proj", (d, 2 * di), ("embed", "ssm_inner"))
    b.add("conv_w", (W, di), (None, "ssm_inner_nt"), scale=1.0 / math.sqrt(W))
    b.add("conv_b", (di,), ("ssm_inner_nt",), init="zeros")
    b.add("x_proj", (di, R + 2 * N), ("ssm_inner", None))
    b.add("dt_proj", (R, di), (None, "ssm_inner"), scale=1.0 / math.sqrt(R))
    b.add("dt_bias", (di,), ("ssm_inner_nt",), init="zeros")
    b.add("A_log", (di, N), ("ssm_inner_nt", None), init="zeros")
    b.add("D", (di,), ("ssm_inner_nt",), init="ones")
    b.add("out_proj", (di, d), ("ssm_inner", "embed"),
          scale=1.0 / math.sqrt(di))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B,S,di]; w: [W,di]. Returns (y, new_state).

    state: [B, W-1, di] — trailing inputs from the previous segment.
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    return y, xp[:, -(W - 1):]


def _ssm_inputs(p: Params, spec: MambaSpec, x: jax.Array):
    """x: [B,S,di] (post-conv, post-silu) -> (dA [B,S,di,N], bx, C)."""
    N, R = spec.cfg.d_state, spec.dt_rank
    xdb = x @ p["x_proj"]                                     # [B,S,R+2N]
    dt_r, Bm, Cm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"]) + p["dt_bias"])  # [B,S,di]
    dt = dt.astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [di,N]
    dA = dt[..., None] * A                                    # [B,S,di,N]
    bx = (dt * x.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    return dA, bx, Cm.astype(jnp.float32)


def _scan_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, a2 * b1 + b2


def _mamba_forward(p: Params, spec: MambaSpec, x: jax.Array,
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Shared train/prefill forward. Returns (out, cache)."""
    B, S, d = x.shape
    di, N = spec.d_inner, spec.cfg.d_state
    h0 = rmsnorm(x, p["norm"], spec.norm_eps)
    xin, z = jnp.split(h0 @ p["in_proj"], 2, axis=-1)
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dA, bx, Cm = _ssm_inputs(p, spec, xc)

    nc = max(1, S // CHUNK)
    Q = S // nc
    assert nc * Q == S, f"seq {S} not divisible into chunks of {Q}"

    def chunk_body(h_carry, inp):
        dA_c, bx_c, C_c = inp                                 # [B,Q,di,N],[B,Q,N]
        decay = jnp.exp(dA_c)
        a_cum, b_cum = jax.lax.associative_scan(
            _scan_combine, (decay, bx_c), axis=1)
        h_all = a_cum * h_carry[:, None] + b_cum              # [B,Q,di,N]
        y = jnp.einsum("bqdn,bqn->bqd", h_all, C_c)
        return h_all[:, -1], y

    reshape = lambda t: jnp.moveaxis(
        t.reshape(B, nc, Q, *t.shape[2:]), 1, 0)              # [nc,B,Q,...]
    h_init = jnp.zeros((B, di, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_body, h_init,
                              (reshape(dA), reshape(bx), reshape(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)              # [B,S,di]
    y = (y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return x + out, {"h": h_last, "conv": conv_state}


def mamba_apply(p: Params, spec: MambaSpec, x: jax.Array) -> jax.Array:
    """Training forward. x: [B,S,d] -> [B,S,d] (with residual)."""
    return _mamba_forward(p, spec, x)[0]


def mamba_prefill(p: Params, spec: MambaSpec, x: jax.Array,
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    return _mamba_forward(p, spec, x)


def mamba_cache_init(spec: MambaSpec, batch: int, dtype) -> Dict[str, Any]:
    di, N, W = spec.d_inner, spec.cfg.d_state, spec.cfg.d_conv
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, di), dtype),
    }


def mamba_decode(p: Params, spec: MambaSpec, x: jax.Array,
                 cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. x: [B,1,d]."""
    B = x.shape[0]
    h0 = rmsnorm(x, p["norm"], spec.norm_eps)
    xin, z = jnp.split(h0 @ p["in_proj"], 2, axis=-1)
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], cache["conv"])
    xc = jax.nn.silu(xc)
    dA, bx, Cm = _ssm_inputs(p, spec, xc)                     # S=1
    h_new = jnp.exp(dA[:, 0]) * cache["h"] + bx[:, 0]         # [B,di,N]
    y = jnp.einsum("bdn,bn->bd", h_new, Cm[:, 0])[:, None]
    y = (y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return x + out, {"h": h_new, "conv": conv_state}
