"""Virtual cluster simulator: hosts, failures, and a calibrated cost model.

The simulator stands in for the IaaS data plane (Grid'5000 in the paper).
Costs are paper-calibrated seconds paid through the installed Clock
(repro.sim): under the default WallClock they are wall sleeps scaled by
``TIME_SCALE`` so the paper's curves (Fig 3/4/6) reproduce shape-faithfully
in seconds instead of minutes; under a SimClock they advance virtual time
instantly.  Failure injection drives the fault-tolerance integration tests.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import uuid
from typing import Callable, Dict, List, Optional

# Canonical definition lives in repro.sim.simtime; re-exported here for
# backward compatibility (chaos/benchmarks import it from this module).
from repro.sim.simtime import TIME_SCALE, active_clock


def sim_sleep(seconds: float) -> None:
    """Pay a paper-calibrated cost through the installed clock."""
    if seconds > 0:
        active_clock().paper_sleep(seconds)


class HostState(enum.Enum):
    IDLE = "idle"
    ALLOCATED = "allocated"
    FAILED = "failed"


@dataclasses.dataclass
class VirtualHost:
    host_id: str
    vcpus: int = 2
    memory_gb: int = 4
    state: HostState = HostState.IDLE
    owner: Optional[str] = None        # coordinator id
    # health-degradation knob for straggler tests: multiplier on step time
    slowdown: float = 1.0
    # network-partition knob: the host is alive and ALLOCATED but cannot be
    # reached by the monitoring tree (distinct from a crash — the IaaS does
    # NOT report partitions, so native notifications never fire for them)
    partitioned: bool = False


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated against the paper's measurements (see benchmarks/)."""
    alloc_base_s: float = 5.0          # IaaS request processing
    alloc_per_vm_s: float = 1.0        # per-VM boot cost
    alloc_batch_parallel: int = 8      # VMs booted concurrently by the IaaS
    ssh_cmd_s: float = 0.5             # one provisioning command on one VM
    ssh_connect_s: float = 1.0         # new SSH connection setup
    hop_latency_s: float = 0.05        # one monitoring-tree hop
    release_s: float = 0.5


class ClusterSim:
    """A pool of virtual hosts + failure injection."""

    def __init__(self, n_hosts: int, cost: CostModel = CostModel(),
                 name: str = "cluster"):
        self.name = name
        self.cost = cost
        self._hosts: Dict[str, VirtualHost] = {}
        self._lock = threading.RLock()
        self._failure_listeners: List[Callable[[VirtualHost], None]] = []
        self._fault_listeners: List[Callable[[str, str, float], None]] = []
        self._capacity_listeners: List[Callable[[], None]] = []
        self._allocation_listeners: List[Callable[[str, int], None]] = []
        # whole-cloud outage flag: every host partitioned AND allocation
        # denied until heal_outage() (the paper's cross-cloud failover
        # motivation — losing one entire cloud backend)
        self.in_outage = False
        # per-VM message channels (gang checkpointing): host_id -> the
        # in-flight messages addressed to it (sent, not yet received)
        self._channels: Dict[str, List] = {}
        self.messages_sent = 0
        self.messages_received = 0
        for i in range(n_hosts):
            hid = f"{name}-host-{i:04d}"
            self._hosts[hid] = VirtualHost(host_id=hid)

    # ---- capacity ------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return len(self._hosts)

    def idle_hosts(self) -> List[VirtualHost]:
        with self._lock:
            return [h for h in self._hosts.values()
                    if h.state == HostState.IDLE and not h.partitioned]

    def host(self, host_id: str) -> VirtualHost:
        return self._hosts[host_id]

    # ---- allocation ----------------------------------------------------
    def allocate(self, n: int, owner: str) -> List[VirtualHost]:
        """Claim n hosts (raises if capacity is insufficient) + boot cost."""
        with self._lock:
            idle = [h for h in self._hosts.values()
                    if h.state == HostState.IDLE and not h.partitioned]
            if len(idle) < n:
                raise CapacityError(
                    f"{self.name}: requested {n} hosts, {len(idle)} idle")
            got = idle[:n]
            for h in got:
                h.state = HostState.ALLOCATED
                h.owner = owner
        # the claim is visible (and notified) BEFORE the boot sleep: a
        # scheduler holding a capacity reservation for this owner must
        # drop it the instant the capacity counters reflect the claim,
        # or the hosts would be double-counted for the whole boot
        self._notify_allocation(owner, n)
        # boot cost: base + ceil(n / batch) * per_vm
        batches = -(-n // self.cost.alloc_batch_parallel)
        sim_sleep(self.cost.alloc_base_s + batches * self.cost.alloc_per_vm_s)
        return got

    def release(self, hosts: List[VirtualHost]) -> None:
        sim_sleep(self.cost.release_s)
        with self._lock:
            for h in hosts:
                if h.state != HostState.FAILED:
                    h.state = HostState.IDLE
                h.owner = None
                h.slowdown = 1.0
                self._channels.pop(h.host_id, None)
                # releasing a host must not punch a hole through a
                # whole-cloud outage: the partition belongs to the cloud,
                # not the owner
                if not self.in_outage:
                    h.partitioned = False
        self._notify_capacity()

    # ---- failures ------------------------------------------------------
    def fail_host(self, host_id: str) -> None:
        with self._lock:
            h = self._hosts[host_id]
            h.state = HostState.FAILED
            # a crashed host loses its channel AND every undelivered
            # message in it — the gang barrier must detect this, not
            # wait forever on an in-flight counter that can't drain
            self._channels.pop(host_id, None)
            listeners = list(self._failure_listeners)
        self._notify_fault("fail", host_id, 0.0)
        for cb in listeners:
            cb(h)

    def recover_host(self, host_id: str) -> None:
        with self._lock:
            h = self._hosts[host_id]
            h.state = HostState.IDLE
            h.owner = None
        self._notify_fault("recover", host_id, 0.0)
        self._notify_capacity()

    def degrade_host(self, host_id: str, slowdown: float) -> None:
        with self._lock:
            self._hosts[host_id].slowdown = slowdown
        self._notify_fault("degrade", host_id, slowdown)

    def partition_host(self, host_id: str) -> None:
        """Cut the host off the monitoring network without killing it.

        Unlike ``fail_host`` this fires no failure notification: the IaaS
        does not see partitions, so only the broadcast tree (or a native
        backend's unreachable-poll fallback) can detect it."""
        with self._lock:
            self._hosts[host_id].partitioned = True
        self._notify_fault("partition", host_id, 1.0)

    def heal_partition(self, host_id: str) -> None:
        with self._lock:
            self._hosts[host_id].partitioned = False
        self._notify_fault("partition", host_id, 0.0)
        self._notify_capacity()

    def cloud_outage(self) -> None:
        """Whole-cloud outage: every host — allocated or idle — becomes
        unreachable and no new capacity can be claimed until
        ``heal_outage``. Like ``partition_host``, the IaaS reports nothing:
        detection is entirely on the monitoring tree (and recovery is
        impossible on this backend — allocation raises CapacityError),
        which is exactly the situation cross-cloud standby failover
        (core/replication.py) exists for."""
        with self._lock:
            self.in_outage = True
            for h in self._hosts.values():
                h.partitioned = True
        self._notify_fault("outage", "*", 1.0)

    def heal_outage(self) -> None:
        with self._lock:
            self.in_outage = False
            for h in self._hosts.values():
                h.partitioned = False
        self._notify_fault("outage", "*", 0.0)
        self._notify_capacity()

    def on_failure(self, cb: Callable[[VirtualHost], None]) -> None:
        self._failure_listeners.append(cb)

    def on_fault(self, cb: Callable[[str, str, float], None]) -> None:
        """Subscribe to every injected fault: cb(kind, host_id, value).

        The chaos harness (core/chaos.py) uses this to build its replayable
        event trace; anything else (metrics, logging) can tap it too."""
        self._fault_listeners.append(cb)

    def on_capacity(self, cb: Callable[[], None]) -> None:
        """Subscribe to capacity-freed events: cb() fires after hosts
        become allocatable again (release, host recovery, partition/outage
        heal). The event-driven ``GlobalScheduler`` keys its scheduling
        passes on this instead of polling the wall clock."""
        self._capacity_listeners.append(cb)

    def on_allocation(self, cb: Callable[[str, int], None]) -> None:
        """Subscribe to allocation claims: ``cb(owner, n)`` fires the
        moment n hosts are claimed for ``owner`` (before the boot cost is
        paid). The scheduler releases its capacity reservation for that
        owner here — the sim's own counters carry the claim from now on."""
        self._allocation_listeners.append(cb)

    def _notify_fault(self, kind: str, host_id: str, value: float) -> None:
        for cb in list(self._fault_listeners):
            cb(kind, host_id, value)

    def _notify_capacity(self) -> None:
        for cb in list(self._capacity_listeners):
            cb()

    def _notify_allocation(self, owner: str, n: int) -> None:
        for cb in list(self._allocation_listeners):
            cb(owner, n)

    def is_reachable(self, host_id: str) -> bool:
        with self._lock:
            h = self._hosts[host_id]
            return h.state == HostState.ALLOCATED and not h.partitioned


    # ---- message transport (gang checkpointing) ------------------------
    # Per-VM message channels with in-flight counters: the simulated
    # TCP/InfiniBand fabric a distributed N-VM application exchanges
    # messages over (paper §2: "parallel and distributed computations").
    # A message is *in flight* from send until the destination host
    # receives it; the gang barrier (core/gang.py) drains these counters
    # to zero before snapshotting, so no message is lost in the cut —
    # the Chandy-Lamport / DMTCP quiesce-and-drain step made concrete.
    def channel_open(self, host_id: str) -> None:
        with self._lock:
            if host_id not in self._hosts:
                raise KeyError(f"unknown host {host_id}")
            self._channels.setdefault(host_id, [])

    def channel_close(self, host_id: str) -> None:
        with self._lock:
            self._channels.pop(host_id, None)

    def channel_send(self, src_host: str, dst_host: str, payload) -> None:
        """Deliver ``payload`` into ``dst_host``'s channel (one fabric hop).

        Raises :class:`ChannelError` when either endpoint is dead,
        partitioned, or has no open channel — a partitioned rank cannot
        talk to its peers, which is exactly what the gang barrier's
        fault detection keys on."""
        sim_sleep(self.cost.hop_latency_s)
        with self._lock:
            if not self._reachable_locked(src_host):
                raise ChannelError(f"send from unreachable host {src_host}")
            if not self._reachable_locked(dst_host):
                raise ChannelError(f"send to unreachable host {dst_host}")
            box = self._channels.get(dst_host)
            if box is None:
                raise ChannelError(f"no open channel on {dst_host}")
            box.append(payload)
            self.messages_sent += 1

    def channel_probe(self, host_id: str) -> None:
        """Control-plane ping over the fabric (one hop, delivers nothing).

        The gang barrier probes each rank at every phase boundary: a
        crashed or partitioned rank cannot echo, so the probe raises
        :class:`ChannelError` and the epoch aborts instead of waiting on
        an ack that can never arrive. Probes carry no payload so they
        never pollute the in-flight counters the drain phase freezes."""
        sim_sleep(self.cost.hop_latency_s)
        with self._lock:
            if not self._reachable_locked(host_id):
                raise ChannelError(f"probe: host {host_id} unreachable")
            if host_id not in self._channels:
                raise ChannelError(f"probe: no open channel on {host_id}")

    def channel_recv(self, host_id: str) -> List:
        """Drain and return every message currently in the host's channel
        (empties the in-flight counter for those messages)."""
        with self._lock:
            box = self._channels.get(host_id)
            if box is None:
                return []
            got, self._channels[host_id] = box, []
            self.messages_received += len(got)
            return got

    def channel_inflight(self, host_ids: Optional[List[str]] = None) -> int:
        """Messages sent but not yet received, summed over ``host_ids``
        (None = every open channel) — the gang drain-phase barrier
        condition is this hitting zero."""
        with self._lock:
            ids = self._channels.keys() if host_ids is None else host_ids
            return sum(len(self._channels.get(h, ())) for h in ids)

    def _reachable_locked(self, host_id: str) -> bool:
        h = self._hosts.get(host_id)
        return (h is not None and h.state == HostState.ALLOCATED
                and not h.partitioned)


class CapacityError(RuntimeError):
    pass


class ChannelError(RuntimeError):
    """A message-transport endpoint is unreachable (crash / partition)."""


def fresh_id(kind: str) -> str:
    return f"{kind}-{uuid.uuid4().hex[:10]}"
