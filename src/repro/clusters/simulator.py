"""Virtual cluster simulator: hosts, failures, and a calibrated cost model.

The simulator stands in for the IaaS data plane (Grid'5000 in the paper).
Costs are paper-calibrated seconds paid through the installed Clock
(repro.sim): under the default WallClock they are wall sleeps scaled by
``TIME_SCALE`` so the paper's curves (Fig 3/4/6) reproduce shape-faithfully
in seconds instead of minutes; under a SimClock they advance virtual time
instantly.  Failure injection drives the fault-tolerance integration tests.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import uuid
from typing import Callable, Dict, List, Optional

# Canonical definition lives in repro.sim.simtime; re-exported here for
# backward compatibility (chaos/benchmarks import it from this module).
from repro.sim.simtime import TIME_SCALE, active_clock


def sim_sleep(seconds: float) -> None:
    """Pay a paper-calibrated cost through the installed clock."""
    if seconds > 0:
        active_clock().paper_sleep(seconds)


class HostState(enum.Enum):
    IDLE = "idle"
    ALLOCATED = "allocated"
    FAILED = "failed"


@dataclasses.dataclass
class VirtualHost:
    host_id: str
    vcpus: int = 2
    memory_gb: int = 4
    state: HostState = HostState.IDLE
    owner: Optional[str] = None        # coordinator id
    # health-degradation knob for straggler tests: multiplier on step time
    slowdown: float = 1.0
    # network-partition knob: the host is alive and ALLOCATED but cannot be
    # reached by the monitoring tree (distinct from a crash — the IaaS does
    # NOT report partitions, so native notifications never fire for them)
    partitioned: bool = False


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated against the paper's measurements (see benchmarks/)."""
    alloc_base_s: float = 5.0          # IaaS request processing
    alloc_per_vm_s: float = 1.0        # per-VM boot cost
    alloc_batch_parallel: int = 8      # VMs booted concurrently by the IaaS
    ssh_cmd_s: float = 0.5             # one provisioning command on one VM
    ssh_connect_s: float = 1.0         # new SSH connection setup
    hop_latency_s: float = 0.05        # one monitoring-tree hop
    release_s: float = 0.5


class ClusterSim:
    """A pool of virtual hosts + failure injection."""

    def __init__(self, n_hosts: int, cost: CostModel = CostModel(),
                 name: str = "cluster"):
        self.name = name
        self.cost = cost
        self._hosts: Dict[str, VirtualHost] = {}
        self._lock = threading.RLock()
        self._failure_listeners: List[Callable[[VirtualHost], None]] = []
        self._fault_listeners: List[Callable[[str, str, float], None]] = []
        self._capacity_listeners: List[Callable[[], None]] = []
        self._allocation_listeners: List[Callable[[str, int], None]] = []
        # whole-cloud outage flag: every host partitioned AND allocation
        # denied until heal_outage() (the paper's cross-cloud failover
        # motivation — losing one entire cloud backend)
        self.in_outage = False
        for i in range(n_hosts):
            hid = f"{name}-host-{i:04d}"
            self._hosts[hid] = VirtualHost(host_id=hid)

    # ---- capacity ------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return len(self._hosts)

    def idle_hosts(self) -> List[VirtualHost]:
        with self._lock:
            return [h for h in self._hosts.values()
                    if h.state == HostState.IDLE and not h.partitioned]

    def host(self, host_id: str) -> VirtualHost:
        return self._hosts[host_id]

    # ---- allocation ----------------------------------------------------
    def allocate(self, n: int, owner: str) -> List[VirtualHost]:
        """Claim n hosts (raises if capacity is insufficient) + boot cost."""
        with self._lock:
            idle = [h for h in self._hosts.values()
                    if h.state == HostState.IDLE and not h.partitioned]
            if len(idle) < n:
                raise CapacityError(
                    f"{self.name}: requested {n} hosts, {len(idle)} idle")
            got = idle[:n]
            for h in got:
                h.state = HostState.ALLOCATED
                h.owner = owner
        # the claim is visible (and notified) BEFORE the boot sleep: a
        # scheduler holding a capacity reservation for this owner must
        # drop it the instant the capacity counters reflect the claim,
        # or the hosts would be double-counted for the whole boot
        self._notify_allocation(owner, n)
        # boot cost: base + ceil(n / batch) * per_vm
        batches = -(-n // self.cost.alloc_batch_parallel)
        sim_sleep(self.cost.alloc_base_s + batches * self.cost.alloc_per_vm_s)
        return got

    def release(self, hosts: List[VirtualHost]) -> None:
        sim_sleep(self.cost.release_s)
        with self._lock:
            for h in hosts:
                if h.state != HostState.FAILED:
                    h.state = HostState.IDLE
                h.owner = None
                h.slowdown = 1.0
                # releasing a host must not punch a hole through a
                # whole-cloud outage: the partition belongs to the cloud,
                # not the owner
                if not self.in_outage:
                    h.partitioned = False
        self._notify_capacity()

    # ---- failures ------------------------------------------------------
    def fail_host(self, host_id: str) -> None:
        with self._lock:
            h = self._hosts[host_id]
            h.state = HostState.FAILED
            listeners = list(self._failure_listeners)
        self._notify_fault("fail", host_id, 0.0)
        for cb in listeners:
            cb(h)

    def recover_host(self, host_id: str) -> None:
        with self._lock:
            h = self._hosts[host_id]
            h.state = HostState.IDLE
            h.owner = None
        self._notify_fault("recover", host_id, 0.0)
        self._notify_capacity()

    def degrade_host(self, host_id: str, slowdown: float) -> None:
        with self._lock:
            self._hosts[host_id].slowdown = slowdown
        self._notify_fault("degrade", host_id, slowdown)

    def partition_host(self, host_id: str) -> None:
        """Cut the host off the monitoring network without killing it.

        Unlike ``fail_host`` this fires no failure notification: the IaaS
        does not see partitions, so only the broadcast tree (or a native
        backend's unreachable-poll fallback) can detect it."""
        with self._lock:
            self._hosts[host_id].partitioned = True
        self._notify_fault("partition", host_id, 1.0)

    def heal_partition(self, host_id: str) -> None:
        with self._lock:
            self._hosts[host_id].partitioned = False
        self._notify_fault("partition", host_id, 0.0)
        self._notify_capacity()

    def cloud_outage(self) -> None:
        """Whole-cloud outage: every host — allocated or idle — becomes
        unreachable and no new capacity can be claimed until
        ``heal_outage``. Like ``partition_host``, the IaaS reports nothing:
        detection is entirely on the monitoring tree (and recovery is
        impossible on this backend — allocation raises CapacityError),
        which is exactly the situation cross-cloud standby failover
        (core/replication.py) exists for."""
        with self._lock:
            self.in_outage = True
            for h in self._hosts.values():
                h.partitioned = True
        self._notify_fault("outage", "*", 1.0)

    def heal_outage(self) -> None:
        with self._lock:
            self.in_outage = False
            for h in self._hosts.values():
                h.partitioned = False
        self._notify_fault("outage", "*", 0.0)
        self._notify_capacity()

    def on_failure(self, cb: Callable[[VirtualHost], None]) -> None:
        self._failure_listeners.append(cb)

    def on_fault(self, cb: Callable[[str, str, float], None]) -> None:
        """Subscribe to every injected fault: cb(kind, host_id, value).

        The chaos harness (core/chaos.py) uses this to build its replayable
        event trace; anything else (metrics, logging) can tap it too."""
        self._fault_listeners.append(cb)

    def on_capacity(self, cb: Callable[[], None]) -> None:
        """Subscribe to capacity-freed events: cb() fires after hosts
        become allocatable again (release, host recovery, partition/outage
        heal). The event-driven ``GlobalScheduler`` keys its scheduling
        passes on this instead of polling the wall clock."""
        self._capacity_listeners.append(cb)

    def on_allocation(self, cb: Callable[[str, int], None]) -> None:
        """Subscribe to allocation claims: ``cb(owner, n)`` fires the
        moment n hosts are claimed for ``owner`` (before the boot cost is
        paid). The scheduler releases its capacity reservation for that
        owner here — the sim's own counters carry the claim from now on."""
        self._allocation_listeners.append(cb)

    def _notify_fault(self, kind: str, host_id: str, value: float) -> None:
        for cb in list(self._fault_listeners):
            cb(kind, host_id, value)

    def _notify_capacity(self) -> None:
        for cb in list(self._capacity_listeners):
            cb()

    def _notify_allocation(self, owner: str, n: int) -> None:
        for cb in list(self._allocation_listeners):
            cb(owner, n)

    def is_reachable(self, host_id: str) -> bool:
        with self._lock:
            h = self._hosts[host_id]
            return h.state == HostState.ALLOCATED and not h.partitioned


class CapacityError(RuntimeError):
    pass


def fresh_id(kind: str) -> str:
    return f"{kind}-{uuid.uuid4().hex[:10]}"
