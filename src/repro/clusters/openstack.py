"""OpenStack-like backend: production-cloud latency profile, NO failure
notification API (paper §3.3: "OpenStack does not provide an API to report
infrastructure failures to clients. So the CACS service must include a
cloud-agnostic monitoring system.").
"""
from __future__ import annotations

from repro.clusters.base import SimBackend
from repro.clusters.simulator import ClusterSim, CostModel

# Calibrated to Fig 6a: OpenStack VM allocation is markedly slower and
# scales worse with VM count than Snooze's.
OPENSTACK_COST = CostModel(alloc_base_s=12.0, alloc_per_vm_s=2.0,
                           alloc_batch_parallel=4, ssh_cmd_s=0.5,
                           ssh_connect_s=1.0)


class OpenStackBackend(SimBackend):
    name = "openstack"
    supports_failure_notifications = False

    def __init__(self, n_hosts: int = 128):
        super().__init__(ClusterSim(n_hosts, OPENSTACK_COST, name="openstack"))
