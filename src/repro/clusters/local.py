"""Local ("desktop") backend — the cloudification source (paper §7.3.1):
one host, no allocation latency. Checkpointing here and restoring on a real
backend migrates a legacy job into the cloud.
"""
from __future__ import annotations

from repro.clusters.base import SimBackend
from repro.clusters.simulator import ClusterSim, CostModel

LOCAL_COST = CostModel(alloc_base_s=0.0, alloc_per_vm_s=0.0,
                       ssh_cmd_s=0.05, ssh_connect_s=0.0, release_s=0.0)


class LocalBackend(SimBackend):
    name = "local"
    supports_failure_notifications = False

    def __init__(self, n_hosts: int = 1):
        super().__init__(ClusterSim(n_hosts, LOCAL_COST, name="local"))
