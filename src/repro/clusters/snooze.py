"""Snooze-like backend: small-cloud latency profile + NATIVE failure
notifications (paper §6.1: "Snooze provides a server and VM failure
notification API that can be directly used by the Monitoring Manager").
"""
from __future__ import annotations

from typing import Callable

from repro.clusters.base import SimBackend, VMHandle
from repro.clusters.simulator import ClusterSim, CostModel


# Calibrated to Fig 6a: Snooze processes VM submissions quickly.
SNOOZE_COST = CostModel(alloc_base_s=4.0, alloc_per_vm_s=0.6,
                        alloc_batch_parallel=8, ssh_cmd_s=0.5,
                        ssh_connect_s=1.0)


class SnoozeBackend(SimBackend):
    name = "snooze"
    supports_failure_notifications = True

    def __init__(self, n_hosts: int = 128):
        super().__init__(ClusterSim(n_hosts, SNOOZE_COST, name="snooze"))

    def subscribe_failures(self, cb: Callable[[VMHandle], None]) -> None:
        def on_host_failure(host):
            vm = self._vm_by_host.get(host.host_id)
            if vm is not None:
                cb(vm)
        self.sim.on_failure(on_host_failure)
