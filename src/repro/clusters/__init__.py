from repro.clusters.base import (ClusterBackend, SimBackend, VMHandle,
                                 VMState, VMTemplate)
from repro.clusters.local import LocalBackend
from repro.clusters.openstack import OpenStackBackend
from repro.clusters.simulator import (CapacityError, ClusterSim, CostModel,
                                      HostState, VirtualHost, sim_sleep)
from repro.clusters.snooze import SnoozeBackend

__all__ = [
    "ClusterBackend", "SimBackend", "VMHandle", "VMState", "VMTemplate",
    "LocalBackend", "OpenStackBackend", "SnoozeBackend",
    "CapacityError", "ClusterSim", "CostModel", "HostState", "VirtualHost",
    "sim_sleep",
]
