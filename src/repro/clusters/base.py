"""Cloud-agnostic cluster backend API (the paper's EC2-shaped Cloud Manager
boundary, §3.3/§6.1).

The CACS service only talks to this interface. Backends differ exactly the
way the paper's do: Snooze exposes native failure notifications; OpenStack
does not (so CACS runs its own monitoring agents); and a Local backend
stands in for the user's desktop (cloudification source, §7.3.1).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional

from repro.clusters.simulator import (ClusterSim, CostModel, HostState,
                                      VirtualHost, fresh_id, sim_sleep)


class VMState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    TERMINATED = "terminated"
    FAILED = "failed"


@dataclasses.dataclass
class VMTemplate:
    vcpus: int = 1
    memory_gb: int = 2
    image: str = "ubuntu-13.10-x86_64-dmtcp"


@dataclasses.dataclass
class VMHandle:
    vm_id: str
    host: VirtualHost
    state: VMState = VMState.RUNNING

    @property
    def reachable(self) -> bool:
        return (self.state == VMState.RUNNING
                and self.host.state == HostState.ALLOCATED
                and not self.host.partitioned)


class ClusterBackend:
    """EC2-shaped VM management API."""

    name: str = "abstract"
    supports_failure_notifications: bool = False

    def allocate_vms(self, n: int, template: VMTemplate,
                     owner: str) -> List[VMHandle]:
        raise NotImplementedError

    def terminate_vms(self, vms: List[VMHandle]) -> None:
        raise NotImplementedError

    def describe_vms(self, vms: List[VMHandle]) -> Dict[str, VMState]:
        raise NotImplementedError

    def subscribe_failures(self, cb: Callable[[VMHandle], None]) -> None:
        raise NotImplementedError(
            f"{self.name} has no failure-notification API")

    def capacity(self) -> int:
        raise NotImplementedError


class SimBackend(ClusterBackend):
    """Shared implementation over the cluster simulator."""

    def __init__(self, sim: ClusterSim):
        self.sim = sim
        self._vms: Dict[str, VMHandle] = {}
        self._vm_by_host: Dict[str, VMHandle] = {}

    def allocate_vms(self, n: int, template: VMTemplate,
                     owner: str) -> List[VMHandle]:
        hosts = self.sim.allocate(n, owner)
        out = []
        for h in hosts:
            vm = VMHandle(vm_id=fresh_id("vm"), host=h)
            self._vms[vm.vm_id] = vm
            self._vm_by_host[h.host_id] = vm
            out.append(vm)
        return out

    def terminate_vms(self, vms: List[VMHandle]) -> None:
        for vm in vms:
            vm.state = VMState.TERMINATED
            self._vm_by_host.pop(vm.host.host_id, None)
        self.sim.release([vm.host for vm in vms])

    def describe_vms(self, vms: List[VMHandle]) -> Dict[str, VMState]:
        out = {}
        for vm in vms:
            if vm.state == VMState.TERMINATED:
                out[vm.vm_id] = VMState.TERMINATED
            elif vm.host.state == HostState.FAILED:
                out[vm.vm_id] = VMState.FAILED
            else:
                out[vm.vm_id] = vm.state
        return out

    def capacity(self) -> int:
        return len(self.sim.idle_hosts())
