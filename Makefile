# CACS reproduction — developer entry points.
#
#   make test            tier-1 test suite (the command ROADMAP.md pins)
#   make bench-smoke     fast benchmark subset proving the measurement paths
#   make chaos-smoke     seeded fault-recovery scenario sweep (MTTR per class)
#   make failover-smoke  seeded cross-cloud outage -> standby failover
#   make sched-smoke     seeded over-subscription scenario + property suite
#   make gang-smoke      gang barrier overhead + outage shrink-restore MTTR
#   make train-smoke     real-pytree device data path: stall/bytes/bit-exact
#   make obs-smoke       telemetry loop: save spans + EWMA slowdown detection
#   make serve-smoke     serving fleet: adopted cold starts + pooled-vs-static
#   make bench-diff      fresh gated benches vs committed baselines
#   make docs-lint       sanity-check docs: files exist, internal refs resolve

PY      ?= python
PYPATH  := src

.PHONY: test bench-smoke chaos-smoke failover-smoke sched-smoke gang-smoke \
	train-smoke obs-smoke serve-smoke bench-diff docs-lint

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only table2,table2incr,ckpt_path,pplane

# trials are cheap now that the chaos harness runs on the virtual clock
chaos-smoke:
	CHAOS_TRIALS=3 PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only fault_recovery

failover-smoke:
	FAILOVER_TRIALS=1 PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only replication

sched-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only oversubscription
	SCHED_PROP_EXAMPLES=25 PYTHONPATH=$(PYPATH) $(PY) -m pytest -q \
		tests/test_scheduler_properties.py tests/test_scheduler_chaos.py

gang-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only gang
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q \
		tests/test_gang.py tests/test_gang_chaos.py

train-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only train_ckpt
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q tests/test_train_ckpt.py

# seeded save/restore + slowdown-detection run; exports a Perfetto-viewable
# Chrome trace + JSONL spans to obs-artifacts/ (CI uploads them)
obs-smoke:
	PYTHONPATH=$(PYPATH) $(PY) scripts/obs_smoke.py --out-dir obs-artifacts
	PYTHONPATH=$(PYPATH) $(PY) scripts/trace_view.py \
		obs-artifacts/obs_smoke.trace.jsonl
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only obs

# checkpoint-backed serving fleet: million-request storm vs static fleet +
# real-stack adoption cold starts and suspend-mid-decode bit-exactness
serve-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only serve_fleet
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q \
		tests/test_serve.py tests/test_serve_fleet.py

# bench_diff diffs EVERY committed baseline, so regenerate them all here
bench-diff:
	CHAOS_TRIALS=2 FAILOVER_TRIALS=1 PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run \
		--only fault_recovery,oversubscription,gang,replication,train_ckpt,obs,serve_fleet \
		--json-dir bench-results
	$(PY) scripts/bench_diff.py --fresh bench-results

docs-lint:
	$(PY) scripts/docs_lint.py
