# CACS reproduction — developer entry points.
#
#   make test         tier-1 test suite (the command ROADMAP.md pins)
#   make bench-smoke  fast benchmark subset proving the measurement paths
#   make docs-lint    sanity-check docs: files exist, internal refs resolve

PY      ?= python
PYPATH  := src

.PHONY: test bench-smoke docs-lint

test:
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=$(PYPATH) $(PY) -m benchmarks.run --only table2,table2incr,ckpt_path,pplane

docs-lint:
	$(PY) scripts/docs_lint.py
