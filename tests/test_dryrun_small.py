"""Dry-run machinery on a small mesh (subprocess, 8 devices): lowering,
sharded compile, collective parsing, roofline math."""
import pytest

from tests.conftest import run_subprocess


def test_collective_parser():
    from repro.launch.analysis import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%add
  %t = (f32[16,16]{1,0}, f32[4]{0}) all-to-all(%a, %b)
  %cp = u8[1024]{0} collective-permute(%c)
  %ard = f32[256]{0} all-reduce-done(%ars)
  %other = f32[999]{0} add(%x, %y)
"""
    out = collective_bytes(hlo)
    assert out["all-gather_bytes"] == 8 * 128 * 2
    assert out["all-reduce_bytes"] == 256 * 4
    assert out["all-to-all_bytes"] == 16 * 16 * 4 + 4 * 4
    assert out["collective-permute_bytes"] == 1024
    per_op = {k: v for k, v in out.items()
              if k.endswith("_bytes")
              and k not in ("total_bytes", "total_link_bytes")}
    assert out["total_bytes"] == sum(per_op.values())
    # link accounting: ring all-reduce moves ~2x the buffer
    assert out["total_link_bytes"] == (out["total_bytes"]
                                       + out["all-reduce_bytes"])


def test_collective_parser_promoted_ar():
    """XLA:CPU-promoted bf16->f32 all-reduces count at native bf16 width."""
    from repro.launch.analysis import collective_bytes
    hlo = ('  %ar = f32[256]{0} all-reduce(%c), '
           'to_apply=%add.clone_promoted\n')
    out = collective_bytes(hlo)
    assert out["all-reduce_bytes"] == 256 * 4 // 2


def test_roofline_terms():
    from repro.configs import SHAPES, get_config
    from repro.launch.analysis import HBM_BW, LINK_BW, PEAK_FLOPS, roofline
    cfg = get_config("internlm2-1.8b")
    shape = SHAPES["train_4k"]
    cost = {"flops": PEAK_FLOPS, "bytes accessed": HBM_BW}
    coll = {"total_bytes": LINK_BW}
    r = roofline(cost, coll, cfg, shape, n_chips=256)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert abs(r["collective_s"] - 1.0) < 1e-9
    assert r["model_flops"] > 6 * cfg.param_count() * 256 * 4096 * 0.9


def test_small_mesh_sharded_train_step_runs():
    """Not just lower/compile — actually EXECUTE a sharded train step on an
    8-device mesh and check loss finiteness + param sharding layout."""
    run_subprocess("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.data.pipeline import TokenPipeline
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model
    from repro.sharding.specs import make_axes, param_specs
    from repro.train import AdamWConfig, init_state, make_train_step
    from repro.train.trainer import state_dims

    cfg = dataclasses.replace(reduced(get_config("llama4-scout-17b-a16e")),
                              dtype="float32")
    model = build_model(cfg)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    axes = make_axes(mesh, use_fsdp=True)
    step = jax.jit(make_train_step(model, AdamWConfig(), axes=axes))
    sds = jax.eval_shape(lambda: init_state(model, jax.random.PRNGKey(0)))
    specs = param_specs(state_dims(model), sds, axes)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(init_state(model, jax.random.PRNGKey(0)), sh)
    pipe = TokenPipeline(cfg, 4, 32, seed=0)
    with mesh:
        for _ in range(2):
            b = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, m = step(state, b)
    assert np.isfinite(float(m["loss"]))
    # MoE expert weights must actually be expert-sharded over 'model'
    we = state["params"]["stack"]["l0_moe"]["we_u"]
    assert "model" in str(we.sharding.spec), we.sharding
    print("loss", float(m["loss"]))
    """, devices=8, timeout=560)


def test_dryrun_cell_on_small_mesh():
    """build_cell + lower_and_analyze end-to-end on a 2x4 mesh."""
    run_subprocess("""
    import json
    import repro.launch.lowering as low
    from repro.launch.mesh import make_test_mesh

    # shrink the production shapes through the same code path
    import repro.configs as C
    mesh = make_test_mesh((2, 4), ("data", "model"))
    cell_args = dict(arch="internlm2-1.8b", shape="train_4k")
    # monkeypatch the shape grid to a tiny stand-in for CPU speed
    import repro.configs.base as base
    tiny = base.ShapeConfig("train_4k", 256, 8, "train")
    C.SHAPES["train_4k"] = tiny
    low.SHAPES["train_4k"] = tiny
    out = low.lower_and_analyze(cell_args, mesh, full_compile=True)
    assert out["flops_per_device"] > 0
    assert out["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert out["memory_analysis"]["argument_size_in_bytes"] > 0
    assert 0 < out["roofline"]["useful_flops_ratio"] < 2.0
    print(json.dumps(out["roofline"]))
    """, devices=8, timeout=560)
