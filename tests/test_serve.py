"""Serving engine + CACS-hosted serving: suspend/resume mid-generation must
not change the generated token stream."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.models import build_model
from repro.obs.telemetry import registry
from repro.serve.engine import Engine, ServeApp
from repro.sim.simtime import active_clock

CFG = dataclasses.replace(reduced(get_config("repro-100m")), dtype="float32")


class _FlakyServe(ServeApp):
    """ServeApp whose decode raises once ``fail_at`` tokens exist."""

    def __init__(self, *args, fail_at=4, **kwargs):
        super().__init__(*args, **kwargs)
        self._fail_at = fail_at

    def _build(self):
        super()._build()
        real = self.engine.decode

        def decode(cache, token, pos):
            if self.generated >= self._fail_at:
                raise RuntimeError("chaos: device lost mid-decode")
            return real(cache, token, pos)
        self.engine.decode = decode


class _GatedServe(ServeApp):
    """ServeApp whose decode parks on a wall event while it holds the
    donated cache — reproduces the surrendered-slot window at will."""

    def __init__(self, *args, gate_at=2, **kwargs):
        super().__init__(*args, **kwargs)
        self._gate_at = gate_at
        self.entered = threading.Event()
        self.release = threading.Event()

    def _build(self):
        super()._build()
        real = self.engine.decode

        def decode(cache, token, pos):
            if self.generated >= self._gate_at and not self.release.is_set():
                self.entered.set()
                self.release.wait(30)
            return real(cache, token, pos)
        self.engine.decode = decode


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """ServeApp's token_delay_s / capture polls sleep on active_clock();
    riding the shared SimClock turns those delays into instant virtual
    jumps (the suspend-resume test no longer wall-sleeps ~2.4s)."""
    yield


def test_engine_generate_shapes():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, cache_len=48)
    toks = jnp.ones((2, 16), jnp.int32)
    out = engine.generate({"tokens": toks}, 8)
    assert out.shape == (2, 8)
    assert out.dtype == jnp.int32
    assert int(out.max()) < model.vocab_padded


def test_generate_deterministic():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    e1 = Engine(model, params, cache_len=48)
    e2 = Engine(model, params, cache_len=48)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab_size, (1, 16)),
        jnp.int32)
    np.testing.assert_array_equal(np.asarray(e1.generate({"tokens": toks}, 8)),
                                  np.asarray(e2.generate({"tokens": toks}, 8)))


def test_serve_app_suspend_resume_token_stream_unchanged():
    """Job-swapping applied to inference: the interrupted stream equals the
    uninterrupted one."""
    n_tokens = 24
    ref = ServeApp(CFG, batch=1, prompt_len=8, n_tokens=n_tokens,
                   cache_len=40)
    ref.start(None, None)
    while not ref.is_done():
        time.sleep(0.02)
    ref.stop()
    ref_tokens = ref.checkpoint_state()["tokens_out"]

    backend = SnoozeBackend(4)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    try:
        asr = ASR(name="serve", n_vms=1, backend="snooze",
                  app_factory=lambda: ServeApp(CFG, batch=1, prompt_len=8,
                                               n_tokens=n_tokens,
                                               cache_len=40,
                                               token_delay_s=0.1),
                  policy=CheckpointPolicy(period_s=0))
        cid = svc.submit(asr)
        svc.wait_for_state(cid, CoordState.RUNNING, 60)
        coord = svc.db.get(cid)
        while coord.app.generated < 4:
            time.sleep(0.02)
        svc.apps.suspend(cid)
        gen_at_suspend = coord.app.generated
        assert gen_at_suspend < n_tokens
        svc.apps.resume(cid)
        coord = svc.db.get(cid)
        while not coord.app.is_done():
            time.sleep(0.05)
        out = coord.app.checkpoint_state()["tokens_out"]
        assert coord.app.restarts == 1
        np.testing.assert_array_equal(out[:, :ref_tokens.shape[1]],
                                      ref_tokens)
    finally:
        svc.shutdown()


def test_decode_failure_restores_cache_and_flips_health():
    """Regression: a decode exception used to leave the donated-cache slot
    None forever — every later capture (suspend, snapshot) deadlocked and
    healthy() stayed True on a dead loop."""
    before = registry().value("serve.decode_failures", 0.0)
    app = _FlakyServe(CFG, batch=1, prompt_len=8, n_tokens=24, cache_len=40,
                      fail_at=3)
    app.start(None, None)
    app._thread.join(timeout=30)
    assert not app._thread.is_alive(), "decode thread should have died"
    assert app.healthy() is False
    assert app.cache is not None, "donated slot must be restored on failure"
    # capture still works (swap-out after the fault), without deadlock
    state = app.checkpoint_state()
    assert state["generated"] == 3
    assert state["tokens_out"].shape == (1, 3)
    assert registry().value("serve.decode_failures", 0.0) == before + 1
    assert app.stop() is False


def test_capture_blocks_without_advancing_virtual_time(sim_clock,
                                                       monkeypatch):
    """Regression: _capture busy-polled ``clock.sleep(0.001)`` while a
    decode held the donated cache — on a SimClock each poll jumped virtual
    time forward, re-timing every pending deadline in the process. The
    capture thread must never sleep on the installed clock (spied on
    directly: daemons leaked by earlier tests may legitimately advance the
    shared clock, so a now()-didn't-move assertion would be flaky)."""
    app = _GatedServe(CFG, batch=1, prompt_len=8, n_tokens=24, cache_len=40,
                      gate_at=2)
    app.start(None, None)
    try:
        assert app.entered.wait(30), "decode never reached the gate"
        clock = active_clock()
        sleeper_idents = []
        real_sleep = clock.sleep

        def spy(dt):
            sleeper_idents.append(threading.get_ident())
            return real_sleep(dt)
        monkeypatch.setattr(clock, "sleep", spy)
        got = {}

        def grab():
            got["state"] = app.checkpoint_state()
        t = threading.Thread(target=grab, daemon=True)
        t.start()
        time.sleep(0.3)          # wall time: capture must still be pinned
        assert t.is_alive(), "capture returned during the donated window"
        app.release.set()
        t.join(timeout=30)
        assert not t.is_alive()
        assert got["state"]["generated"] >= 2
        assert t.ident not in sleeper_idents, \
            "capture slept on the installed clock during the donated window"
    finally:
        app.release.set()
        app.stop()


def test_stop_timeout_counts_leaked_decode_thread():
    """Regression: stop() joined with a timeout and returned regardless —
    a wedged decode thread leaked silently. It must be detected, counted
    in serve.stop_timeouts (with the last error as note) and reported."""
    before = registry().value("serve.stop_timeouts", 0.0)
    app = _GatedServe(CFG, batch=1, prompt_len=8, n_tokens=24, cache_len=40,
                      gate_at=2)
    app.start(None, None)
    try:
        assert app.entered.wait(30), "decode never reached the gate"
        leaked = app.stop(join_s=0.2)
        assert leaked is True
        assert registry().value("serve.stop_timeouts", 0.0) == before + 1
    finally:
        app.release.set()
        app._thread.join(timeout=30)
    assert not app._thread.is_alive()
    assert app.stop() is False
