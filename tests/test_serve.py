"""Serving engine + CACS-hosted serving: suspend/resume mid-generation must
not change the generated token stream."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import ASR, CACSService, CheckpointPolicy, CoordState
from repro.models import build_model
from repro.serve.engine import Engine, ServeApp

CFG = dataclasses.replace(reduced(get_config("repro-100m")), dtype="float32")


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """ServeApp's token_delay_s / capture polls sleep on active_clock();
    riding the shared SimClock turns those delays into instant virtual
    jumps (the suspend-resume test no longer wall-sleeps ~2.4s)."""
    yield


def test_engine_generate_shapes():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, cache_len=48)
    toks = jnp.ones((2, 16), jnp.int32)
    out = engine.generate({"tokens": toks}, 8)
    assert out.shape == (2, 8)
    assert out.dtype == jnp.int32
    assert int(out.max()) < model.vocab_padded


def test_generate_deterministic():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    e1 = Engine(model, params, cache_len=48)
    e2 = Engine(model, params, cache_len=48)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab_size, (1, 16)),
        jnp.int32)
    np.testing.assert_array_equal(np.asarray(e1.generate({"tokens": toks}, 8)),
                                  np.asarray(e2.generate({"tokens": toks}, 8)))


def test_serve_app_suspend_resume_token_stream_unchanged():
    """Job-swapping applied to inference: the interrupted stream equals the
    uninterrupted one."""
    n_tokens = 24
    ref = ServeApp(CFG, batch=1, prompt_len=8, n_tokens=n_tokens,
                   cache_len=40)
    ref.start(None, None)
    while not ref.is_done():
        time.sleep(0.02)
    ref.stop()
    ref_tokens = ref.checkpoint_state()["tokens_out"]

    backend = SnoozeBackend(4)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    try:
        asr = ASR(name="serve", n_vms=1, backend="snooze",
                  app_factory=lambda: ServeApp(CFG, batch=1, prompt_len=8,
                                               n_tokens=n_tokens,
                                               cache_len=40,
                                               token_delay_s=0.1),
                  policy=CheckpointPolicy(period_s=0))
        cid = svc.submit(asr)
        svc.wait_for_state(cid, CoordState.RUNNING, 60)
        coord = svc.db.get(cid)
        while coord.app.generated < 4:
            time.sleep(0.02)
        svc.apps.suspend(cid)
        gen_at_suspend = coord.app.generated
        assert gen_at_suspend < n_tokens
        svc.apps.resume(cid)
        coord = svc.db.get(cid)
        while not coord.app.is_done():
            time.sleep(0.05)
        out = coord.app.checkpoint_state()["tokens_out"]
        assert coord.app.restarts == 1
        np.testing.assert_array_equal(out[:, :ref_tokens.shape[1]],
                                      ref_tokens)
    finally:
        svc.shutdown()
