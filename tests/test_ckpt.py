"""Checkpoint substrate: roundtrip identity (property-based), atomic
commit, retention, codecs, two-tier durability."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # bare env: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.ckpt import (AsyncCheckpointer, InMemoryStore, TwoTierStore,
                        latest_step, list_steps, restore, save_checkpoint)
from repro.ckpt import gc as ckpt_gc
from repro.ckpt.layout import COMMITTED, step_prefix
from repro.ckpt.reader import load_manifest


@settings(max_examples=20, deadline=None)
@given(st.lists(
    st.tuples(
        st.lists(st.integers(1, 7), min_size=0, max_size=3),  # shape
        st.sampled_from(["float32", "int32", "bfloat16", "float16"])),
    min_size=1, max_size=5),
    st.integers(0, 2 ** 31 - 1))
def test_roundtrip_identity_property(leaf_specs, seed):
    """Any pytree of arrays round-trips bit-exactly through save/restore."""
    rng = np.random.Generator(np.random.PCG64(seed))
    tree = {}
    for i, (shape, dtype) in enumerate(leaf_specs):
        if dtype == "int32":
            arr = rng.integers(-1000, 1000, shape).astype(np.int32)
        else:
            arr = rng.standard_normal(shape).astype(np.float32)
        tree[f"leaf{i}"] = jnp.asarray(arr).astype(dtype)
    tree["nested"] = {"scalar": 42, "pair": (tree["leaf0"], 3.5)}
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, tree)
    out, man = restore(store, "p")
    for (pa, va), (pb, vb) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    assert out["nested"]["scalar"] == 42
    assert isinstance(out["nested"]["pair"], tuple)


def test_uncommitted_checkpoint_invisible():
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, {"x": jnp.ones(4)})
    save_checkpoint(store, "p", 2, {"x": jnp.ones(4) * 2})
    # simulate crash between manifest write and commit of step 2
    store.delete(f"{step_prefix('p', 2)}/{COMMITTED}")
    assert latest_step(store, "p") == 1
    out, man = restore(store, "p")
    assert man.step == 1
    with pytest.raises(FileNotFoundError):
        load_manifest(store, "p", 2)


def test_gc_retention():
    store = InMemoryStore()
    for s in range(1, 11):
        save_checkpoint(store, "p", s, {"x": jnp.ones(4) * s})
    deleted = ckpt_gc.collect(store, "p", keep_last=2, keep_every=5)
    assert list_steps(store, "p") == [5, 9, 10]
    assert 1 in deleted and 5 not in deleted
    # chunks of deleted steps actually removed
    assert not store.list(step_prefix("p", 1))


@pytest.mark.parametrize("codec", ["raw", "zlib", "int8", "int8+zlib"])
def test_codecs(codec):
    store = InMemoryStore()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(10_000),
                    jnp.float32)
    ints = jnp.arange(100, dtype=jnp.int32)      # int leaves stay lossless
    save_checkpoint(store, "p", 1, {"x": x, "i": ints}, codec=codec)
    out, _ = restore(store, "p")
    np.testing.assert_array_equal(np.asarray(out["i"]), np.asarray(ints))
    if codec in ("raw", "zlib"):
        np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
    else:
        err = np.abs(np.asarray(out["x"]) - np.asarray(x)).max()
        assert err < np.abs(np.asarray(x)).max() / 127.0 * 0.51 + 1e-6


def test_compressed_smaller():
    rng = np.random.default_rng(0)
    smooth = jnp.asarray(np.cumsum(rng.standard_normal(100_000) * 1e-3),
                         jnp.float32)
    sizes = {}
    for codec in ("raw", "zlib", "int8+zlib"):
        store = InMemoryStore()
        save_checkpoint(store, "p", 1, {"x": smooth}, codec=codec)
        sizes[codec] = store.total_bytes()
    assert sizes["zlib"] < sizes["raw"]
    assert sizes["int8+zlib"] < 0.35 * sizes["raw"]


def test_two_tier_survives_local_loss():
    local, remote = InMemoryStore(), InMemoryStore()
    tt = TwoTierStore(local, remote)
    save_checkpoint(tt, "p", 1, {"x": jnp.arange(100.0)})
    tt.drop_local()
    out, _ = restore(tt, "p")
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.arange(100.0, dtype=np.float32))
    tt.close()


def test_async_checkpointer_double_buffer():
    store = InMemoryStore(latency_s=0.01)
    ck = AsyncCheckpointer(store, "p")
    for s in range(1, 6):
        ck.save(s, {"x": jnp.ones(1000) * s})
    ck.wait()
    assert ck.last_committed == 5
    assert latest_step(store, "p") == 5
    # every step restorable and correct (no torn writes under overlap)
    for s in (1, 3, 5):
        out, _ = restore(store, "p", step=s)
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.full(1000, float(s), np.float32))
    ck.close()


def test_localfs_store(tmp_path):
    from repro.ckpt import LocalFSStore
    store = LocalFSStore(str(tmp_path))
    save_checkpoint(store, "p", 1, {"w": jnp.ones((3, 3), jnp.bfloat16)})
    out, _ = restore(store, "p")
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.ones((3, 3), np.float32))
