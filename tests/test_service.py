"""CACS service integration: lifecycle, periodic checkpoints, both failure
recovery paths, suspend/resume, straggler handling, termination cleanup."""
import time

import pytest

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        SimulatedApp)
from tests.conftest import run_subprocess  # noqa: F401  (shared helper)


@pytest.fixture
def snooze_svc():
    backend = SnoozeBackend(n_hosts=16)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    yield svc, backend
    svc.shutdown()


@pytest.fixture
def ostack_svc():
    backend = OpenStackBackend(n_hosts=16)
    svc = CACSService({"openstack": backend}, {"default": InMemoryStore()})
    yield svc, backend
    svc.shutdown()


def _submit(svc, backend_name, n_vms=4, period=0.15, **app_kw):
    asr = ASR(name="app", n_vms=n_vms, backend=backend_name,
              app_factory=lambda: SimulatedApp(iter_time_s=0.5, state_mb=0.05,
                                               **app_kw),
              policy=CheckpointPolicy(period_s=period, keep_last=3))
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=30)
    return cid


def _wait_recovered(svc, cid, n, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        c = svc.db.get(cid)
        if c.recoveries >= n and c.state == CoordState.RUNNING:
            return c
        time.sleep(0.02)
    raise TimeoutError(f"no recovery #{n}; state={svc.db.get(cid).state}")


def test_lifecycle_and_periodic_checkpoints(snooze_svc):
    svc, _ = snooze_svc
    cid = _submit(svc, "snooze")
    time.sleep(0.7)
    cks = svc.list_checkpoints(cid)
    assert len(cks) >= 2, "periodic checkpoints missing"
    assert len(cks) <= 3, "gc keep_last=3 violated"
    info = svc.get_checkpoint(cid, cks[-1])
    assert info["bytes"] > 0 and info["leaves"] >= 2
    final = svc.delete_coordinator(cid)
    assert final["state"] == "TERMINATED"
    # §5.4: all references removed
    assert not svc.ckpt.store().list(f"apps/{cid}")
    assert all(c["id"] != cid for c in svc.list_coordinators())


def test_vm_failure_native_notifications(snooze_svc):
    svc, backend = snooze_svc
    cid = _submit(svc, "snooze")
    time.sleep(0.4)
    coord = svc.db.get(cid)
    backend.sim.fail_host(coord.vms[1].host.host_id)
    c = _wait_recovered(svc, cid, 1)
    assert c.app.restarts == 1
    assert all(vm.reachable for vm in c.vms), "failed VM not replaced"
    assert svc.apps.monitor.native_notifications >= 1


def test_vm_failure_polling_path(ostack_svc):
    svc, backend = ostack_svc
    cid = _submit(svc, "openstack")
    time.sleep(0.4)
    coord = svc.db.get(cid)
    backend.sim.fail_host(coord.vms[0].host.host_id)
    c = _wait_recovered(svc, cid, 1)
    assert c.app.restarts == 1
    assert svc.apps.monitor.native_notifications == 0  # agent-based only


def test_app_failure_restarts_in_place(snooze_svc):
    svc, _ = snooze_svc
    cid = _submit(svc, "snooze")
    time.sleep(0.4)
    coord = svc.db.get(cid)
    vms_before = [vm.vm_id for vm in coord.vms]
    coord.app.poison()
    c = _wait_recovered(svc, cid, 1)
    # paper §6.3 case 2: same VMs, app restarted from image
    assert [vm.vm_id for vm in c.vms] == vms_before
    assert c.app.restarts == 1
    assert c.app.iteration > 0        # restored from checkpoint, not zero


def test_recovery_restores_latest_state(snooze_svc):
    svc, backend = snooze_svc
    cid = _submit(svc, "snooze")
    time.sleep(0.6)
    coord = svc.db.get(cid)
    it_at_ckpt = coord.app.checkpoint_state()["iteration"]
    backend.sim.fail_host(coord.vms[0].host.host_id)
    c = _wait_recovered(svc, cid, 1)
    time.sleep(0.2)
    assert c.app.iteration >= max(1, it_at_ckpt - 50)


def test_suspend_resume_preserves_progress(snooze_svc):
    svc, backend = snooze_svc
    cid = _submit(svc, "snooze")
    time.sleep(0.4)
    it_before = svc.db.get(cid).app.iteration
    svc.apps.suspend(cid)
    c = svc.db.get(cid)
    assert c.state == CoordState.SUSPENDED and not c.vms
    idle_during = len(backend.sim.idle_hosts())
    svc.apps.resume(cid)
    c = svc.db.get(cid)
    assert c.state == CoordState.RUNNING
    time.sleep(0.3)
    assert c.app.iteration >= it_before   # no lost progress
    assert len(backend.sim.idle_hosts()) == idle_during - 4


def test_straggler_triggers_proactive_suspend(snooze_svc):
    svc, backend = snooze_svc
    cid = _submit(svc, "snooze", n_vms=8)
    time.sleep(0.3)
    coord = svc.db.get(cid)
    backend.sim.degrade_host(coord.vms[0].host.host_id, slowdown=100.0)
    deadline = time.monotonic() + 20
    while time.monotonic() < deadline:
        if svc.db.get(cid).state == CoordState.SUSPENDED:
            break
        time.sleep(0.02)
    assert svc.db.get(cid).state == CoordState.SUSPENDED
    # the image exists, so the scheduler can resume it elsewhere
    assert svc.list_checkpoints(cid)


def test_service_restart_rehydrates_and_resumes():
    """§6.4 restartability end-to-end: a service instance dies (no clean
    shutdown); a fresh instance over the same stores rehydrates the
    coordinator record via CoordinatorDB.load and — after the caller
    re-attaches an app factory — restarts the job from its images."""
    from repro.ckpt import InMemoryStore as _Store
    ckpt_store, db_store = _Store(), _Store()
    svc1 = CACSService({"snooze": SnoozeBackend(n_hosts=8)},
                       {"default": ckpt_store}, db_store=db_store)
    asr = ASR(name="app", n_vms=2, backend="snooze",
              app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                               state_mb=0.05),
              policy=CheckpointPolicy(period_s=0, keep_last=3))
    cid = svc1.submit(asr)
    svc1.wait_for_state(cid, CoordState.RUNNING, timeout=30)
    time.sleep(0.2)
    step = svc1.trigger_checkpoint(cid)
    it_saved = svc1.ckpt.load(svc1.db.get(cid), step)["iteration"]
    # simulate a service-instance crash: daemons stop, no terminate — the
    # record stays in the db store and the images in the ckpt store
    svc1.apps.stop_daemons()

    svc2 = CACSService({"snooze": SnoozeBackend(n_hosts=8)},
                       {"default": ckpt_store}, db_store=db_store)
    try:
        coord = svc2.db.get(cid)              # rehydrated on start
        assert coord.state == CoordState.RUNNING   # last persisted state
        assert coord.vms == [] and coord.app is None
        assert svc2.list_checkpoints(cid) == [step]
        coord.asr.app_factory = lambda: SimulatedApp(iter_time_s=0.5,
                                                     state_mb=0.05)
        svc2.restart_from(cid, step)
        c = svc2.wait_for_state(cid, CoordState.RUNNING, timeout=30)
        assert c.app.iteration >= it_saved    # resumed from the image
        assert len(c.vms) == 2
    finally:
        svc2.shutdown()
        svc1.provision.close()


def test_restart_from_earlier_image(snooze_svc):
    svc, _ = snooze_svc
    cid = _submit(svc, "snooze", period=0.0)
    time.sleep(0.2)
    s1 = svc.trigger_checkpoint(cid)
    time.sleep(0.4)
    s2 = svc.trigger_checkpoint(cid)
    it_s2 = svc.db.get(cid).app.iteration
    info1 = svc.get_checkpoint(cid, s1)
    svc.restart_from(cid, s1)          # user picks an EARLIER image
    c = svc.db.get(cid)
    assert c.state == CoordState.RUNNING
    assert c.app.checkpoint_state()["iteration"] <= max(it_s2, 1)
    assert info1["step"] == s1
