"""Gang jobs under seeded chaos, end to end through the service.

Two storylines, both bit-for-bit replayable on the virtual clock:

  * mid-barrier faults — a rank-scoped storage fault, a straggler, a
    partition and a rank crash each fired INSIDE a snapshot's barrier.
    Every epoch aborts all-or-nothing: the torn step never becomes
    visible, the previous committed gang image restores at full rank
    count, and the plane heals (next snapshot commits, or the normal
    recovery cycle replaces the lost VM).
  * cloud outage → elastic shrink — the GlobalScheduler requeues the
    4-rank gang off the dead cloud and shrink-restores it onto 2
    surviving ranks of another cloud, with zero chunk re-uploads and
    every shared chunk fetched exactly once.
"""
import time

import pytest

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, ChaosController, CheckpointPolicy,
                        CoordState, FaultEvent, FaultKind, FaultSchedule,
                        GangApp, GlobalScheduler)
from repro.core.chaos import VirtualClock, run_gang_scenario
from repro.sim import active_clock


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    yield


def _gang_schedule(seed):
    return FaultSchedule(seed=seed, events=[
        FaultEvent(at_s=2.0, kind=FaultKind.GANG_BARRIER_PUT_FAULT,
                   vm_index=seed % 4, n_ops=3, phase="save"),
        FaultEvent(at_s=6.0, kind=FaultKind.GANG_BARRIER_STRAGGLER,
                   vm_index=(seed + 1) % 4, slowdown=200.0),
        FaultEvent(at_s=14.0, kind=FaultKind.GANG_BARRIER_PARTITION,
                   vm_index=(seed + 2) % 4, phase="drain"),
        FaultEvent(at_s=26.0, kind=FaultKind.GANG_BARRIER_CRASH,
                   vm_index=(seed + 3) % 4, phase="drain"),
    ])


def test_mid_barrier_faults_abort_all_or_nothing():
    res = run_gang_scenario(_gang_schedule(3), settle_timeout_s=120)
    assert res.all_ok, res.to_dict()["outcomes"]
    assert res.final_state == "RUNNING"
    # every event aborted exactly one epoch; crash + partition each drove
    # one full recovery cycle off the intact previous image
    reasons = [o.detail for o in res.outcomes]
    assert "abort=store_fault" in reasons[0]
    assert "abort=straggler" in reasons[1]
    assert "abort=partition_or_crash" in reasons[2]
    assert "abort=partition_or_crash" in reasons[3]
    assert res.recoveries >= 2
    assert all(o.trace_id.startswith("tr-gang-") for o in res.outcomes)


def test_gang_chaos_trace_replays_bit_for_bit():
    r1 = run_gang_scenario(_gang_schedule(5), settle_timeout_s=120)
    r2 = run_gang_scenario(_gang_schedule(5), settle_timeout_s=120)
    assert r1.all_ok and r2.all_ok
    assert r1.trace == r2.trace
    assert [o.trace_id for o in r1.outcomes] \
        == [o.trace_id for o in r2.outcomes]


def _run_shrink_scenario(seed):
    """4-rank gang on cloud A (Snooze, 8 hosts); cloud B (OpenStack) has
    only 2 hosts. Both clouds read the same object store, so the warm
    zero-re-upload gate passes without a replicator. An outage of A must
    end with the gang resharded onto B's 2 survivors."""
    a = SnoozeBackend(n_hosts=8)
    b = OpenStackBackend(n_hosts=2)
    store = InMemoryStore()
    svc = CACSService({"snooze": a, "openstack": b}, {"default": store})
    sched = GlobalScheduler(svc, clock=VirtualClock(),
                            cloud_stores={"snooze": "default",
                                          "openstack": "default"})
    svc.attach_scheduler(sched)
    sched.start()
    try:
        cid = sched.submit(ASR(
            name=f"gang-{seed}", n_vms=4, backend="snooze", priority=5,
            app_factory=lambda: GangApp(global_rows=16, iter_time_s=0.05),
            policy=CheckpointPolicy(period_s=0, keep_last=3),
            gang=True, min_vms=2))
        svc.wait_for_state(cid, CoordState.RUNNING, 30)
        active_clock().paper_sleep(1.0)
        svc.trigger_checkpoint(cid)        # committed gang image at 4 ranks
        schedule = FaultSchedule(seed=seed, events=[
            FaultEvent(at_s=2.0, kind=FaultKind.CLOUD_OUTAGE)])
        ctrl = ChaosController(svc, cid, a, schedule, scheduler=sched,
                               settle_timeout_s=120)
        outcomes = ctrl.run()
        coord = svc.db.get(cid)
        deadline = time.monotonic() + 30
        while (time.monotonic() < deadline
               and coord.state != CoordState.RUNNING):
            active_clock().sleep(0.01)
        it0 = coord.app.min_iteration()
        active_clock().paper_sleep(1.0)    # survivors must make progress
        return {
            "ok": all(o.ok for o in outcomes),
            "trace": [o.trace_key() for o in outcomes],
            "decisions": [t[1:] for t in sched.decision_trace()],
            "state": coord.state.value,
            "backend": coord.asr.backend,
            "n_vms": len(coord.vms),
            "asr_n_vms": coord.asr.n_vms,
            "metrics": dict(coord.metrics),
            "shrinks": sched.shrinks,
            "requeues": sched.requeues,
            "progressed": coord.app.min_iteration() > it0,
            "restarts": coord.app.restarts,
        }
    finally:
        sched.stop()
        svc.shutdown()


def test_outage_shrink_restores_gang_onto_surviving_ranks():
    res = _run_shrink_scenario(seed=9)
    assert res["ok"], res["trace"]
    assert res["state"] == "RUNNING"
    assert res["backend"] == "openstack"
    assert res["n_vms"] == 2 and res["asr_n_vms"] == 2, \
        "the gang must land on exactly the 2 survivors"
    assert res["metrics"]["gang_full_vms"] == 4
    assert res["shrinks"] == 1 and res["requeues"] == 1
    # zero-re-upload invariant holds across the shrink
    assert res["metrics"]["backfill_reuploads"] == 0
    # reshard-on-restore fetched every shared chunk exactly once
    assert res["metrics"]["gang_restore_ranks"] == 2
    assert (res["metrics"]["gang_restore_fetches"]
            == res["metrics"]["gang_restore_unique"])
    assert res["progressed"], "survivors must resume the computation"
    assert res["restarts"] == 1
    ops = [d[0] for d in res["decisions"]]
    assert ops == ["submit", "start", "requeue", "backfill", "shrink"]


def test_outage_shrink_replays_bit_for_bit():
    r1 = _run_shrink_scenario(seed=13)
    r2 = _run_shrink_scenario(seed=13)
    assert r1["ok"] and r2["ok"]
    assert r1["trace"] == r2["trace"]
    assert r1["decisions"] == r2["decisions"]
    assert r1["n_vms"] == r2["n_vms"] == 2


def test_gang_without_image_never_places_below_full_size():
    """All-or-nothing: a fresh gang job (no committed image yet) must not
    start on fewer VMs than asked, even when min_vms would allow it."""
    b = OpenStackBackend(n_hosts=2)
    svc = CACSService({"openstack": b}, {"default": InMemoryStore()})
    sched = GlobalScheduler(svc, clock=VirtualClock(),
                            cloud_stores={"openstack": "default"})
    svc.attach_scheduler(sched)
    sched.start()
    try:
        cid = sched.submit(ASR(
            name="gang-fresh", n_vms=4, backend="openstack", priority=5,
            app_factory=lambda: GangApp(global_rows=8, iter_time_s=0.05),
            policy=CheckpointPolicy(period_s=0),
            gang=True, min_vms=2))
        active_clock().paper_sleep(2.0)
        sched.tick()
        active_clock().paper_sleep(1.0)
        coord = svc.db.get(cid)
        assert coord.state == CoordState.QUEUED
        assert sched.shrinks == 0
    finally:
        sched.stop()
        svc.shutdown()
