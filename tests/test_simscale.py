"""Large-scale determinism + soak tests for the discrete-event engine.

These are the acceptance teeth for the virtual-time work: a seeded
scenario with >= 1,000 hosts and >= 24 simulated hours must finish in
well under 10s of wall time and replay byte-identically, and a
week-long 10,000-lifecycle soak with mixed faults must hold the
capacity-safety / no-starvation / bounded-rollback invariants while
staying inside a tight wall budget.
"""
import time

import pytest

from repro.sim import SimEngine

WALL_BUDGET_ACCEPT_S = 10.0      # the ISSUE acceptance bound
WALL_BUDGET_SOAK_S = 30.0        # generous for slow CI; ~1.5s locally


def _day_scale_engine(seed: int) -> SimEngine:
    eng = SimEngine(n_hosts=1000, seed=seed, host_mtbf_s=200_000.0)
    eng.load(n_jobs=3000, horizon_s=86_400.0)
    eng.run()
    return eng


# ---------------------------------------------------------------------------
# acceptance: 1,000 hosts x 24 simulated hours, < 10s wall, replayable
# ---------------------------------------------------------------------------

def test_thousand_hosts_one_day_under_wall_budget():
    t0 = time.monotonic()
    eng = _day_scale_engine(seed=7)
    wall = time.monotonic() - t0
    assert wall < WALL_BUDGET_ACCEPT_S, \
        f"24 simulated hours on 1000 hosts took {wall:.2f}s wall"
    assert eng.now >= 86_400.0 * 0.9          # ran (nearly) the full day
    assert eng.completed == 3000
    assert eng.recoveries > 0                  # faults actually fired
    assert eng.events_fired > 20_000


def test_thousand_host_trace_replays_byte_identically():
    a = _day_scale_engine(seed=7)
    b = _day_scale_engine(seed=7)
    assert a.trace_digest() == b.trace_digest()
    assert a.trace_bytes() == b.trace_bytes()
    # and a different seed genuinely changes the trace
    c = _day_scale_engine(seed=8)
    assert c.trace_digest() != a.trace_digest()


# ---------------------------------------------------------------------------
# soak: 1,000 hosts x 10,000 job lifecycles x a simulated week
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def soak():
    """Arrivals packed into 4 days (utilisation ~0.96) so the preemption
    and aging paths are genuinely exercised; faults span the whole week."""
    t0 = time.monotonic()
    eng = SimEngine(n_hosts=1000, seed=11, host_mtbf_s=2_592_000.0)
    eng.load(n_jobs=10_000, horizon_s=7 * 86_400.0,
             arrival_horizon_s=4 * 86_400.0, mean_work_s=7200.0)
    eng.run()
    eng.wall_s = time.monotonic() - t0
    return eng


def test_soak_simulates_a_week_within_wall_budget(soak):
    assert soak.wall_s < WALL_BUDGET_SOAK_S, \
        f"week-long soak took {soak.wall_s:.2f}s wall"
    assert soak.now >= 6 * 86_400.0            # a real week-scale horizon
    assert soak.events_fired > 100_000


def test_soak_no_starvation_every_lifecycle_completes(soak):
    assert soak.completed == 10_000
    unfinished = [j.jid for j in soak.jobs if j.finished_at < 0]
    assert unfinished == []


def test_soak_exercises_preemption_and_recovery(soak):
    assert soak.preemptions > 100, "load should force real preemption"
    assert soak.recoveries > 50, "mtbf should force real host faults"


def test_soak_capacity_safety_and_work_conservation(soak):
    # deep checks already ran every DEEP_CHECK_EVERY events during run();
    # re-assert the terminal state explicitly
    soak.check_invariants()
    soak.assert_work_conserving()
    assert soak.used == 0 and len(soak.free) == soak.n_hosts
    assert soak.host_job == {}


def test_soak_rollback_bounded_by_checkpoint_period(soak):
    """No fault may lose more progress than one checkpoint period."""
    period = 900.0
    losses = []
    for line in soak.trace:
        parts = line.split()
        if parts[1] == "fault" and len(parts) > 4:
            losses.append(float(parts[4].split("=", 1)[1]))
    assert losses, "no occupied-host faults in the soak trace"
    worst = max(losses)
    assert worst <= period + 1e-6, \
        f"a fault lost {worst:.1f}s of work (> ckpt period {period}s)"


def test_soak_trace_digest_is_stable(soak):
    """Replay the identical config and require byte equality — the trace
    is the regression artifact for the whole scheduling/fault policy."""
    eng = SimEngine(n_hosts=1000, seed=11, host_mtbf_s=2_592_000.0)
    eng.load(n_jobs=10_000, horizon_s=7 * 86_400.0,
             arrival_horizon_s=4 * 86_400.0, mean_work_s=7200.0)
    eng.run()
    assert eng.trace_digest() == soak.trace_digest()
