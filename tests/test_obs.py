"""Unified telemetry tests (ISSUE 9): metrics registry, span tracer,
checkpoint-lifecycle instrumentation, low-performance detection, daemon
error counters, and deterministic trace export."""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.ckpt import (DataPlaneConfig, InMemoryStore, restore,
                        save_checkpoint)
from repro.ckpt.plane import ByteBudget
from repro.obs import (MetricsRegistry, SampleView, Tracer, use_registry,
                       use_tracer)
from repro.obs.telemetry import unique_name


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    reg.inc("c", 2)
    reg.inc("c")
    assert reg.value("c") == 3.0
    reg.set_gauge("g", 5.0)
    reg.set_gauge("g", 2.0)
    g = reg.gauge("g")
    assert g.value == 2.0 and g.high_water == 5.0
    reg.gauge_max("g", 9.0)                  # ratchets high-water only
    assert g.value == 2.0 and g.high_water == 9.0
    h = reg.histogram("h")
    for v in (0.001, 0.5, 100.0):
        h.observe(v)
    assert h.count == 3 and h.min == 0.001 and h.max == 100.0
    assert abs(h.sum - 100.501) < 1e-9


def test_registry_snapshot_sorted_and_typed():
    reg = MetricsRegistry()
    reg.inc("b.count")
    reg.set_gauge("a.level", 1.0)
    reg.histogram("c.lat").observe(0.2)
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["b.count"]["type"] == "counter"
    assert snap["a.level"]["type"] == "gauge"
    assert snap["c.lat"]["type"] == "histogram"
    assert reg.snapshot(prefix="a.") .keys() == {"a.level"}


def test_metric_type_clash_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("c", 5)
    reg.set_gauge("g", 1.0)
    reg.histogram("h").observe(3.0)
    assert reg.value("c") == 0.0
    assert reg.gauge("g").value == 0.0
    assert reg.histogram("h").count == 0


def test_counter_note_keeps_last_error():
    reg = MetricsRegistry()
    reg.inc("errs", note="ValueError: first")
    reg.inc("errs", note="KeyError: second")
    c = reg.counter("errs")
    assert c.value == 2.0
    assert c.note == "KeyError: second"
    assert c.as_dict()["note"] == "KeyError: second"


def test_sample_view_is_list_like():
    reg = MetricsRegistry()
    h = reg.histogram(unique_name("view.test"))
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    view = SampleView(h)
    assert len(view) == 3
    assert view[0] == 0.1 and view[-1] == 0.3
    assert list(view) == [0.1, 0.2, 0.3]
    assert view == [0.1, 0.2, 0.3]
    with pytest.raises((TypeError, AttributeError)):
        view.append(0.4)                     # read-only: no list mutators


def test_trainer_and_serve_stalls_are_views():
    # the attribute survived the histogram migration as a read-only
    # property (tier-1 test_train_ckpt exercises the live path)
    from repro.serve.engine import ServeApp
    from repro.train.trainer import TrainerApp
    assert isinstance(TrainerApp.ckpt_stalls, property)
    assert isinstance(ServeApp.ckpt_stalls, property)


# ---------------------------------------------------------------------------
# tracer primitives
# ---------------------------------------------------------------------------

def test_span_nesting_and_trace_id_inheritance():
    tr = Tracer()
    with tr.span("outer", cat="a", trace_id="tr-1") as outer:
        with tr.span("inner", cat="a") as inner:
            assert tr.current() is inner
        tr.event("ping")
        assert tr.current() is outer
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent is spans["outer"]
    assert spans["inner"].trace_id == "tr-1"      # inherited
    assert spans["ping"].trace_id == "tr-1"
    assert spans["outer"].duration >= 0.0


def test_span_records_error_and_reraises():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    (sp,) = tr.spans(name="boom")
    assert sp.args["error"] == "ValueError"


def test_tracer_cap_counts_drops():
    tr = Tracer(max_records=3)
    for i in range(5):
        tr.event(f"e{i}")
    assert len(tr.spans()) == 3
    assert tr.dropped == 2


def test_exports_parse_and_correlate():
    tr = Tracer()
    with tr.span("save", cat="ckpt", trace_id="tr-9", args={"step": 1}):
        tr.event("upload", cat="ckpt")
    rows = [json.loads(l) for l in tr.to_jsonl().splitlines()]
    assert {r["name"] for r in rows} == {"save", "upload"}
    assert all(r["trace_id"] == "tr-9" for r in rows)
    by_name = {r["name"]: r for r in rows}
    assert by_name["upload"]["parent"] == by_name["save"]["id"]
    doc = json.loads(tr.to_chrome())
    names = [e["name"] for e in doc["traceEvents"]]
    assert "save" in names and "upload" in names and "thread_name" in names
    phases = {e["name"]: e["ph"] for e in doc["traceEvents"]}
    assert phases["upload"] == "i"               # instant event


# ---------------------------------------------------------------------------
# checkpoint-path instrumentation
# ---------------------------------------------------------------------------

def _tree():
    rng = np.random.Generator(np.random.PCG64(3))
    return {"w": rng.standard_normal(2048), "b": rng.standard_normal(64)}


def test_save_restore_spans_and_counters():
    with use_registry(MetricsRegistry()) as reg, use_tracer(Tracer()) as tr:
        store = InMemoryStore()
        save_checkpoint(store, "x", 1, _tree(), codec="zlib",
                        trace_id="tr-sr")
        restore(store, "x", trace_id="tr-sr")
        for name in ("ckpt/save", "ckpt/materialize", "ckpt/encode",
                     "ckpt/upload", "ckpt/manifest", "ckpt/commit",
                     "ckpt/restore", "restore/plan", "restore/fetch_decode",
                     "restore/assemble"):
            assert tr.spans(name=name, trace_id="tr-sr"), f"missing {name}"
        assert reg.value("ckpt.saves") == 1.0
        assert reg.value("ckpt.chunks") >= 2.0
        assert reg.value("ckpt.bytes_written") > 0.0


def test_byte_budget_wait_and_high_water_metrics():
    with use_registry(MetricsRegistry()) as reg:
        budget = ByteBudget(100, name="tb")
        budget.acquire(80)
        blocked = threading.Event()

        def late():
            budget.acquire(50)               # must wait for the release
            blocked.set()

        t = threading.Thread(target=late, daemon=True)
        t.start()
        time.sleep(0.05)
        assert not blocked.is_set()
        budget.release(80)
        assert blocked.wait(5.0)
        t.join(5.0)
        assert reg.histogram("tb.budget_wait_s").count == 1
        assert reg.gauge("tb.inflight_bytes").high_water == 80.0


# ---------------------------------------------------------------------------
# low-performance detection + daemon error counters
# ---------------------------------------------------------------------------

def test_lowperf_detector_fires_after_grace(sim_clock):
    from repro.core.monitoring import LowPerfConfig, MonitoringManager
    from repro.sim import active_clock
    with use_registry(MetricsRegistry()) as reg:
        mon = MonitoringManager(
            lambda cid, kind: None,
            lowperf=LowPerfConfig(warmup_samples=2, grace_polls=2,
                                  min_window_s=0.5))
        counter = {"v": 0.0}
        mon.watch("c1", [], None, False, perf_fn=lambda: counter["v"],
                  trace_id="tr-perf")
        info = mon._watched["c1"]
        clk = active_clock()

        def sample(rate):
            counter["v"] += rate             # 1 paper-second window
            clk.paper_sleep(1.0)
            return mon._check_perf("c1", info)

        assert not sample(2.0)               # warmup 1
        assert not sample(2.0)               # warmup 2 -> baseline 2.0
        assert info["perf_baseline"] == pytest.approx(2.0)
        fired = [sample(0.05) for _ in range(8)]
        assert any(fired), "EWMA collapse under 0.4x baseline must fire"
        assert fired.count(True) == 1        # exactly once per watch
        assert not sample(0.05)              # stays fired
        assert reg.value("app.throughput:c1", -1) >= 0.0
        assert reg.gauge("app.throughput_ewma:c1").value < 0.8


def test_lowperf_healthy_app_never_fires(sim_clock):
    from repro.core.monitoring import LowPerfConfig, MonitoringManager
    with use_registry(MetricsRegistry()):
        from repro.sim import active_clock
        mon = MonitoringManager(
            lambda cid, kind: None,
            lowperf=LowPerfConfig(warmup_samples=2, grace_polls=2,
                                  min_window_s=0.5))
        counter = {"v": 0.0}
        mon.watch("c2", [], None, False, perf_fn=lambda: counter["v"])
        info = mon._watched["c2"]
        clk = active_clock()
        for _ in range(12):                  # steady pace
            counter["v"] += 2.0
            clk.paper_sleep(1.0)
            assert not mon._check_perf("c2", info)


def test_appmgr_guarded_errors_counted():
    from repro.clusters import SnoozeBackend
    from repro.core.service import CACSService
    with use_registry(MetricsRegistry()) as reg:
        backend = SnoozeBackend(n_hosts=2)
        svc = CACSService({backend.name: backend}, start_daemons=False)
        try:
            svc.apps._guarded(lambda: 1 / 0)
        finally:
            svc.shutdown()
        assert reg.value("appmgr.op_errors") == 1.0
        assert "ZeroDivisionError" in reg.counter("appmgr.op_errors").note


def test_ckpt_daemon_error_counted():
    from repro.clusters import SnoozeBackend
    from repro.core.application import SimulatedApp
    from repro.core.coordinator import ASR, CheckpointPolicy, CoordState
    from repro.core.service import CACSService
    with use_registry(MetricsRegistry()) as reg:
        backend = SnoozeBackend(n_hosts=2)
        svc = CACSService({backend.name: backend})
        asr = ASR(name="dmn", n_vms=1, backend=backend.name,
                  app_factory=lambda: SimulatedApp(iter_time_s=0.05,
                                                   state_mb=0.01),
                  policy=CheckpointPolicy(period_s=0.05))
        cid = svc.submit(asr)
        try:
            svc.wait_for_state(cid, CoordState.RUNNING, timeout=30)

            def boom(*a, **kw):
                raise RuntimeError("daemon boom")

            svc.apps.checkpoint_now = boom   # periodic save now explodes
            deadline = time.monotonic() + 10
            while (reg.value("appmgr.daemon_errors") == 0
                   and time.monotonic() < deadline):
                time.sleep(0.01)
        finally:
            del svc.apps.checkpoint_now      # terminate needs the real one
            svc.shutdown()
        assert reg.value("appmgr.daemon_errors") >= 1.0
        note = reg.counter("appmgr.daemon_errors").note
        assert "RuntimeError: daemon boom" in note


def test_replication_daemon_error_counted():
    from repro.clusters import SnoozeBackend
    from repro.core.application import SimulatedApp
    from repro.core.coordinator import ASR, CheckpointPolicy, CoordState
    from repro.core.replication import (ImageReplicator, ReplicationPolicy,
                                        StandbyTarget)
    from repro.core.service import CACSService
    with use_registry(MetricsRegistry()) as reg:
        backend = SnoozeBackend(n_hosts=2)
        svc = CACSService({backend.name: backend}, start_daemons=False)
        asr = ASR(name="rep", n_vms=1, backend=backend.name,
                  app_factory=lambda: SimulatedApp(iter_time_s=0.05,
                                                   state_mb=0.01),
                  policy=CheckpointPolicy(period_s=0.0))
        cid = svc.submit(asr)
        try:
            svc.wait_for_state(cid, CoordState.RUNNING, timeout=30)
            rep = ImageReplicator(svc)
            rep.add_target(StandbyTarget("dr", InMemoryStore(), "cloud"))
            rep.watch(cid, ReplicationPolicy(targets=("dr",)))

            def boom(*a, **kw):
                raise OSError("standby store down")

            rep._sync_pair = boom            # the swallowed-except path
            rep.sync()
        finally:
            svc.shutdown()
        assert reg.value("replication.daemon_errors") == 1.0
        note = reg.counter("replication.daemon_errors").note
        assert "OSError: standby store down" in note
        assert rep.sync_errors == 1


# ---------------------------------------------------------------------------
# deterministic export (same discipline as the SimEngine trace digests)
# ---------------------------------------------------------------------------

_DET_SNIPPET = """
import sys
sys.path.insert(0, {src!r})
import hashlib
import numpy as np
from repro.ckpt import DataPlaneConfig, InMemoryStore, restore, \\
    save_checkpoint
from repro.obs import MetricsRegistry, Tracer, use_registry, use_tracer
from repro.sim import SimClock, use_clock


def run_once():
    clk = SimClock()
    try:
        with use_clock(clk), use_registry(MetricsRegistry()) as reg, \\
                use_tracer(Tracer()) as tr:
            rng = np.random.Generator(np.random.PCG64(7))
            tree = {{"a": rng.standard_normal(512),
                     "nest": {{"b": rng.standard_normal(256)}}}}
            store = InMemoryStore()
            plane = DataPlaneConfig.serial()
            save_checkpoint(store, "x", 1, tree, codec="zlib", plane=plane,
                            trace_id="tr-det-0000")
            restore(store, "x", plane=plane, trace_id="tr-det-0000")
            snap = repr(sorted(reg.snapshot().items()))
            return tr.to_jsonl(), tr.to_chrome(), snap
    finally:
        clk.close()


a, b = run_once(), run_once()
assert a[0] == b[0], "JSONL export diverged across replays"
assert a[1] == b[1], "Chrome export diverged across replays"
assert a[2] == b[2], "registry snapshot diverged across replays"
print(hashlib.sha256("".join(a).encode()).hexdigest())
"""


def _run_det(hashseed: str) -> str:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), os.pardir, "src"))
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    r = subprocess.run(
        [sys.executable, "-c", _DET_SNIPPET.format(src=src)],
        capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, f"determinism subprocess failed:\n{r.stderr}"
    return r.stdout


def test_trace_export_deterministic_across_processes():
    """Same seed => byte-identical JSONL + Chrome exports, within a
    process (assert inside the snippet) AND across processes with
    different hash seeds (PYTHONHASHSEED-proof, like SimEngine traces)."""
    assert _run_det("0") == _run_det("1")
