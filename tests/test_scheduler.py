"""Priority scheduler: preemption (job swapping), queueing, resume order."""
import time

import pytest

from repro.ckpt import InMemoryStore
from repro.clusters import SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        PriorityScheduler, SimulatedApp)


@pytest.fixture
def env():
    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    sched = PriorityScheduler(svc, "snooze")
    yield svc, sched, backend
    sched.stop()
    svc.shutdown()


def _asr(name, n_vms, priority):
    return ASR(name=name, n_vms=n_vms, backend="snooze", priority=priority,
               app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                                state_mb=0.01),
               policy=CheckpointPolicy(period_s=0))


def test_high_priority_preempts_low(env):
    svc, sched, backend = env
    low = sched.submit(_asr("low", 6, priority=1))
    svc.wait_for_state(low, CoordState.RUNNING, 20)
    hi = sched.submit(_asr("hi", 6, priority=9))
    assert hi is not None, "should preempt, not queue"
    svc.wait_for_state(hi, CoordState.RUNNING, 20)
    assert svc.db.get(low).state == CoordState.SUSPENDED
    assert sched.preemptions == 1
    # low resumes when hi completes
    svc.delete_coordinator(hi)
    sched.tick()
    assert svc.db.get(low).state == CoordState.RUNNING
    assert sched.resumes == 1


def test_equal_priority_queues_instead_of_preempting(env):
    svc, sched, backend = env
    a = sched.submit(_asr("a", 6, priority=5))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    b = sched.submit(_asr("b", 6, priority=5))
    assert b is None, "equal priority must queue, not preempt"
    assert sched.queue_depth == 1
    assert svc.db.get(a).state == CoordState.RUNNING
    svc.delete_coordinator(a)
    sched.tick()
    assert sched.queue_depth == 0


def test_no_preemption_when_it_would_not_fit(env):
    svc, sched, backend = env
    a = sched.submit(_asr("a", 3, priority=1))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    # 5 idle; need 12: even preempting a (3) only frees 8 total
    b = sched.submit(_asr("b", 12, priority=9))
    assert b is None
    assert svc.db.get(a).state == CoordState.RUNNING, \
        "must not preempt when the high-prio job still can't fit"
    assert sched.preemptions == 0


def test_background_loop_drains_queue(env):
    svc, sched, backend = env
    sched.start()
    a = sched.submit(_asr("a", 8, priority=5))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    b = sched.submit(_asr("b", 4, priority=5))
    assert b is None
    svc.delete_coordinator(a)
    deadline = time.monotonic() + 20
    while time.monotonic() < deadline:
        running = [c for c in svc.db.list()
                   if c.state == CoordState.RUNNING]
        if sched.queue_depth == 0 and len(running) == 1:
            break
        time.sleep(0.05)
    assert sched.queue_depth == 0
    running = [c for c in svc.db.list() if c.state == CoordState.RUNNING]
    assert len(running) == 1 and running[0].asr.name == "b"
