"""GlobalScheduler: preemption (job swapping), queueing, aging, queue
persistence, cross-cloud backfill, and the lock/rollback invariants."""
import time

import pytest

from repro.ckpt import InMemoryStore
from repro.ckpt.storage import FaultyStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        GlobalScheduler, ImageReplicator, ReplicationPolicy,
                        SimulatedApp, StandbyTarget)
from repro.sim import active_clock


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """Run this suite on the discrete-event virtual clock (repro.sim)."""
    yield



@pytest.fixture
def env():
    backend = SnoozeBackend(n_hosts=8)
    svc = CACSService({"snooze": backend}, {"default": InMemoryStore()})
    sched = GlobalScheduler(svc)
    svc.attach_scheduler(sched)
    yield svc, sched, backend
    sched.stop()
    svc.shutdown()


def _asr(name, n_vms, priority, backend="snooze", **kw):
    return ASR(name=name, n_vms=n_vms, backend=backend, priority=priority,
               app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                                state_mb=0.01),
               policy=CheckpointPolicy(period_s=0), **kw)


def test_high_priority_preempts_low(env):
    svc, sched, backend = env
    low = sched.submit(_asr("low", 6, priority=1))
    svc.wait_for_state(low, CoordState.RUNNING, 20)
    hi = sched.submit(_asr("hi", 6, priority=9))
    svc.wait_for_state(hi, CoordState.RUNNING, 20)
    assert svc.db.get(low).state == CoordState.SUSPENDED
    assert sched.preemptions == 1
    # low resumes when hi completes
    svc.delete_coordinator(hi)
    sched.tick()
    svc.wait_for_state(low, CoordState.RUNNING, 20)
    assert sched.resumes == 1


def test_equal_priority_queues_instead_of_preempting(env):
    svc, sched, backend = env
    a = sched.submit(_asr("a", 6, priority=5))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    b = sched.submit(_asr("b", 6, priority=5))
    assert svc.db.get(b).state == CoordState.QUEUED, \
        "equal priority must queue, not preempt"
    assert sched.queue_depth == 1
    assert svc.db.get(a).state == CoordState.RUNNING
    svc.delete_coordinator(a)
    sched.tick()
    assert sched.queue_depth == 0
    svc.wait_for_state(b, CoordState.RUNNING, 20)


def test_no_preemption_when_it_would_not_fit(env):
    svc, sched, backend = env
    a = sched.submit(_asr("a", 3, priority=1))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    # 5 idle; need 12: even preempting a (3) only frees 8 total
    b = sched.submit(_asr("b", 12, priority=9))
    assert svc.db.get(b).state == CoordState.QUEUED
    assert svc.db.get(a).state == CoordState.RUNNING, \
        "must not preempt when the high-prio job still can't fit"
    assert sched.preemptions == 0


def test_background_loop_drains_queue(env):
    svc, sched, backend = env
    sched.start()
    a = sched.submit(_asr("a", 8, priority=5))
    svc.wait_for_state(a, CoordState.RUNNING, 20)
    b = sched.submit(_asr("b", 4, priority=5))
    assert svc.db.get(b).state == CoordState.QUEUED
    svc.delete_coordinator(a)
    # event-driven: releasing a's hosts kicks the scheduler — no polling
    svc.wait_for_state(b, CoordState.RUNNING, 20)
    assert sched.queue_depth == 0


def test_preemption_is_all_or_nothing(env, monkeypatch):
    """Partial-preemption leak regression: when the Nth victim's swap-out
    save fails (FaultyStore), the already-suspended victims must be
    resumed, not stranded with their capacity gone."""
    backend = SnoozeBackend(n_hosts=8)
    store = FaultyStore(InMemoryStore())
    svc = CACSService({"snooze": backend}, {"default": store})
    sched = GlobalScheduler(svc)
    try:
        a = sched.submit(_asr("victim-a", 3, priority=1))
        b = sched.submit(_asr("victim-b", 3, priority=2))
        svc.wait_for_state(a, CoordState.RUNNING, 20)
        svc.wait_for_state(b, CoordState.RUNNING, 20)

        orig = svc.apps.suspend

        def failing_suspend(coord_id, reason="user"):
            if coord_id == b:          # arm right before the 2nd victim's
                store.arm_put_errors(1)   # swap-out write
            return orig(coord_id, reason)

        monkeypatch.setattr(svc.apps, "suspend", failing_suspend)
        hi = sched.submit(_asr("hi", 8, priority=9))
        # the preemption aborted: victim-a was suspended (lowest priority
        # first), victim-b's save failed, victim-a must be running again
        assert sched.aborted_preemptions == 1
        assert svc.db.get(a).state == CoordState.RUNNING
        assert svc.db.get(b).state == CoordState.RUNNING
        assert svc.db.get(hi).state == CoordState.QUEUED
        assert any(t[1] == "preempt_abort" for t in sched.decision_trace())
        # once the fault clears, the retry goes through end to end
        store.disarm()
        monkeypatch.setattr(svc.apps, "suspend", orig)
        sched.tick()
        svc.wait_for_state(hi, CoordState.RUNNING, 20)
        assert svc.db.get(a).state == CoordState.SUSPENDED
        assert svc.db.get(b).state == CoordState.SUSPENDED
    finally:
        sched.stop()
        svc.shutdown()


def test_blocking_calls_run_outside_scheduler_lock(env, monkeypatch):
    """Every suspend/resume/start the scheduler issues must run with the
    scheduler lock released (the PR 3 hold-a-lock-across-a-save hazard)."""
    svc, sched, backend = env
    seen = []
    for name in ("suspend", "resume", "start_queued"):
        orig = getattr(svc.apps, name)

        def wrapper(*a, _orig=orig, _name=name, **kw):
            seen.append((_name, sched.lock_held()))
            return _orig(*a, **kw)

        monkeypatch.setattr(svc.apps, name, wrapper)
    low = sched.submit(_asr("low", 6, priority=1))
    svc.wait_for_state(low, CoordState.RUNNING, 20)
    hi = sched.submit(_asr("hi", 6, priority=9))
    svc.wait_for_state(hi, CoordState.RUNNING, 20)
    svc.delete_coordinator(hi)
    sched.tick()
    svc.wait_for_state(low, CoordState.RUNNING, 20)
    ops = {name for name, _ in seen}
    assert {"suspend", "resume", "start_queued"} <= ops
    assert all(not held for _, held in seen), \
        f"blocking call under the scheduler lock: {seen}"


def test_aging_promotes_long_waiting_jobs(env):
    """Anti-starvation: with aging enabled, a lower-priority job that has
    waited longer outranks a younger higher-priority one."""
    svc, _, backend = env

    class FakeClock:
        t = 0.0

        def now(self):
            return self.t

    clock = FakeClock()
    sched = GlobalScheduler(svc, clock=clock, aging_rate=1.0)
    try:
        blocker = sched.submit(_asr("blocker", 8, priority=9))
        svc.wait_for_state(blocker, CoordState.RUNNING, 20)
        x = sched.submit(_asr("x", 8, priority=5))      # queued at t=0
        clock.t = 4.0
        y = sched.submit(_asr("y", 8, priority=6))      # queued at t=4
        clock.t = 8.0
        # eff(x) = 5 + 8 = 13 > eff(y) = 6 + 4 = 10
        svc.delete_coordinator(blocker)
        sched.tick()
        svc.wait_for_state(x, CoordState.RUNNING, 20)
        assert svc.db.get(y).state == CoordState.QUEUED
    finally:
        sched.stop()


def test_queue_persists_across_service_restart():
    """Satellite: queued work survives a service crash — the QUEUED record
    (with its queue stamp) rehydrates via CoordinatorDB.load and a fresh
    scheduler adopts and places it."""
    db_store = InMemoryStore()
    backend1 = SnoozeBackend(n_hosts=4)
    svc1 = CACSService({"snooze": backend1}, {"default": InMemoryStore()},
                       db_store=db_store)
    sched1 = GlobalScheduler(svc1)
    blocker = sched1.submit(_asr("blocker", 4, priority=5))
    svc1.wait_for_state(blocker, CoordState.RUNNING, 20)
    queued = sched1.submit(_asr("waiter", 4, priority=3))
    assert svc1.db.get(queued).state == CoordState.QUEUED
    # crash: no clean shutdown — only the daemons die with the process
    sched1.stop()
    svc1.apps.stop_daemons()

    svc2 = CACSService({"snooze": SnoozeBackend(n_hosts=4)},
                       {"default": InMemoryStore()}, db_store=db_store)
    try:
        rec = svc2.db.get(queued)
        assert rec.state == CoordState.QUEUED
        assert "queued_at_v" in rec.metrics       # aging stamp persisted
        for coord in svc2.db.list():              # code is not persisted:
            coord.asr.app_factory = lambda: SimulatedApp(iter_time_s=0.5)
        sched2 = GlobalScheduler(svc2)
        sched2.tick()
        svc2.wait_for_state(queued, CoordState.RUNNING, 20)
        sched2.stop()
    finally:
        svc2.shutdown()
        svc1.provision.close()


def test_cross_cloud_backfill_zero_reuploads():
    """Tentpole: a preempted job whose images are fully replicated on
    another cloud resumes there through the prefix-adoption path with
    zero chunk re-uploads, and its next save commits to the new store."""
    a = SnoozeBackend(n_hosts=8)
    b = OpenStackBackend(n_hosts=4)
    store_a, store_b = InMemoryStore(), InMemoryStore()
    svc = CACSService({"snooze": a, "openstack": b},
                      {"default": store_a, "standby": store_b})
    rep = ImageReplicator(svc)
    rep.add_target(StandbyTarget("openstack", store=store_b,
                                 backend="openstack"))
    svc.attach_replicator(rep)
    sched = GlobalScheduler(svc, cloud_stores={"snooze": "default",
                                               "openstack": "standby"})
    svc.attach_scheduler(sched)
    sched.start()
    rep.start()
    try:
        low = sched.submit(_asr("low", 4, priority=1))
        svc.wait_for_state(low, CoordState.RUNNING, 20)
        svc.trigger_checkpoint(low)
        rep.watch(low, ReplicationPolicy(targets=("openstack",)))
        hi = sched.submit(_asr("hi", 8, priority=9, clouds=("snooze",)))
        svc.wait_for_state(hi, CoordState.RUNNING, 20)
        # low: preempted -> swap-out image replicates -> backfill resumes
        # it on openstack (the replicator's on_replicated kick, no polling)
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            c = svc.db.get(low)
            if c.state == CoordState.RUNNING and c.asr.backend == "openstack":
                break
            active_clock().sleep(0.02)
        c = svc.db.get(low)
        assert (c.state, c.asr.backend) == (CoordState.RUNNING, "openstack")
        assert sched.backfills == 1
        assert sched.backfill_reuploads == 0
        assert c.metrics["backfill_reuploads"] == 0
        assert c.asr.policy.store == "standby"
        # the post-backfill save continues the adopted lineage standby-side
        from repro.ckpt.reader import list_steps
        step = svc.trigger_checkpoint(low)
        assert step in list_steps(store_b, c.ckpt_prefix)
    finally:
        sched.stop()
        rep.stop()
        svc.shutdown()
