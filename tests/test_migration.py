"""Cross-cloud migration / cloning / cloudification (paper §5.3, §7.3)."""
import dataclasses
import time

import numpy as np
import pytest

from repro.ckpt import ChaosStorageError, FaultyStore, InMemoryStore
from repro.clusters import LocalBackend, OpenStackBackend, SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        SimulatedApp, clone, cloudify, migrate)


@pytest.fixture
def two_clouds():
    src = CACSService({"snooze": SnoozeBackend(8)},
                      {"default": InMemoryStore()})
    dst = CACSService({"openstack": OpenStackBackend(8)},
                      {"default": InMemoryStore()})
    yield src, dst
    src.shutdown()
    dst.shutdown()


def _submit_sim(svc, backend, n_vms=2):
    asr = ASR(name="sim", n_vms=n_vms, backend=backend,
              app_factory=lambda: SimulatedApp(iter_time_s=0.3,
                                               state_mb=0.02),
              policy=CheckpointPolicy(period_s=0.2, keep_last=2))
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, 30)
    return cid


def test_clone_keeps_source_running(two_clouds):
    src, dst = two_clouds
    cid = _submit_sim(src, "snooze")
    time.sleep(0.3)
    res = clone(src, cid, dst, backend="openstack")
    assert src.db.get(cid).state == CoordState.RUNNING
    c2 = dst.db.get(res.dst_id)
    assert c2.state == CoordState.RUNNING
    assert c2.app.restarts == 1
    assert c2.app.iteration > 0, "clone must resume from the image"


def test_migrate_terminates_source_and_changes_vm_count(two_clouds):
    src, dst = two_clouds
    cid = _submit_sim(src, "snooze", n_vms=4)
    time.sleep(0.3)
    it_before = src.db.get(cid).app.iteration
    res = migrate(src, cid, dst, backend="openstack", n_vms=2)
    assert all(c["id"] != cid for c in src.list_coordinators())
    c2 = dst.db.get(res.dst_id)
    assert c2.state == CoordState.RUNNING
    assert len(c2.vms) == 2, "heterogeneous migration: different VM count"
    time.sleep(0.3)
    assert c2.app.iteration >= it_before * 0.3


def test_cloudify_desktop_to_cloud():
    desktop = CACSService({"local": LocalBackend(1)},
                          {"default": InMemoryStore()})
    cloud = CACSService({"openstack": OpenStackBackend(8)},
                        {"default": InMemoryStore()})
    try:
        cid = _submit_sim(desktop, "local", n_vms=1)
        time.sleep(0.3)
        res = cloudify(desktop, cid, cloud, backend="openstack", n_vms=2)
        c2 = cloud.db.get(res.dst_id)
        assert c2.state == CoordState.RUNNING and c2.app.iteration > 0
    finally:
        desktop.shutdown()
        cloud.shutdown()


def test_clone_explicit_earlier_step(two_clouds):
    """fresh_checkpoint=False with an explicit committed step clones from
    exactly that image, not the newest one."""
    src, dst = two_clouds
    asr = ASR(name="sim", n_vms=2, backend="snooze",
              app_factory=lambda: SimulatedApp(iter_time_s=0.3,
                                               state_mb=0.02),
              policy=CheckpointPolicy(period_s=0, keep_last=3))
    cid = src.submit(asr)
    src.wait_for_state(cid, CoordState.RUNNING, 30)
    time.sleep(0.3)
    s1 = src.trigger_checkpoint(cid)
    it_s1 = src.ckpt.load(src.db.get(cid), s1)["iteration"]
    time.sleep(0.3)
    src.trigger_checkpoint(cid)               # a newer image exists
    res = clone(src, cid, dst, backend="openstack", step=s1,
                fresh_checkpoint=False)
    assert res.step == s1 and res.checkpoint_s < 0.05
    c2 = dst.db.get(res.dst_id)
    assert c2.state == CoordState.RUNNING
    # restored from s1: cannot have started beyond the newer image
    assert c2.app.restarts == 1
    assert c2.app.iteration >= it_s1


def test_clone_missing_explicit_step_raises_cleanly(two_clouds):
    """An explicit-but-missing step must raise (never restart from
    garbage) and must not leak a half-created destination record."""
    src, dst = two_clouds
    cid = _submit_sim(src, "snooze")
    src.trigger_checkpoint(cid)
    with pytest.raises(FileNotFoundError):
        clone(src, cid, dst, backend="openstack", step=999,
              fresh_checkpoint=False)
    assert src.db.get(cid).state == CoordState.RUNNING
    assert not dst.list_coordinators(), "failed clone leaked the dst record"


def test_failed_migration_leaves_source_running_and_no_dst_leak():
    """Regression (FaultyStore): if the transfer dies mid-upload, the
    source must be untouched and the half-created destination coordinator
    cleaned up — migrate only terminates the source after success."""
    faulty = FaultyStore(InMemoryStore())
    src = CACSService({"snooze": SnoozeBackend(8)},
                      {"default": InMemoryStore()})
    dst = CACSService({"openstack": OpenStackBackend(8)},
                      {"default": faulty})
    try:
        cid = _submit_sim(src, "snooze")
        time.sleep(0.2)
        faulty.arm_put_errors(1)              # first chunk put dies
        with pytest.raises((ChaosStorageError, IOError)):
            migrate(src, cid, dst, backend="openstack")
        # source untouched: still RUNNING, record intact, images intact
        c = src.db.get(cid)
        assert c.state == CoordState.RUNNING
        assert src.list_checkpoints(cid)
        # destination fully cleaned: no record, no committed images
        assert not dst.list_coordinators()
        faulty.disarm()
        # and the same migration succeeds once the store heals
        res = migrate(src, cid, dst, backend="openstack")
        assert dst.db.get(res.dst_id).state == CoordState.RUNNING
        assert all(ci["id"] != cid for ci in src.list_coordinators())
    finally:
        src.shutdown()
        dst.shutdown()


def test_migrated_training_job_is_bit_exact(two_clouds):
    """The paper's strongest claim, applied to a real JAX job: the migrated
    training run continues the exact optimizer/token trajectory."""
    from repro.train.trainer import TrainerApp
    src, dst = two_clouds
    cfg = dataclasses.replace(reduced(get_config("repro-100m")),
                              dtype="float32")
    n_total = 10

    # reference: uninterrupted 10 steps
    ref = TrainerApp(cfg, global_batch=2, seq_len=32, n_steps=n_total)
    ref.start(None, None)
    while not ref.is_done():
        time.sleep(0.02)
    ref.stop()

    asr = ASR(name="train", n_vms=2, backend="snooze",
              app_factory=lambda: TrainerApp(cfg, global_batch=2, seq_len=32,
                                             n_steps=n_total),
              policy=CheckpointPolicy(period_s=0))
    cid = src.submit(asr)
    src.wait_for_state(cid, CoordState.RUNNING, 60)
    while src.db.get(cid).app.current_step < 4:
        time.sleep(0.02)
    res = migrate(src, cid, dst, backend="openstack", n_vms=1)
    c2 = dst.db.get(res.dst_id)
    while not c2.app.is_done():
        time.sleep(0.05)
    c2.app.stop()
    assert c2.app.current_step == n_total
    np.testing.assert_allclose(c2.app.losses[-1], ref.losses[-1],
                               rtol=0, atol=0)
