"""Checkpoint-backed serving fleet: scale-out by CAS restore with prefix
adoption (zero re-uploads), scale-in by suspend (capacity reclaimed for
batch), deterministic routing, chaos suspend-mid-decode, and the
request-storm DES engine."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.ckpt import FaultyStore, InMemoryStore
from repro.ckpt.reader import list_steps
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.configs import get_config, reduced
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        GlobalScheduler, ImageReplicator, ReplicationPolicy,
                        StandbyTarget)
from repro.obs.telemetry import registry
from repro.serve import FleetController, FleetPolicy, RequestTrace, Router
from repro.serve.engine import ServeApp
from repro.sim import active_clock
from repro.sim.serve import PARKED, ServeFleetEngine

CFG = dataclasses.replace(reduced(get_config("repro-100m")), dtype="float32")


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """Whole suite on the discrete-event virtual clock."""
    yield


def _wait(pred, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        active_clock().sleep(0.02)
    return False


# ---------------------------------------------------------------------------
# workload primitives
# ---------------------------------------------------------------------------

def test_router_least_outstanding_deterministic():
    r = Router()
    for name in ("r2", "r0", "r1"):
        r.add(name)
    picks = [r.route() for _ in range(6)]
    # least outstanding, lexicographic tie-break: round-robins in order
    assert picks == ["r0", "r1", "r2", "r0", "r1", "r2"]
    r.complete("r1")
    assert r.route() == "r1"               # only r1 has 1 outstanding
    r.remove("r2")
    assert r.outstanding("r2") == 0
    assert r.route() in ("r0", "r1")
    assert r.route() is not None
    r2 = Router()
    assert r2.route() is None              # no members: rejected
    assert r2.rejected == 1


def test_request_trace_deterministic_and_restartable():
    trace = RequestTrace(seed=13, horizon_s=600.0, base_qps=2.0,
                         peak_qps=10.0, period_s=300.0,
                         burst_every_s=200.0, burst_s=20.0, burst_mult=3.0)
    a = list(trace)
    b = list(trace)                         # each iter() restarts the stream
    assert a == b
    assert len(a) > 0
    assert all(0.0 <= t <= 600.0 for t in a)
    assert a == sorted(a)
    other = list(RequestTrace(seed=14, horizon_s=600.0, base_qps=2.0,
                              peak_qps=10.0, period_s=300.0))
    assert a != other


def test_load_max_priority_caps_batch_priorities():
    from repro.sim.engine import SimEngine
    eng = SimEngine(8, seed=3)
    eng.load(n_jobs=50, horizon_s=100.0, max_priority=5)
    assert all(1 <= j.priority <= 5 for j in eng.jobs)
    with pytest.raises(ValueError):
        eng.load(n_jobs=1, horizon_s=1.0, max_priority=0)
    with pytest.raises(ValueError):
        eng.load(n_jobs=1, horizon_s=1.0, max_priority=10)


# ---------------------------------------------------------------------------
# FleetController on the real stack
# ---------------------------------------------------------------------------

def _fleet_env(n_hosts=4, n_tokens=24):
    backend = SnoozeBackend(n_hosts=n_hosts)
    store = InMemoryStore()
    svc = CACSService({"snooze": backend}, {"default": store})
    sched = GlobalScheduler(svc)            # no start(): synchronous ticks
    svc.attach_scheduler(sched)
    fleet = FleetController(
        svc, sched, name="m1",
        replica_factory=lambda: ServeApp(CFG, batch=1, prompt_len=8,
                                         n_tokens=n_tokens, cache_len=48),
        policy=FleetPolicy(min_replicas=1, max_replicas=4,
                           scale_in_idle_s=0.0),
        backend="snooze", priority=5)
    return svc, sched, fleet, store


def _publish_seed(fleet, n_seed_tokens=6):
    seed_app = ServeApp(CFG, batch=1, prompt_len=8, n_tokens=n_seed_tokens,
                        cache_len=48)
    seed_app.start(None, None)
    assert _wait(seed_app.is_done)
    seed_app.stop()
    state = seed_app.checkpoint_state()
    fleet.publish_seed(state, step=state["generated"])
    return state


def test_fleet_scale_out_adopts_seed_with_zero_reuploads():
    """Tentpole: replicas cold-start by restoring the shared seed image
    straight from CAS — nothing is uploaded, the replica's own prefix
    stays empty, and cold-start latency lands in the registry under the
    job's trace_id."""
    svc, sched, fleet, store = _fleet_env()
    try:
        seed = _publish_seed(fleet, n_seed_tokens=6)
        put_before = store.put_count
        cids = fleet.scale_out(2)
        assert len(cids) == 2
        fleet.wait_live(cids, timeout=60)
        assert fleet.coldstart_reuploads == 0
        assert store.put_count == put_before, \
            "cold start must not write a single object"
        for cid in cids:
            coord = svc.db.get(cid)
            assert coord.state == CoordState.RUNNING
            assert list_steps(store, coord.ckpt_prefix) == []
            # restored, not re-run: continues from the seed's generation
            assert coord.app.restarts == 1
            assert coord.app.generated >= seed["generated"]
            # cold start is a first-class metric under the job's trace_id
            assert coord.metrics["coldstart_s"] >= 0.0
            gauge = registry().value(f"coord.{coord.trace_id}.coldstart_s",
                                     None)
            assert gauge is not None and gauge >= 0.0
        # the generated stream extends the seed's bit-for-bit
        for cid in cids:
            coord = svc.db.get(cid)
            assert _wait(coord.app.is_done)
            out = coord.app.checkpoint_state()["tokens_out"]
            np.testing.assert_array_equal(
                out[:, :seed["tokens_out"].shape[1]], seed["tokens_out"])
        assert sorted(fleet.live()) == sorted(cids)
        assert fleet.stats()["coldstarts"] == 2
    finally:
        sched.stop()
        svc.shutdown()


def test_fleet_scale_in_parks_reclaims_capacity_then_unparks():
    """Scale-in suspends an idle replica and flags it fleet_parked: the
    scheduler hands its host to waiting batch work instead of
    auto-resuming it; a later scale-out unparks it (preempting the batch
    job right back when the cloud is full)."""
    svc, sched, fleet, store = _fleet_env(n_hosts=4, n_tokens=400)
    try:
        _publish_seed(fleet)
        cids = fleet.scale_out(2)
        fleet.wait_live(cids, timeout=60)

        from repro.core import SimulatedApp
        batch = sched.submit(ASR(
            name="batch", n_vms=3, backend="snooze", priority=1,
            app_factory=lambda: SimulatedApp(iter_time_s=0.5, state_mb=0.01),
            policy=CheckpointPolicy(period_s=0)))
        assert svc.db.get(batch).state == CoordState.QUEUED   # 2 hosts free

        parked = fleet.scale_in(1, force=True)
        assert len(parked) == 1
        coord = svc.db.get(parked[0])
        assert coord.state == CoordState.SUSPENDED
        assert coord.metrics["fleet_parked"] == 1
        assert parked[0] in fleet.parked()

        # the freed host + the 2 idle ones now fit the batch job — and the
        # parked replica must NOT be auto-resumed by the pass
        sched.tick()
        svc.wait_for_state(batch, CoordState.RUNNING, 30)
        assert svc.db.get(parked[0]).state == CoordState.SUSPENDED

        # scale-out prefers the parked replica; the cloud is full, so the
        # higher-priority replica preempts the batch job to come back
        out = fleet.scale_out(1)
        assert out == parked
        fleet.wait_live(out, timeout=60)
        assert svc.db.get(parked[0]).state == CoordState.RUNNING
        assert svc.db.get(batch).state == CoordState.SUSPENDED
        assert fleet.parks == 1 and fleet.unparks == 1
        assert registry().value("fleet.m1.parks", 0.0) == 1
    finally:
        sched.stop()
        svc.shutdown()


# ---------------------------------------------------------------------------
# chaos: suspend mid-decode, resume on another cloud, bit-identical stream
# ---------------------------------------------------------------------------

def test_suspend_mid_decode_cross_cloud_stream_bit_identical():
    """Satellite: the suspend lands while a decode holds the donated
    cache (the capture pins and waits the window out); the swap-out image
    survives a torn replication attempt (FaultyStore chaos), the job
    resumes on the *other* cloud reading only replicated chunks, and the
    final token stream is bit-identical to an unsuspended run."""
    n_tokens = 16

    ref = ServeApp(CFG, batch=1, prompt_len=8, n_tokens=n_tokens,
                   cache_len=48)
    ref.start(None, None)
    assert _wait(ref.is_done)
    ref.stop()
    ref_tokens = ref.checkpoint_state()["tokens_out"]

    gate_entered = threading.Event()
    gate_release = threading.Event()
    made = []

    class _Gated(ServeApp):
        def _build(self):
            super()._build()
            real = self.engine.decode

            def decode(cache, token, pos):
                if self.generated >= 5 and not gate_release.is_set():
                    gate_entered.set()
                    gate_release.wait(30)
                return real(cache, token, pos)
            self.engine.decode = decode

    def factory():
        # only the first incarnation is gated: the resumed app (restored
        # past the gate) must decode freely
        app = _Gated(CFG, batch=1, prompt_len=8, n_tokens=n_tokens,
                     cache_len=48) if not made else \
            ServeApp(CFG, batch=1, prompt_len=8, n_tokens=n_tokens,
                     cache_len=48)
        made.append(app)
        return app

    store_a = InMemoryStore()
    inner_b = InMemoryStore()
    store_b = FaultyStore(inner_b)
    svc = CACSService({"snooze": SnoozeBackend(4),
                       "openstack": OpenStackBackend(4)},
                      {"default": store_a, "standby": store_b})
    try:
        cid = svc.submit(ASR(name="serve", n_vms=1, backend="snooze",
                             app_factory=factory,
                             policy=CheckpointPolicy(period_s=0)))
        svc.wait_for_state(cid, CoordState.RUNNING, 60)
        assert gate_entered.wait(30), "decode never reached the gate"

        # suspend now: the donated cache is surrendered to the gated
        # decode, so the capture must pin and wait — not deadlock, not
        # poll virtual time
        err = []

        def do_suspend():
            try:
                svc.apps.suspend(cid, reason="chaos")
            except Exception as e:             # noqa: BLE001
                err.append(e)
        t = threading.Thread(target=do_suspend, daemon=True)
        t.start()
        time.sleep(0.3)
        assert t.is_alive(), "suspend finished inside the donated window"
        gate_release.set()
        t.join(timeout=60)
        assert not t.is_alive() and not err
        coord = svc.db.get(cid)
        assert coord.state == CoordState.SUSPENDED
        gen_at_suspend = made[0].generated
        assert 5 <= gen_at_suspend < n_tokens

        # replicate the swap-out image to the standby cloud — first
        # attempt torn by chaos (invisible: no COMMITTED), retry heals
        rep = ImageReplicator(svc)
        rep.add_target(StandbyTarget("standby", store=store_b,
                                     backend="openstack"))
        rep.watch(cid, ReplicationPolicy(targets=("standby",)))
        store_b.arm_put_errors(1)
        rep.sync()
        assert rep.sync_errors >= 1
        assert list_steps(store_b, coord.ckpt_prefix) == []
        store_b.disarm()
        rep.sync()
        assert len(list_steps(store_b, coord.ckpt_prefix)) == 1

        # retarget home to the standby cloud and resume there: the
        # restore reads only replicated chunks — zero uploads to B
        svc.ckpt.detach(cid)
        coord.asr.backend = "openstack"
        coord.asr.policy.store = "standby"
        puts_before = inner_b.put_count
        svc.apps.resume(cid, block=True)
        assert coord.state == CoordState.RUNNING
        assert inner_b.put_count == puts_before

        app = made[-1]
        assert app.restarts == 1
        assert _wait(app.is_done)
        out = app.checkpoint_state()["tokens_out"]
        np.testing.assert_array_equal(out, ref_tokens)
    finally:
        gate_release.set()
        svc.shutdown()


# ---------------------------------------------------------------------------
# request-storm DES engine
# ---------------------------------------------------------------------------

def _des(seed=11, policy=None, **kw):
    trace = RequestTrace(seed=seed, horizon_s=7200.0, base_qps=4.0,
                         peak_qps=35.0, period_s=3600.0,
                         burst_every_s=600.0, burst_s=120.0, burst_mult=3.0)
    pol = policy or FleetPolicy(min_replicas=1, max_replicas=8,
                                target_util=0.7, scale_in_idle_s=30.0,
                                eval_period_s=5.0)
    eng = ServeFleetEngine(16, seed, trace=trace, policy=pol,
                           service_s=0.1, concurrency=2,
                           replica_boot_s=5.0, suspend_s=2.0, **kw)
    eng.start_fleet(pol.min_replicas)
    eng.load(n_jobs=30, horizon_s=7200.0, max_vms=4, mean_work_s=600.0,
             max_priority=8)
    return eng


def test_serve_fleet_engine_deterministic_trace():
    a, b = _des(), _des()
    a.run()
    b.run()
    assert a.trace_digest() == b.trace_digest()
    assert a.served == b.served == a.requests
    assert a.fleet_stats() == b.fleet_stats()
    assert a.requests > 50_000              # a storm, not a trickle
    assert a.parks > 0 and a.coldstarts > 1 # the autoscaler actually moved
    a.check_invariants()
    for jid in a.parked_jids:
        assert a.jobs[jid].state == PARKED


def test_serve_fleet_engine_survives_host_faults():
    eng = _des(seed=5, host_mtbf_s=3000.0)
    eng.run()
    eng.check_invariants()
    assert eng.served == eng.requests
    assert eng.recoveries > 0
    e2 = _des(seed=5, host_mtbf_s=3000.0)
    e2.run()
    assert eng.trace_digest() == e2.trace_digest()


def test_pooled_fleet_beats_static_on_diurnal_storm():
    """The benchmark's claim, in miniature: under a diurnal+bursty storm
    an autoscaled (pooled) fleet yields BOTH better p99 (it scales to the
    peak) and better served-QPS-per-host-second (it parks the trough)
    than a static mid-sized fleet, on identical request bytes."""
    pooled_pol = FleetPolicy(min_replicas=1, max_replicas=8,
                             target_util=0.7, scale_in_idle_s=30.0,
                             eval_period_s=5.0)
    static_pol = FleetPolicy(min_replicas=4, max_replicas=4,
                             target_util=0.7, scale_in_idle_s=1e18,
                             eval_period_s=5.0)
    pooled = _des(seed=21, policy=pooled_pol)
    static = _des(seed=21, policy=static_pol)
    pooled.run()
    static.run()
    ps, ss = pooled.fleet_stats(), static.fleet_stats()
    assert ps["requests"] == ss["requests"]          # identical storm
    assert ps["p99_s"] < ss["p99_s"]
    assert ps["served_qps_per_host"] > ss["served_qps_per_host"]
