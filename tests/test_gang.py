"""Gang-consistent checkpointing, layer by layer.

Format layer (ckpt/gang.py): one merged manifest per gang epoch, rank
shards as chunks at global offsets, reshard-on-restore to any rank count
with single-flight chunk fetches, per-rank-scoped CAS dedup, GC
compatibility.

Protocol layer (core/gang.py): the two-phase barrier commits a
conservation-consistent cut of a live message-passing job on the
simulated fabric, and aborts all-or-nothing under rank-scoped store
faults, partitions, and stragglers — the previous committed image always
survives.
"""
import time
import types

import numpy as np
import pytest

from repro.ckpt import gc as ckpt_gc
from repro.ckpt.gang import (GangCheckpointer, load_gang_ranks,
                             save_gang_image, scoped_known_digests)
from repro.ckpt.layout import MANIFEST, step_prefix
from repro.ckpt.reader import list_steps
from repro.ckpt.storage import FaultyStore, InMemoryStore
from repro.clusters.base import SimBackend, VMTemplate
from repro.clusters.simulator import ClusterSim
from repro.core.gang import (GANG_ROUTED, GANG_SHARDED, BarrierConfig,
                             GangApp, GangBarrierError, GangCoordinator,
                             GangStragglerError, gang_invariant)
from repro.sim import active_clock
from repro.sharding.specs import even_regions


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    yield


# ---------------------------------------------------------------------------
# format layer
# ---------------------------------------------------------------------------

def _rank_trees(n_ranks, rows=12, inflight=3):
    """Synthetic but invariant-consistent rank trees for a global cut."""
    rng = np.random.default_rng(0)
    regions = even_regions(rows, n_ranks)
    trees = []
    msgs = [(float(r), float(i), float(rng.integers(rows)), 1.0)
            for r in range(n_ranks) for i in range(inflight)]
    per_rank = np.array(msgs, np.float64).reshape(-1, 4)
    for r, (off, length) in enumerate(regions):
        state = rng.random((length, 2)) * 10
        trees.append({"state": state, "iteration": 7,
                      "inbox": per_rank[r::n_ranks].copy()})
    return trees


def _concat_state(trees):
    return np.concatenate([np.asarray(t["state"]) for t in trees], axis=0)


def test_gang_roundtrip_and_reshard_single_flight():
    store = InMemoryStore()
    trees = _rank_trees(4)
    save_gang_image(store, "apps/j", 100, trees,
                    sharded=GANG_SHARDED, routed=GANG_ROUTED)
    full = _concat_state(trees)
    all_rows = np.concatenate([t["inbox"] for t in trees], axis=0)
    for n_new in (2, 3, 4, 6):
        out, man, stats = load_gang_ranks(store, "apps/j", n_ranks=n_new)
        assert len(out) == n_new
        np.testing.assert_array_equal(_concat_state(out), full)
        # every in-flight row survives, re-routed to its new owner rank
        rows = np.concatenate([t["inbox"] for t in out], axis=0)
        assert (sorted(map(tuple, rows.tolist()))
                == sorted(map(tuple, all_rows.tolist())))
        assert all(t["iteration"] == 7 for t in out)
        # shared chunks are fetched exactly once (single-flight CAS reads)
        assert stats["max_fetches_per_chunk"] == 1
        assert stats["chunk_fetches"] == stats["unique_chunks"]
        inv = gang_invariant(out)
        # synthetic trees aren't conservation-consistent; shape only
        assert set(inv) == {"sent", "applied", "inflight", "consistent"}


def test_second_epoch_dedups_within_rank_scope_only():
    store = InMemoryStore()
    ck = GangCheckpointer(store, "apps/j")
    trees = _rank_trees(4)
    m1 = ck.save(100, trees, sharded=GANG_SHARDED, routed=GANG_ROUTED)
    m2 = ck.save(101, trees, sharded=GANG_SHARDED, routed=GANG_ROUTED)
    s1, s2 = m1.metadata["dedup"], m2.metadata["dedup"]
    assert s1["dedup_hits"] == 0
    assert s2["dedup_hits"] == s2["chunks"], \
        "identical epoch must dedup every chunk against the prior image"
    # the dedup tables are per rank scope — priming sees every scope
    knowns = scoped_known_digests(store, "apps/j")
    assert sorted(knowns) == [0, 1, 2, 3]
    # and a scoped digest never leaks into another rank's table
    for r, tbl in knowns.items():
        for digest in tbl:
            assert store.exists(f"apps/j/cas/r{r}-{digest}")


def test_rank_scoped_put_fault_aborts_save_and_preserves_prior_image():
    """Satellite regression: arming FaultyStore on ONE rank's CAS prefix
    fails only that rank's uploads; the epoch save raises, the torn step
    never becomes visible, and the previous image still restores."""
    store = FaultyStore(InMemoryStore())
    ck = GangCheckpointer(store, "apps/j")
    trees = _rank_trees(4)
    ck.save(100, trees, sharded=GANG_SHARDED, routed=GANG_ROUTED)
    trees2 = _rank_trees(4)
    for t in trees2:
        t["state"] = t["state"] + 1.0      # force fresh chunks
    store.arm_put_errors(3, key_prefix="apps/j/cas/r2-")
    with pytest.raises(Exception):
        ck.save(101, trees2, sharded=GANG_SHARDED, routed=GANG_ROUTED)
    store.disarm()
    assert list_steps(store, "apps/j") == [100], \
        "aborted epoch must stay invisible"
    assert not store.exists(f"{step_prefix('apps/j', 101)}/{MANIFEST}")
    out, _, _ = load_gang_ranks(store, "apps/j", n_ranks=4)
    np.testing.assert_array_equal(_concat_state(out), _concat_state(trees))
    # the plane heals: the next epoch commits (dedup tables were
    # invalidated only for keys that actually vanished)
    ck.save(102, trees2, sharded=GANG_SHARDED, routed=GANG_ROUTED)
    assert list_steps(store, "apps/j") == [100, 102]


def test_gc_collect_reaps_rank_submanifests_with_the_step():
    store = InMemoryStore()
    ck = GangCheckpointer(store, "apps/j")
    for step in (100, 101, 102):
        ck.save(step, _rank_trees(3), sharded=GANG_SHARDED,
                routed=GANG_ROUTED)
    ckpt_gc.collect(store, "apps/j", keep_last=1, on_swept=ck.invalidate)
    assert list_steps(store, "apps/j") == [102]
    for step in (100, 101):
        assert not store.list(step_prefix("apps/j", step)), \
            "rank_<r>.json must be reaped with its step directory"
    out, _, _ = load_gang_ranks(store, "apps/j", n_ranks=3)
    assert len(out) == 3


# ---------------------------------------------------------------------------
# protocol layer
# ---------------------------------------------------------------------------

class _Harness:
    def __init__(self, n_ranks=4, n_hosts=8, rows=12, barrier=None):
        self.sim = ClusterSim(n_hosts, name="c0")
        self.backend = SimBackend(self.sim)
        self.vms = self.backend.allocate_vms(n_ranks, VMTemplate(), "gang")
        self.app = GangApp(global_rows=rows, iter_time_s=0.05,
                           barrier=barrier)
        ctx = types.SimpleNamespace(coord_id="j", vms=self.vms,
                                    service=None, transport=self.sim)
        self.app.start(ctx, None)
        self.store = FaultyStore(InMemoryStore())
        self.ck = GangCheckpointer(self.store, "apps/j")
        self.coord = GangCoordinator(
            self.app, self.sim,
            lambda step, trees: self.ck.save(step, trees,
                                             sharded=GANG_SHARDED,
                                             routed=GANG_ROUTED),
            trace_id="tr-j-0000")

    def stop(self):
        self.app.stop()


def test_barrier_commits_conservation_consistent_cut():
    h = _Harness()
    try:
        active_clock().sleep(2.0)              # let messages fly
        h.coord.snapshot(1)
        out, man, _ = load_gang_ranks(h.store, "apps/j", n_ranks=4)
        inv = gang_invariant(out)
        assert inv["consistent"] == 1.0, inv
        assert inv["sent"] > 0
        assert man.metadata["gang"]["ranks"] == 4
        # the job keeps running after release
        it0 = h.app.min_iteration()
        active_clock().sleep(1.0)
        assert h.app.min_iteration() > it0
    finally:
        h.stop()


def test_partition_mid_drain_aborts_and_releases_all_ranks():
    h = _Harness()
    try:
        active_clock().sleep(1.0)
        h.coord.snapshot(1)
        hid = h.vms[1].host.host_id
        h.coord.arm("drain", lambda: h.sim.partition_host(hid))
        with pytest.raises(GangBarrierError):
            h.coord.snapshot(2)
        assert h.coord.last_abort_reason == "partition_or_crash"
        assert list_steps(h.store, "apps/j") == [1], \
            "aborted epoch must leave the previous image as newest"
        h.sim.heal_partition(hid)
        # every rank was released: all keep iterating
        it0 = [rk.iteration for rk in h.app.ranks]
        active_clock().sleep(1.0)
        assert all(rk.iteration > i0
                   for rk, i0 in zip(h.app.ranks, it0))
        # and the next epoch commits
        h.coord.snapshot(3)
        assert list_steps(h.store, "apps/j") == [1, 3]
    finally:
        h.stop()


def test_rank_crash_mid_drain_aborts_without_torn_image():
    h = _Harness()
    try:
        active_clock().sleep(1.0)
        h.coord.snapshot(1)
        hid = h.vms[2].host.host_id
        h.coord.arm("drain", lambda: h.sim.fail_host(hid))
        with pytest.raises(GangBarrierError):
            h.coord.snapshot(2)
        assert h.coord.last_abort_reason == "partition_or_crash"
        assert list_steps(h.store, "apps/j") == [1]
        out, _, _ = load_gang_ranks(h.store, "apps/j", n_ranks=4)
        assert gang_invariant(out)["consistent"] == 1.0
    finally:
        h.stop()


def test_straggler_exhausts_ack_retries_and_aborts():
    cfg = BarrierConfig(ack_timeout_s=0.5, ack_retries=1, backoff_s=0.1)
    h = _Harness(barrier=cfg)
    try:
        active_clock().sleep(1.0)
        h.coord.snapshot(1)
        rank = h.app.ranks[3]
        hid = h.vms[3].host.host_id
        h.sim.degrade_host(hid, 100.0)
        # wall-poll (never a virtual sleep) until rank 3 is pinned INSIDE
        # its 5s slowed iteration: that sleep's deadline is the only one
        # that can sit >2 virtual seconds out (fast ranks iterate at
        # 0.05s, quiesce polls at <=1.0s). A virtual sleep here raced
        # wall scheduling — the pause could land near the slowed sleep's
        # END, where the rank wakes within the 1.3s ack budget and acks.
        clock = active_clock()
        deadline = time.monotonic() + 30
        while not any(d > clock.now() + 2.0
                      for d in clock.pending_deadlines()):
            assert time.monotonic() < deadline, \
                "degraded rank never entered its slowed iteration"
            time.sleep(0.001)
        with pytest.raises(GangStragglerError):
            h.coord.snapshot(2)
        assert h.coord.last_abort_reason == "straggler"
        h.sim.degrade_host(hid, 1.0)
        # the straggler is still inside its stale 5s sleep (the abort
        # budget is shorter than the sleep); wait for it to wake and
        # iterate at full speed before asking for the healed epoch
        it0 = rank.iteration
        deadline = time.monotonic() + 30
        while rank.iteration <= it0:
            assert time.monotonic() < deadline, "rank 3 never resumed"
            time.sleep(0.001)
        h.coord.snapshot(3)                    # healed: commits again
        assert list_steps(h.store, "apps/j") == [1, 3]
        assert h.coord.stats()["aborts"] == 1
    finally:
        h.stop()


def test_shrink_restore_preserves_cut_and_invariant():
    """Snapshot at 4 ranks, restore at 2: the global cut reassembles
    exactly, in-flight rows route to their new owners, and the invariant
    holds — the storage half of outage-driven elastic shrink."""
    h = _Harness(n_ranks=4, rows=10)
    try:
        active_clock().sleep(2.0)
        h.coord.snapshot(5)
        out4, _, _ = load_gang_ranks(h.store, "apps/j", n_ranks=4)
        out2, _, stats = load_gang_ranks(h.store, "apps/j", n_ranks=2)
        assert gang_invariant(out2)["consistent"] == 1.0
        np.testing.assert_array_equal(_concat_state(out2),
                                      _concat_state(out4))
        assert stats["max_fetches_per_chunk"] == 1
        # restart the app on 2 of the VMs from the restored trees
        h.app.stop()
        ctx = types.SimpleNamespace(coord_id="j", vms=h.vms[:2],
                                    service=None, transport=h.sim)
        app2 = GangApp(global_rows=10, iter_time_s=0.05)
        app2.start(ctx, out2)
        try:
            it0 = app2.min_iteration()
            assert it0 == out2[0]["iteration"], \
                "restore must resume from the cut's iteration"
            active_clock().sleep(1.0)
            assert app2.min_iteration() > it0
        finally:
            app2.stop()
    finally:
        h.stop()


def test_barrier_trace_replays_bit_for_bit():
    """Same storyline, same clock → the same protocol trace. Drain rows
    carry in-flight counts, which depend on same-instant thread wakes —
    scheduling, not protocol — so the comparison drops their payloads
    (FaultOutcome.trace_key makes the same call for storage faults)."""
    def run():
        h = _Harness(n_ranks=3, rows=9)
        try:
            active_clock().sleep(1.0)
            h.coord.snapshot(1)
            hid = h.vms[0].host.host_id
            h.coord.arm("drain", lambda: h.sim.partition_host(hid))
            with pytest.raises(GangBarrierError):
                h.coord.snapshot(2)
            return [(step, tag, "" if tag == "drain" else detail)
                    for _, step, tag, detail in h.coord.barrier_trace()]
        finally:
            h.stop()
    t1, t2 = run(), run()
    assert t1 == t2
    assert (2, "abort", "partition_or_crash") in t1
