"""Config registry + analytic parameter-count sanity."""
import pytest

from repro.configs import (ARCH_REGISTRY, ASSIGNED_ARCHS, SHAPES, get_config,
                           reduced, shape_applicable)

EXPECTED = {
    # arch -> (published total params, tolerance fraction)
    "internlm2-1.8b": (1.89e9, 0.25),
    "granite-8b": (8.1e9, 0.25),
    "nemotron-4-340b": (340e9, 0.20),
    "gemma3-12b": (12e9, 0.35),
    "xlstm-125m": (125e6, 0.6),
    "internvl2-2b": (1.9e9, 0.3),        # LM backbone only (ViT is a stub)
    "llama4-maverick-400b-a17b": (400e9, 0.25),
    "llama4-scout-17b-a16e": (109e9, 0.30),
    "jamba-v0.1-52b": (52e9, 0.30),
}


def test_registry_has_all_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        assert get_config(a).name == a


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nonexistent-model")


@pytest.mark.parametrize("arch,expected", sorted(EXPECTED.items()))
def test_param_counts_match_published(arch, expected):
    target, tol = expected
    n = get_config(arch).param_count()
    assert abs(n - target) / target < tol, \
        f"{arch}: analytic {n:.3g} vs published {target:.3g}"


def test_moe_active_params():
    mav = get_config("llama4-maverick-400b-a17b")
    assert mav.active_param_count() < 0.1 * mav.param_count()
    scout = get_config("llama4-scout-17b-a16e")
    assert scout.active_param_count() < 0.35 * scout.param_count()


def test_shape_grid():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    # long_500k only for sub-quadratic archs
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == cfg.subquadratic, (arch, why)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[s])[0]
    live = sum(1 for a in ASSIGNED_ARCHS for s in SHAPES.values()
               if shape_applicable(get_config(a), s)[0])
    assert live == 33   # 30 universal + 3 subquadratic long_500k


@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
def test_reduced_configs_are_small(arch):
    r = reduced(get_config(arch))
    assert r.d_model <= 128 and r.vocab_size <= 512
    assert r.param_count() < 5e6
    # family-defining structure is preserved
    full = get_config(arch)
    assert r.family == full.family
    assert (r.moe is None) == (full.moe is None)
    assert (r.ssm is None) == (full.ssm is None)
    assert r.attn_every == full.attn_every
