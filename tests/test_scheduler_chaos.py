"""GlobalScheduler under seeded chaos: vm_crash recovers in place,
CLOUD_OUTAGE requeues the job off the dead cloud and backfills it onto a
surviving cloud with zero chunk re-uploads — and the whole storyline
(fault trace + scheduler decision trace) replays bit-for-bit from the
seed, with every blocking call verifiably outside the scheduler lock."""
import time

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, ChaosController, CheckpointPolicy,
                        CoordState, FaultEvent, FaultKind, FaultSchedule,
                        GlobalScheduler, ImageReplicator, ReplicationPolicy,
                        SimulatedApp, StandbyTarget)
from repro.core.chaos import VirtualClock
from repro.sim import active_clock


import pytest


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """Run this suite on the discrete-event virtual clock (repro.sim)."""
    yield



def _run_outage_scenario(seed, record_lock=False):
    """Seeded storyline: one replicated job on cloud A; a VM crash
    (same-cloud recovery), then a whole-cloud outage of A (requeue +
    cross-cloud backfill onto B). Returns everything determinism needs."""
    a = SnoozeBackend(n_hosts=8)
    b = OpenStackBackend(n_hosts=8)
    store_a, store_b = InMemoryStore(), InMemoryStore()
    svc = CACSService({"snooze": a, "openstack": b},
                      {"default": store_a, "standby": store_b})
    rep = ImageReplicator(svc)
    rep.add_target(StandbyTarget("openstack", store=store_b,
                                 backend="openstack"))
    svc.attach_replicator(rep)
    sched = GlobalScheduler(svc, clock=VirtualClock(),
                            cloud_stores={"snooze": "default",
                                          "openstack": "standby"})
    svc.attach_scheduler(sched)
    lock_sightings = []
    if record_lock:
        for name in ("suspend", "resume", "restart_from", "start_queued"):
            orig = getattr(svc.apps, name)

            def wrapper(*args, _orig=orig, _name=name, **kw):
                lock_sightings.append((_name, sched.lock_held()))
                return _orig(*args, **kw)

            setattr(svc.apps, name, wrapper)
    sched.start()
    rep.start()
    try:
        cid = sched.submit(ASR(
            name=f"chaos-{seed}", n_vms=4, backend="snooze", priority=5,
            app_factory=lambda: SimulatedApp(iter_time_s=0.2,
                                             state_mb=0.02),
            policy=CheckpointPolicy(period_s=0.2, keep_last=3)))
        svc.wait_for_state(cid, CoordState.RUNNING, 30)
        svc.trigger_checkpoint(cid)        # a restore point always exists
        rep.watch(cid, ReplicationPolicy(targets=("openstack",)))
        rep.sync()                         # standby warm before the clock

        schedule = FaultSchedule(seed=seed, events=[
            FaultEvent(at_s=2.0, kind=FaultKind.VM_CRASH,
                       vm_index=seed % 4),
            FaultEvent(at_s=8.0, kind=FaultKind.CLOUD_OUTAGE),
        ])
        ctrl = ChaosController(svc, cid, a, schedule, scheduler=sched,
                               settle_timeout_s=60)
        outcomes = ctrl.run()
        coord = svc.db.get(cid)
        # the outage settles on the scheduler's backfill; give the final
        # state AND the counters a beat to publish before reading them:
        # restart_from flips the job RUNNING before _finish_restart (on
        # the pool thread) bumps backfills, so waiting on state alone
        # races the counter by a few milliseconds under load
        deadline = time.monotonic() + 30
        while (time.monotonic() < deadline
               and not (coord.state == CoordState.RUNNING
                        and sched.backfills >= 1)):
            active_clock().sleep(0.01)
        return {
            "ok": all(o.ok for o in outcomes),
            "trace": [o.trace_key() for o in outcomes],
            "decisions": [t[1:] for t in sched.decision_trace()],
            "backend": coord.asr.backend,
            "state": coord.state.value,
            "backfills": sched.backfills,
            "requeues": sched.requeues,
            "reuploads": sched.backfill_reuploads,
            "recoveries": coord.recoveries,
            "restarts": coord.app.restarts if coord.app else -1,
            "lock_sightings": lock_sightings,
        }
    finally:
        sched.stop()
        rep.stop()
        svc.shutdown()


def test_outage_requeues_and_backfills_onto_surviving_cloud():
    res = _run_outage_scenario(seed=7, record_lock=True)
    assert res["ok"], res["trace"]
    assert res["state"] == "RUNNING"
    assert res["backend"] == "openstack", \
        "the job must end up on the surviving cloud"
    assert res["requeues"] == 1 and res["backfills"] == 1
    assert res["reuploads"] == 0, \
        "backfill must restore purely from pre-replicated chunks"
    assert res["recoveries"] >= 1          # the vm_crash recovered in place
    assert res["restarts"] >= 2, \
        "the app must have restored from an image twice (crash + backfill)"
    ops = [op for op, _ in res["lock_sightings"]]
    assert "suspend" in ops or "restart_from" in ops
    assert all(not held for _, held in res["lock_sightings"]), \
        f"blocking call under the scheduler lock: {res['lock_sightings']}"
    # the decision trace tells the whole story, wall-clock-free
    kinds = [d[0] for d in res["decisions"]]
    assert kinds == ["submit", "start", "requeue", "backfill"]


def test_same_seed_replays_identical_decision_trace():
    """Satellite: same seed → identical fault trace AND identical
    scheduler decision trace across two runs (TIME_SCALE-compressed
    virtual clock injected into the scheduler)."""
    r1 = _run_outage_scenario(seed=11)
    r2 = _run_outage_scenario(seed=11)
    assert r1["ok"] and r2["ok"]
    assert r1["trace"] == r2["trace"]
    assert r1["decisions"] == r2["decisions"]
    assert r1["backend"] == r2["backend"] == "openstack"


def test_vm_crash_on_spanning_scheduler_recovers_in_place():
    """A plain VM crash must never trigger cross-cloud movement: the home
    cloud has spare capacity, so passive recovery replaces the VM there."""
    a = SnoozeBackend(n_hosts=8)
    b = OpenStackBackend(n_hosts=8)
    svc = CACSService({"snooze": a, "openstack": b},
                      {"default": InMemoryStore(),
                       "standby": InMemoryStore()})
    sched = GlobalScheduler(svc, cloud_stores={"snooze": "default",
                                               "openstack": "standby"})
    svc.attach_scheduler(sched)
    sched.start()
    try:
        cid = sched.submit(ASR(
            name="crash", n_vms=4, backend="snooze", priority=5,
            app_factory=lambda: SimulatedApp(iter_time_s=0.2,
                                             state_mb=0.01),
            policy=CheckpointPolicy(period_s=0)))
        svc.wait_for_state(cid, CoordState.RUNNING, 30)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        a.sim.fail_host(coord.vms[0].host.host_id)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if coord.recoveries >= 1 and coord.state == CoordState.RUNNING:
                break
            active_clock().sleep(0.02)
        assert coord.state == CoordState.RUNNING
        assert coord.asr.backend == "snooze", "no cross-cloud move"
        assert sched.backfills == 0 and sched.requeues == 0
    finally:
        sched.stop()
        svc.shutdown()
