"""Chaos harness + recovery-control-plane regression suite.

Covers: seeded fault-schedule determinism and scenario replay; the
recovery races the harness exposed (terminate during RESTARTING, double
vm_failure, straggler→suspend debounce, suspend holding coord.lock across
a save); step-counter reseeding after every restore path; mid-save storage
faults vs the COMMITTED protocol; and monitor robustness (raising health
hooks, total partitions, native-backend partition fallback).
"""
import threading
import time

import pytest

from repro.ckpt import ChaosStorageError, FaultyStore, InMemoryStore
from repro.ckpt.reader import list_steps
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, ChaosHealthHook, CheckpointPolicy,
                        CoordState, FaultEvent, FaultKind, FaultSchedule,
                        SimulatedApp, run_scenario)
from repro.core.monitoring import heartbeat_roundtrip
from repro.sim import active_clock


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """The whole suite runs on the discrete-event virtual clock: every
    sleep/poll in the control plane advances virtual time instantly, so
    multi-fault scenarios settle in milliseconds of wall time."""
    yield


def _mk_service(backend_cls=SnoozeBackend, n_hosts=16, store=None,
                **svc_kw):
    backend = backend_cls(n_hosts=n_hosts)
    store = store if store is not None else InMemoryStore()
    svc = CACSService({backend.name: backend}, {"default": store}, **svc_kw)
    return svc, backend, store


def _submit(svc, backend, n_vms=4, period=0.0, hook=None, **app_kw):
    asr = ASR(name="chaos-app", n_vms=n_vms, backend=backend.name,
              app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                               state_mb=0.05, **app_kw),
              policy=CheckpointPolicy(period_s=period, keep_last=3),
              health_hook=hook)
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, timeout=30)
    return cid


def _wait(pred, timeout=30.0):
    # wall safety deadline, clock-paced polling: the poll itself drives
    # virtual time forward when the system is otherwise idle
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        active_clock().sleep(0.01)
    return False


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_schedule_generation_deterministic():
    a = FaultSchedule.generate(seed=3, n_events=6)
    b = FaultSchedule.generate(seed=3, n_events=6)
    c = FaultSchedule.generate(seed=4, n_events=6)
    assert a.events == b.events
    assert a.events != c.events
    assert all(e.at_s <= n.at_s for e, n in zip(a.events, a.events[1:]))


def test_scenario_replays_deterministically():
    sched = FaultSchedule.generate(seed=5, n_events=3)
    r1 = run_scenario(sched, settle_timeout_s=30)
    r2 = run_scenario(sched, settle_timeout_s=30)
    assert r1.trace == r2.trace
    assert r1.sim_faults == r2.sim_faults
    assert r1.recoveries == r2.recoveries
    assert r1.final_state == r2.final_state


def test_vm_crash_scenario_measures_mttr():
    sched = FaultSchedule(seed=1, events=[
        FaultEvent(at_s=1.0, kind=FaultKind.VM_CRASH, vm_index=1)])
    res = run_scenario(sched, settle_timeout_s=30)
    (o,) = res.outcomes
    assert o.ok and o.final_state == "RUNNING"
    assert res.recoveries == 1
    assert o.detection_s is not None and o.detection_s >= 0
    assert o.restore_s is not None and o.restore_s > 0
    assert o.mttr_s is not None and o.mttr_s >= o.restore_s


def test_storyline_all_fault_classes_recover():
    res = run_scenario(FaultSchedule.storyline(seed=42),
                       settle_timeout_s=60)
    assert res.all_ok, [o for o in res.outcomes if not o.ok]
    assert res.final_state == "RUNNING"
    kinds = {o.event.kind for o in res.outcomes}
    # every single-cloud fault class; CLOUD_OUTAGE needs a standby cloud
    # (covered by tests/test_replication.py) and is excluded by design
    from repro.core.chaos import SINGLE_CLOUD_KINDS
    assert kinds == set(SINGLE_CLOUD_KINDS)


# ---------------------------------------------------------------------------
# step-counter reseeding (recovery must not restart numbering at 1)
# ---------------------------------------------------------------------------

def test_step_counter_reseeds_after_recovery_on_fresh_manager():
    svc, backend, _ = _mk_service()
    try:
        cid = _submit(svc, backend)
        s1 = svc.trigger_checkpoint(cid)
        s2 = svc.trigger_checkpoint(cid)
        assert (s1, s2) == (1, 2)
        # simulate a restarted Application Manager: in-memory counter gone
        svc.apps._step_counter.clear()
        coord = svc.db.get(cid)
        backend.sim.fail_host(coord.vms[0].host.host_id)
        assert _wait(lambda: coord.recoveries >= 1
                     and coord.state == CoordState.RUNNING)
        s3 = svc.trigger_checkpoint(cid)
        assert s3 == s2 + 1, "post-recovery save must continue numbering"
        assert svc.list_checkpoints(cid)[-1] == s3
    finally:
        svc.shutdown()


def test_restart_from_earlier_image_does_not_clobber_newer():
    svc, backend, store = _mk_service()
    try:
        cid = _submit(svc, backend)
        s1 = svc.trigger_checkpoint(cid)
        s2 = svc.trigger_checkpoint(cid)
        s3 = svc.trigger_checkpoint(cid)
        svc.apps._step_counter.clear()      # fresh-manager worst case
        svc.restart_from(cid, s1)           # user picks the EARLIEST image
        s4 = svc.trigger_checkpoint(cid)
        assert s4 == s3 + 1, "next save must not overwrite newer images"
        steps = svc.list_checkpoints(cid)
        assert steps[-1] == s4
        assert s2 in steps or s3 in steps   # keep_last=3 pruned oldest only
    finally:
        svc.shutdown()


def test_resume_reseeds_step_counter():
    svc, backend, _ = _mk_service()
    try:
        cid = _submit(svc, backend)
        svc.trigger_checkpoint(cid)
        svc.apps.suspend(cid)               # writes step 2 (swap-out image)
        svc.apps._step_counter.clear()
        svc.apps.resume(cid)
        assert svc.db.get(cid).state == CoordState.RUNNING
        assert svc.trigger_checkpoint(cid) == 3
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# races the chaos harness exposed
# ---------------------------------------------------------------------------

def test_straggler_suspend_debounced():
    # the swap-out save is slow (store latency), so the monitor re-reports
    # the straggler many times while the suspend is in flight — duplicates
    # must be dropped, not raced into RuntimeErrors
    svc, backend, _ = _mk_service(store=InMemoryStore(latency_s=0.05))
    try:
        cid = _submit(svc, backend, n_vms=4)
        coord = svc.db.get(cid)
        backend.sim.degrade_host(coord.vms[1].host.host_id, slowdown=100.0)
        assert _wait(lambda: coord.state == CoordState.SUSPENDED)
        assert svc.apps.events_deduped >= 1
        suspended = [h for h in coord.history if h[1] == "SUSPENDED"]
        assert len(suspended) == 1
        assert not any(h[1] == "ERROR" for h in coord.history)
    finally:
        svc.shutdown()


def test_suspend_does_not_hold_lock_during_save():
    gate = threading.Event()
    hit = threading.Event()

    class GateStore(InMemoryStore):
        def put(self, key, data):
            if "/cas/" in key and not hit.is_set():
                hit.set()
                assert gate.wait(10), "test gate never released"
            super().put(key, data)

    svc, backend, _ = _mk_service(store=GateStore())
    try:
        cid = _submit(svc, backend)
        coord = svc.db.get(cid)
        t = threading.Thread(target=svc.apps.suspend, args=(cid,))
        t.start()
        assert hit.wait(10), "suspend never reached the store"
        # the swap-out write is in flight; coord.lock must NOT be held —
        # checkpoint_now / the daemon / monitor handling all need it
        acquired = coord.lock.acquire(timeout=2)
        assert acquired, "suspend held coord.lock across the blocking save"
        coord.lock.release()
        gate.set()
        t.join(timeout=10)
        assert coord.state == CoordState.SUSPENDED
    finally:
        gate.set()
        svc.shutdown()


def test_terminate_during_restarting_is_clean():
    # OpenStack's slow allocation opens a wide RESTARTING window
    svc, backend, _ = _mk_service(backend_cls=OpenStackBackend)
    try:
        cid = _submit(svc, backend)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        backend.sim.fail_host(coord.vms[0].host.host_id)
        assert _wait(lambda: coord.state == CoordState.RESTARTING)
        final = svc.delete_coordinator(cid)
        assert final["state"] == "TERMINATED"
        assert not any(h[1] == "ERROR" for h in coord.history)
        with pytest.raises(KeyError):
            svc.db.get(cid)
        # no leaked allocations: nothing in the sim still belongs to cid
        leaked = [h.host_id for h in backend.sim._hosts.values()
                  if h.owner == cid]
        assert not leaked
    finally:
        svc.shutdown()


def test_double_vm_failure_triggers_single_recovery():
    svc, backend, _ = _mk_service()
    try:
        cid = _submit(svc, backend)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        backend.sim.fail_host(coord.vms[0].host.host_id)
        backend.sim.fail_host(coord.vms[2].host.host_id)
        assert _wait(lambda: coord.recoveries >= 1
                     and coord.state == CoordState.RUNNING)
        active_clock().sleep(0.3)  # any spurious second recovery would land
        assert coord.recoveries == 1
        assert all(vm.reachable for vm in coord.vms)
        assert coord.app.restarts == 1
        assert svc.apps.events_deduped >= 1   # second notification dropped
    finally:
        svc.shutdown()


def test_immediate_resume_after_suspend_gets_healthy_cluster():
    # SUSPENDED is published only after the old cluster is detached from
    # coord.vms: a resume racing the suspend's teardown must end up on a
    # fresh, reachable cluster (not one the suspend thread then destroys)
    svc, backend, _ = _mk_service(store=InMemoryStore(latency_s=0.02))
    try:
        cid = _submit(svc, backend, n_vms=4)
        coord = svc.db.get(cid)
        backend.sim.degrade_host(coord.vms[1].host.host_id, slowdown=100.0)
        assert _wait(lambda: coord.state == CoordState.SUSPENDED)
        svc.apps.resume(cid)                 # as fast after SUSPENDED as
        assert coord.state == CoordState.RUNNING      # the API allows
        assert len(coord.vms) == 4
        assert all(vm.reachable for vm in coord.vms)
        active_clock().sleep(0.2)            # suspend teardown fully done
        assert all(vm.reachable for vm in coord.vms), \
            "suspend teardown destroyed the resumed cluster"
    finally:
        svc.shutdown()


def test_resume_capacity_race_falls_back_to_suspended():
    svc, backend, _ = _mk_service(n_hosts=8)
    try:
        cid = _submit(svc, backend, n_vms=4)
        svc.trigger_checkpoint(cid)
        svc.apps.suspend(cid)
        # another tenant grabs most of the cloud while we're swapped out
        stolen = backend.sim.allocate(5, "other-tenant")
        svc.apps.resume(cid)                 # capacity check races away
        coord = svc.db.get(cid)
        assert coord.state == CoordState.SUSPENDED, \
            "failed resume must fall back to SUSPENDED, not ERROR"
        backend.sim.release(stolen)
        svc.apps.resume(cid)
        assert coord.state == CoordState.RUNNING
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# storage faults vs the COMMITTED protocol
# ---------------------------------------------------------------------------

def test_put_fault_mid_save_leaves_previous_committed_loadable():
    store = FaultyStore(InMemoryStore())
    svc, backend, _ = _mk_service(store=store)
    try:
        cid = _submit(svc, backend)
        s1 = svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        before = svc.ckpt.load(coord, s1)
        store.arm_put_errors(1)
        with pytest.raises((ChaosStorageError, IOError)):
            svc.trigger_checkpoint(cid)
        store.disarm()
        # the torn step is invisible; the previous image restores intact
        assert list_steps(store, coord.ckpt_prefix) == [s1]
        after = svc.ckpt.load(coord, None)
        assert after["iteration"] == before["iteration"]
        # and the plane is healthy again: the next save commits past it
        s_next = svc.trigger_checkpoint(cid)
        assert s_next > s1
        assert list_steps(store, coord.ckpt_prefix)[-1] == s_next
    finally:
        svc.shutdown()


def test_periodic_daemon_survives_async_save_fault():
    store = FaultyStore(InMemoryStore())
    svc, backend, _ = _mk_service(store=store)
    try:
        cid = _submit(svc, backend, period=0.08)
        coord = svc.db.get(cid)
        assert _wait(lambda: len(list_steps(store, coord.ckpt_prefix)) >= 1)
        store.arm_put_errors(1)              # one periodic save will die
        assert _wait(lambda: store.faults_injected >= 1)
        n_after_fault = len(list_steps(store, coord.ckpt_prefix))
        # the daemon must keep checkpointing this app afterwards
        assert _wait(lambda: len(list_steps(store, coord.ckpt_prefix))
                     > n_after_fault), "periodic daemon died after a fault"
        ck = svc.ckpt._async.get(cid)
        assert ck is not None and ck.failed_saves >= 1
        assert ck.last_error is not None
    finally:
        svc.shutdown()


def test_recovery_restores_despite_transient_get_faults():
    store = FaultyStore(InMemoryStore())
    svc, backend, _ = _mk_service(store=store)
    try:
        cid = _submit(svc, backend)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        store.arm_get_errors(1)
        backend.sim.fail_host(coord.vms[0].host.host_id)
        assert _wait(lambda: coord.recoveries >= 1
                     and coord.state == CoordState.RUNNING), \
            "transient get fault during restore must be retried"
        assert not any(h[1] == "ERROR" for h in coord.history)
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# monitor robustness
# ---------------------------------------------------------------------------

def test_monitor_survives_raising_health_hook():
    svc, backend, _ = _mk_service()
    try:
        hook = ChaosHealthHook()
        cid = _submit(svc, backend, hook=hook)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        hook.arm(1)                          # next health poll RAISES
        assert _wait(lambda: coord.recoveries >= 1
                     and coord.state == CoordState.RUNNING)
        mon = svc.apps.monitor
        assert mon._thread is not None and mon._thread.is_alive()
        hb = mon.heartbeats
        assert _wait(lambda: mon.heartbeats > hb), \
            "monitor thread stopped polling after a raising hook"
    finally:
        svc.shutdown()


def test_partition_detected_on_native_backend_via_fallback():
    svc, backend, _ = _mk_service()          # Snooze: native notifications
    try:
        cid = _submit(svc, backend)
        svc.trigger_checkpoint(cid)
        coord = svc.db.get(cid)
        backend.sim.partition_host(coord.vms[1].host.host_id)
        assert _wait(lambda: coord.recoveries >= 1
                     and coord.state == CoordState.RUNNING), \
            "partition is invisible to the IaaS; the tree must catch it"
        assert svc.apps.monitor.native_notifications == 0
        assert svc.apps.monitor.partition_fallbacks >= 1
        assert all(vm.reachable for vm in coord.vms)
    finally:
        svc.shutdown()


def test_heartbeat_with_every_vm_unreachable():
    backend = SnoozeBackend(n_hosts=8)
    vms = backend.allocate_vms(3, None, owner="t")
    for vm in vms:
        backend.sim.partition_host(vm.host.host_id)

    def exploding_hook():
        raise RuntimeError("no one to ask")

    rep = heartbeat_roundtrip(vms, exploding_hook)
    assert sorted(rep.unreachable) == sorted(vm.vm_id for vm in vms)
    assert rep.unhealthy == []               # hook skipped: app unreachable
    assert rep.stragglers == []              # no pace baseline
    assert not rep.ok
