"""Shared test helpers.

NOTE: no XLA_FLAGS manipulation here — smoke tests must see the real single
CPU device. Multi-device tests (resharding, dry-run) spawn subprocesses
that set --xla_force_host_platform_device_count themselves.
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture
def sim_clock():
    """Run a test on the discrete-event virtual clock (repro.sim).

    Installs a fresh SimClock process-wide for the duration of the test:
    every ``sim_sleep``, store latency, daemon poll and settle wait in the
    control plane advances virtual time instantly instead of wall
    sleeping.  Suites opt in with a module-local autouse shim::

        @pytest.fixture(autouse=True)
        def _virtual_time(sim_clock):
            yield

    Teardown closes the clock (wakes every sleeper) *after* the test's own
    service fixtures have shut down, then restores the wall clock.
    """
    from repro.sim import SimClock, install_clock
    clk = SimClock()
    prev = install_clock(clk)
    try:
        yield clk
    finally:
        clk.close()
        install_clock(prev)


def make_batch(cfg, model, B, S, seed=0):
    rng = np.random.Generator(np.random.PCG64(seed))
    toks = lambda b, s: rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks(B, S)),
             "targets": jnp.asarray(toks(B, S))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            model.dtype) * 0.02
    elif cfg.frontend is not None:
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            model.dtype) * 0.02
        batch["tokens"] = jnp.asarray(toks(B, S - cfg.frontend_len))
    return batch


def run_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with N forced host devices."""
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n")
    r = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
