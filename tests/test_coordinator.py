"""Coordinator state machine + DB invariants (property-based)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # bare env: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.ckpt import InMemoryStore
from repro.core import (ASR, CoordinatorDB, CoordState, InvalidTransition,
                        SimulatedApp)
from repro.core.coordinator import TRANSITIONS


def _asr():
    return ASR(name="t", n_vms=1, backend="x",
               app_factory=lambda: SimulatedApp())


def test_legal_lifecycle():
    db = CoordinatorDB()
    c = db.create(_asr())
    for s in (CoordState.PROVISIONING, CoordState.READY, CoordState.RUNNING,
              CoordState.SUSPENDED, CoordState.RESTARTING, CoordState.RUNNING,
              CoordState.TERMINATING, CoordState.TERMINATED):
        db.transition(c, s)
    assert [h[1] for h in c.history][0] == "CREATING"
    assert c.state == CoordState.TERMINATED


def test_illegal_transitions_raise():
    db = CoordinatorDB()
    c = db.create(_asr())
    with pytest.raises(InvalidTransition):
        db.transition(c, CoordState.RUNNING)          # CREATING -> RUNNING
    db.transition(c, CoordState.PROVISIONING)
    with pytest.raises(InvalidTransition):
        db.transition(c, CoordState.SUSPENDED)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(list(CoordState)), min_size=1, max_size=12))
def test_state_machine_closure_property(walk):
    """Random transition walks: every accepted transition is in the table;
    TERMINATED is absorbing; history length == accepted transitions + 1."""
    db = CoordinatorDB()
    c = db.create(_asr())
    accepted = 0
    for target in walk:
        prev = c.state
        try:
            db.transition(c, target)
            assert target in TRANSITIONS[prev]
            accepted += 1
        except InvalidTransition:
            assert target not in TRANSITIONS[prev]
            assert c.state == prev
    assert len(c.history) == accepted + 1
    if CoordState.TERMINATED in [h for _, h, *_ in []]:
        pass
    assert TRANSITIONS[CoordState.TERMINATED] == ()


def test_db_persistence():
    store = InMemoryStore()
    db = CoordinatorDB(store)
    c = db.create(_asr())
    db.transition(c, CoordState.PROVISIONING)
    keys = store.list("db/coordinators/")
    assert len(keys) == 1
    assert b"PROVISIONING" in store.get(keys[0])
    db.remove(c.coord_id)
    assert not store.list("db/coordinators/")


def test_db_load_rehydrates_records():
    """The read path of the persistence story (§6.4): a fresh DB over the
    same store sees every record — state, history, policy — sans the
    process-bound app/VMs, and raises helpfully if the app is started
    without re-attaching a factory."""
    import dataclasses

    from repro.core.coordinator import CheckpointPolicy

    store = InMemoryStore()
    db = CoordinatorDB(store)
    asr = dataclasses.replace(
        _asr(), policy=CheckpointPolicy(period_s=0.5, codec="zlib",
                                        keep_last=7, store="default"))
    a = db.create(asr)
    db.transition(a, CoordState.PROVISIONING)
    db.transition(a, CoordState.READY)
    b = db.create(_asr())
    a.metrics["last_recovery_s"] = 1.25
    db.transition(a, CoordState.RUNNING)      # re-persists a with metrics

    db2 = CoordinatorDB(store)
    loaded = {c.coord_id: c for c in db2.load()}
    assert set(loaded) == {a.coord_id, b.coord_id}
    ra = loaded[a.coord_id]
    assert ra.state == CoordState.RUNNING
    assert [s for _, s in ra.history] == ["CREATING", "PROVISIONING",
                                          "READY", "RUNNING"]
    assert ra.vms == [] and ra.app is None
    assert ra.asr.policy.codec == "zlib" and ra.asr.policy.keep_last == 7
    assert ra.asr.policy.period_s == 0.5
    assert ra.metrics["last_recovery_s"] == 1.25
    assert ra.ckpt_prefix == a.ckpt_prefix
    with pytest.raises(RuntimeError, match="app_factory"):
        ra.asr.app_factory()
    # idempotent: records already in memory are not re-loaded
    assert db2.load() == []
    # a memory-only DB has nothing to load
    assert CoordinatorDB().load() == []
