"""Cross-cloud checkpoint replication & standby failover.

Covers: chunk-level replication dedup (only missing chunks cross the
link); the standby-side commit protocol (only fully replicated images are
visible, torn replications heal); lag/RPO accounting and the bandwidth
cap; whole-cloud outage semantics in the simulator; the seeded failover
scenario (standby restart from the newest fully replicated image with
zero chunk re-uploads, deterministic trace); and warm migration
(cross-cloud transfer collapsing to the unreplicated delta).
"""
import time

import pytest

from repro.ckpt import FaultyStore, InMemoryStore
from repro.ckpt.layout import cas_prefix
from repro.ckpt.reader import list_steps
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.clusters.simulator import CapacityError
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        FailoverController, ImageReplicator,
                        ReplicationPolicy, SimulatedApp, StandbyTarget,
                        clone, run_failover_scenario)


def _mk_pair(dst_store=None):
    src_store = InMemoryStore()
    dst_store = dst_store if dst_store is not None else InMemoryStore()
    src = CACSService({"snooze": SnoozeBackend(8)}, {"default": src_store})
    dst = CACSService({"openstack": OpenStackBackend(8)},
                      {"default": dst_store})
    return src, src_store, dst, dst_store


def _submit(svc, backend="snooze", n_vms=2, state_mb=0.05, period=0.0):
    asr = ASR(name="repl", n_vms=n_vms, backend=backend,
              app_factory=lambda: SimulatedApp(iter_time_s=0.2,
                                               state_mb=state_mb),
              policy=CheckpointPolicy(period_s=period, keep_last=3))
    cid = svc.submit(asr)
    svc.wait_for_state(cid, CoordState.RUNNING, 30)
    return cid


def _replicator(src, dst, dst_store, **policy_kw):
    rep = ImageReplicator(src)
    rep.add_target(StandbyTarget("standby", store=dst_store, service=dst,
                                 backend="openstack"))
    return rep, ReplicationPolicy(targets=("standby",), **policy_kw)


# ---------------------------------------------------------------------------
# replication data path
# ---------------------------------------------------------------------------

def test_replicates_only_missing_chunks():
    from benchmarks.common import DistributedSimApp
    src_store, dst_store = InMemoryStore(), InMemoryStore()
    src = CACSService({"snooze": SnoozeBackend(8)}, {"default": src_store})
    dst = CACSService({"openstack": OpenStackBackend(8)},
                      {"default": dst_store})
    try:
        asr = ASR(name="repl", n_vms=2, backend="snooze",
                  app_factory=lambda: DistributedSimApp(8, 1.0,
                                                        iter_time_s=0.2),
                  policy=CheckpointPolicy(period_s=0.0, keep_last=3))
        cid = src.submit(asr)
        src.wait_for_state(cid, CoordState.RUNNING, 30)
        s1 = src.trigger_checkpoint(cid)
        rep, pol = _replicator(src, dst, dst_store)
        rep.watch(cid, pol)
        rep.sync()
        prefix = src.db.get(cid).ckpt_prefix
        assert list_steps(dst_store, prefix) == [s1]
        bytes_first = dst_store.bytes_in
        # the 8 proc shards are untouched between saves: replicating s2
        # ships only the small changed chunks (+ manifest/marker), the
        # shared bulk dedups against what s1 already put on the standby
        s2 = src.trigger_checkpoint(cid)
        rep.sync()
        assert list_steps(dst_store, prefix) == [s1, s2]
        stats = rep.replication_stats(cid)["targets"]["standby"]
        assert stats["last_step"] == s2
        assert stats["lag_images"] == 0 and stats["rpo_s"] == 0.0
        delta = dst_store.bytes_in - bytes_first
        assert delta < bytes_first / 4
        assert stats["chunks_skipped"] >= 8       # shared shards deduped
    finally:
        src.shutdown()
        dst.shutdown()


def test_standby_sees_only_fully_replicated_images():
    faulty = FaultyStore(InMemoryStore())
    src, src_store, dst, dst_store = _mk_pair(dst_store=faulty)
    try:
        cid = _submit(src)
        step = src.trigger_checkpoint(cid)
        rep, pol = _replicator(src, dst, faulty)
        rep.watch(cid, pol)
        prefix = src.db.get(cid).ckpt_prefix
        faulty.arm_put_errors(1)              # tear the replication mid-ship
        rep.sync()
        # the torn image must be invisible on the standby (no COMMITTED)
        assert list_steps(faulty, prefix) == []
        assert rep.sync_errors >= 1
        assert rep.replication_stats(cid)["targets"]["standby"]["errors"] >= 1
        faulty.disarm()
        rep.sync()                            # the next pass heals it
        assert list_steps(faulty, prefix) == [step]
    finally:
        src.shutdown()
        dst.shutdown()


def test_replication_lag_and_budget_accounting():
    src, src_store, dst, dst_store = _mk_pair()
    try:
        cid = _submit(src)
        src.trigger_checkpoint(cid)
        rep, pol = _replicator(src, dst, dst_store, lag_budget_s=1e-9)
        rep.watch(cid, pol)
        rep.sync()
        time.sleep(0.02)                      # commit-time gap > budget
        src.trigger_checkpoint(cid)
        src.trigger_checkpoint(cid)
        stats = rep.replication_stats(cid)["targets"]["standby"]
        assert stats["lag_images"] == 2
        assert stats["rpo_s"] > 0
        assert not stats["within_budget"]
        rep.sync()
        stats = rep.replication_stats(cid)["targets"]["standby"]
        assert stats["lag_images"] == 0 and stats["within_budget"]
        # the coordinator carries the lag metric for dashboards
        assert "replication_lag_s:standby" in src.db.get(cid).metrics
    finally:
        src.shutdown()
        dst.shutdown()


def test_bandwidth_cap_throttles_replication():
    src, src_store, dst, dst_store = _mk_pair()
    try:
        cid = _submit(src, state_mb=0.4)      # ~0.4 MB image
        src.trigger_checkpoint(cid)
        rep, pol = _replicator(src, dst, dst_store, bandwidth_bps=4e6)
        rep.watch(cid, pol)
        t0 = time.monotonic()
        rep.sync()                            # ~0.4MB at 4MB/s -> >=0.1s
        assert time.monotonic() - t0 >= 0.08
        assert rep.replication_stats(cid)["targets"]["standby"][
            "bytes_copied"] >= 0.4 * 1024 * 1024
    finally:
        src.shutdown()
        dst.shutdown()


def test_prunes_standby_steps_with_primary_gc():
    src, src_store, dst, dst_store = _mk_pair()
    try:
        cid = _submit(src)
        rep, pol = _replicator(src, dst, dst_store)
        rep.watch(cid, pol)
        prefix = src.db.get(cid).ckpt_prefix
        for _ in range(5):                    # keep_last=3 prunes 1..2
            src.trigger_checkpoint(cid)
            rep.sync()
        assert list_steps(dst_store, prefix) == list_steps(src_store, prefix)
        stats = rep.replication_stats(cid)["targets"]["standby"]
        assert stats["steps_pruned"] >= 1
    finally:
        src.shutdown()
        dst.shutdown()


# ---------------------------------------------------------------------------
# whole-cloud outage (simulator semantics)
# ---------------------------------------------------------------------------

def test_cloud_outage_blocks_allocation_until_healed():
    backend = SnoozeBackend(n_hosts=4)
    sim = backend.sim
    got = sim.allocate(2, "owner")
    sim.cloud_outage()
    assert sim.idle_hosts() == []
    assert all(h.partitioned for h in got)
    with pytest.raises(CapacityError):
        sim.allocate(1, "owner2")
    sim.release(got)                          # release mid-outage: hosts
    assert sim.idle_hosts() == []             # stay dark, not reusable
    sim.heal_outage()
    assert len(sim.idle_hosts()) == 4
    assert sim.allocate(1, "owner3")


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------

def test_seeded_failover_restarts_on_standby_with_zero_reuploads():
    res = run_failover_scenario(seed=11, outage_at_s=20.0, period_s=0.05,
                                settle_timeout_s=60)
    fo = res.failover
    assert fo.ok and res.standby_state == "RUNNING"
    assert fo.target == "standby" and fo.step is not None
    # the acceptance bar: every restored chunk was pre-replicated — the
    # failover itself uploads nothing into the standby CAS namespace
    assert fo.chunks_reuploaded == 0
    assert fo.mttr_s is not None and fo.mttr_s > 0
    assert res.restored_iteration <= res.primary_iteration
    assert res.primary_final_state == "TERMINATED"   # retired, images kept
    assert res.trace[0][0] == "cloud_outage" and res.trace[0][2] is True


def test_failover_scenario_replays_deterministically():
    a = run_failover_scenario(seed=23, outage_at_s=10.0, settle_timeout_s=60)
    b = run_failover_scenario(seed=23, outage_at_s=10.0, settle_timeout_s=60)
    # same determinism contract as chaos.run_scenario: the outcome *trace*
    # (fault, target, ok, final state, detail head) replays bit-for-bit;
    # wall-time quantities (MTTR, iteration counts) are measurements
    assert a.trace == b.trace
    assert a.failover.ok and b.failover.ok
    assert a.failover.step == b.failover.step


def test_lagged_replication_increases_rpo_not_mttr_failure():
    res = run_failover_scenario(seed=7, outage_at_s=25.0, period_s=0.05,
                                continuous_replication=False,
                                settle_timeout_s=60)
    assert res.failover.ok
    # replication stopped after the first image: the standby restores an
    # old step and the RPO (lost iterations) is visibly larger
    assert res.failover.step == 1
    assert res.replication["targets"]["standby"]["lag_images"] >= 1
    assert res.iterations_lost > 0


def test_failover_without_replica_fails_loudly():
    src, src_store, dst, dst_store = _mk_pair()
    try:
        cid = _submit(src)
        src.trigger_checkpoint(cid)
        rep, pol = _replicator(src, dst, dst_store)
        rep.watch(cid, pol)                   # watched but never synced
        ctrl = FailoverController(src, rep)
        with pytest.raises(RuntimeError, match="fully replicated"):
            ctrl.failover(cid)
        assert not dst.list_coordinators()    # nothing half-created
    finally:
        src.shutdown()
        dst.shutdown()


def test_service_facade_exposes_replication_stats():
    src, src_store, dst, dst_store = _mk_pair()
    try:
        cid = _submit(src)
        assert src.replication_stats(cid) == {}
        rep, pol = _replicator(src, dst, dst_store)
        src.attach_replicator(rep)
        rep.watch(cid, pol)
        src.trigger_checkpoint(cid)
        rep.sync()
        stats = src.replication_stats(cid)
        assert stats["targets"]["standby"]["images_replicated"] == 1
    finally:
        src.shutdown()                        # also stops the replicator
        dst.shutdown()


# ---------------------------------------------------------------------------
# warm migration
# ---------------------------------------------------------------------------

def test_warm_migration_transfers_only_unreplicated_delta():
    from benchmarks.common import DistributedSimApp
    src_store = InMemoryStore()
    warm_store, cold_store = InMemoryStore(), InMemoryStore()
    src = CACSService({"snooze": SnoozeBackend(8)}, {"default": src_store})
    warm = CACSService({"openstack": OpenStackBackend(8)},
                       {"default": warm_store})
    cold = CACSService({"openstack": OpenStackBackend(8)},
                       {"default": cold_store})
    try:
        asr = ASR(name="warm", n_vms=2, backend="snooze",
                  app_factory=lambda: DistributedSimApp(8, 2.0,
                                                        iter_time_s=0.2),
                  policy=CheckpointPolicy(period_s=0.0))
        cid = src.submit(asr)
        src.wait_for_state(cid, CoordState.RUNNING, 30)
        src.trigger_checkpoint(cid)
        rep = ImageReplicator(src)
        rep.add_target(StandbyTarget("w", store=warm_store, service=warm,
                                     backend="openstack"))
        rep.watch(cid, ReplicationPolicy(targets=("w",)))
        rep.sync()
        # dirty 2 of 8 shards -> the next image is 3/4 replicated already
        app = src.db.get(cid).app
        app.shards[0] = app.shards[0] + 1.0
        app.shards[1] = app.shards[1] + 1.0
        s2 = src.trigger_checkpoint(cid)

        before = src_store.bytes_out
        clone(src, cid, cold, backend="openstack", step=s2,
              fresh_checkpoint=False)
        cold_bytes = src_store.bytes_out - before

        before = src_store.bytes_out
        clone(src, cid, warm, backend="openstack", step=s2,
              fresh_checkpoint=False)
        warm_bytes = src_store.bytes_out - before

        # warm transfer crosses only the unreplicated delta (2/8 shards);
        # everything else is sourced from the destination-side replica
        assert warm_bytes < cold_bytes / 2
        wstats = warm_store.dedup_stats()
        assert wstats["replica_hits"] >= 6
        assert wstats["replica_bytes_local"] > 0
        assert cold_store.dedup_stats()["replica_hits"] == 0
    finally:
        src.shutdown()
        warm.shutdown()
        cold.shutdown()
