"""Parallel data plane: crash-safety, dedup determinism and bit-identical
round-trips under concurrency (writer/reader worker pools, multi-stream
two-tier replication, atomic put_if_absent)."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, DataPlaneConfig, InMemoryStore,
                        TwoTierStore, restore, save_checkpoint)
from repro.ckpt.layout import COMMITTED, MANIFEST, cas_prefix, step_prefix

PAR = DataPlaneConfig.with_workers(8)


def _tree(seed: int, n_leaves: int = 12, n: int = 2048):
    rng = np.random.Generator(np.random.PCG64(seed))
    return {f"leaf{i:02d}": jnp.asarray(
        rng.standard_normal(n).astype(np.float32))
        for i in range(n_leaves)}


class OrderedStore(InMemoryStore):
    """Records the completion order of puts (for commit-protocol checks)."""

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.put_order = []

    def put(self, key, data):
        super().put(key, data)
        with self._lock:
            self.put_order.append(key)


def test_parallel_roundtrip_bit_identical():
    tree = _tree(0)
    store = InMemoryStore(latency_s=0.001)
    save_checkpoint(store, "p", 1, tree, plane=PAR)
    out, _ = restore(store, "p", plane=PAR)
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_committed_never_precedes_referenced_chunks():
    """Crash-safety under parallelism: every chunk a manifest references is
    durable before the manifest, which lands before COMMITTED."""
    tree = _tree(1, n_leaves=24)
    store = OrderedStore(latency_s=0.0005)
    man = save_checkpoint(store, "p", 1, tree, plane=PAR)
    order = {k: i for i, k in enumerate(store.put_order)}
    man_at = order[f"{step_prefix('p', 1)}/{MANIFEST}"]
    com_at = order[f"{step_prefix('p', 1)}/{COMMITTED}"]
    assert com_at == len(store.put_order) - 1
    assert man_at == com_at - 1
    for li in man.leaves.values():
        for c in li.chunks:
            assert order[c.key] < man_at, f"chunk {c.key} after manifest"


def test_parallel_dedup_counters_deterministic():
    """Identical content across leaves collapses to one put no matter how
    8 workers race: single-flight + atomic put_if_absent."""
    same = jnp.asarray(np.full(4096, 3.25, np.float32))
    tree = {f"dup{i}": same for i in range(16)}
    store = InMemoryStore()
    man = save_checkpoint(store, "p", 1, tree, plane=PAR)
    dd = man.metadata["dedup"]
    assert dd["chunks"] == 16
    assert dd["dedup_misses"] == 1
    assert dd["dedup_hits"] == 15
    assert dd["bytes_written"] == 4096 * 4
    assert len(store.list(cas_prefix("p"))) == 1
    # store-level counters agree (no lost updates)
    assert store.dedup_misses == 1


def test_workers1_reproduces_serial_plane():
    tree = _tree(2)
    serial = InMemoryStore()
    par = InMemoryStore()
    m1 = save_checkpoint(serial, "p", 1, tree,
                         plane=DataPlaneConfig.serial())
    m2 = save_checkpoint(par, "p", 1, tree, plane=PAR)
    assert m1.metadata["dedup"] == {**m2.metadata["dedup"]}
    assert serial.put_count == par.put_count
    # identical chunk keys, identical stored payload (manifest JSON length
    # can differ by a digit of the wall-clock timestamp, so compare cas/)
    assert serial.list("") == par.list("")
    assert serial.total_bytes(cas_prefix("p")) == \
        par.total_bytes(cas_prefix("p"))


def test_backpressure_tiny_budget_still_correct():
    """max_inflight_bytes smaller than one chunk: pipeline degrades to
    near-serial admission but must not deadlock or corrupt."""
    tree = _tree(3, n_leaves=8)
    plane = DataPlaneConfig(encode_workers=2, upload_workers=4,
                            max_inflight_bytes=1024)        # < one chunk
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, tree, plane=plane)
    out, _ = restore(store, "p", plane=plane)
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_concurrent_saves_and_restore_shared_link():
    """Stress: three writers on distinct prefixes + a reader, all through
    one shared-bandwidth store (the paper's contended NFS ingress)."""
    store = InMemoryStore(bandwidth_bps=2e9, shared_link=True)
    trees = {f"app{i}": _tree(10 + i, n_leaves=6) for i in range(3)}
    for name, tree in trees.items():        # step 1 exists for the reader
        save_checkpoint(store, name, 1, tree, plane=PAR)
    errors = []

    def writer(name, tree):
        try:
            for step in (2, 3):
                save_checkpoint(store, name, step, tree, plane=PAR)
        except BaseException as e:          # pragma: no cover
            errors.append(e)

    def reader(name, tree):
        try:
            for _ in range(4):
                out, _ = restore(store, name, 1, plane=PAR)
                for k, v in tree.items():
                    np.testing.assert_array_equal(np.asarray(out[k]),
                                                  np.asarray(v))
        except BaseException as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(n, t))
               for n, t in trees.items()]
    threads += [threading.Thread(target=reader, args=(n, t))
                for n, t in trees.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors
    for name, tree in trees.items():        # every committed step restores
        for step in (1, 2, 3):
            out, _ = restore(store, name, step, plane=PAR)
            for k, v in tree.items():
                np.testing.assert_array_equal(np.asarray(out[k]),
                                              np.asarray(v))


def test_put_if_absent_atomic_under_race():
    store = InMemoryStore(latency_s=0.002)
    data = b"z" * 4096
    results = []

    def race():
        results.append(store.put_if_absent("k", data))

    threads = [threading.Thread(target=race) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert results.count(True) == 1         # exactly one writer
    assert store.put_count == 1             # and exactly one store write
    assert store.dedup_misses == 1
    assert store.dedup_hits == 7


def test_restore_single_flight_shared_chunk_fetched_once():
    same = jnp.asarray(np.arange(2048.0, dtype=np.float32))
    tree = {f"dup{i}": same for i in range(8)}
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, tree, plane=PAR)
    store.get_count = 0
    out, _ = restore(store, "p", plane=PAR)
    # 1 manifest get + exactly 1 fetch of the single shared CAS chunk
    assert store.get_count == 2
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(same))


def test_restore_tiny_prefetch_window_no_duplicate_fetches():
    """With a prefetch window smaller than one chunk, assembly overtakes
    the queue and force-submits; stale queue entries must not be
    resubmitted after release (regression: double-fetch + window leak)."""
    tree = {f"leaf{i}": jnp.asarray(np.full(512, float(i + 1), np.float32))
            for i in range(8)}
    tree["dupA"] = tree["dupB"] = jnp.asarray(np.full(512, -1.0, np.float32))
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, tree, plane=PAR)
    store.get_count = 0
    plane = DataPlaneConfig(fetch_workers=4, max_inflight_bytes=1)
    out, _ = restore(store, "p", plane=plane)
    # 1 manifest get + exactly one fetch per distinct decode (9: 8 unique
    # leaves + the shared dup chunk) — no duplicate fetches
    assert store.get_count == 10
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_restore_same_bytes_different_shape_and_dtype():
    """Byte-identical chunks shared by leaves of different shape/dtype map
    to ONE CAS key but distinct decodes — the restore cache must not hand
    one leaf's decode to another (regression: cache was keyed by CAS key
    alone)."""
    tree = {"flat": jnp.zeros(1024, jnp.float32),
            "grid": jnp.zeros((32, 32), jnp.float32),
            "ints": jnp.zeros(1024, jnp.int32)}     # same 4096 zero bytes
    store = InMemoryStore()
    man = save_checkpoint(store, "p", 1, tree, plane=PAR)
    keys = {li.chunks[0].key for li in man.leaves.values()}
    assert len(keys) == 1                           # truly one shared chunk
    out, _ = restore(store, "p", plane=PAR)
    assert np.asarray(out["flat"]).shape == (1024,)
    assert np.asarray(out["grid"]).shape == (32, 32)
    assert np.asarray(out["ints"]).dtype == np.int32
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_two_tier_multistream_durability_and_flush():
    local = InMemoryStore()
    remote = InMemoryStore(latency_s=0.001)
    tt = TwoTierStore(local, remote, upload_streams=4)
    tree = _tree(4)
    save_checkpoint(tt, "p", 1, tree, plane=PAR)    # flush()es inside
    assert tt.pending_uploads() == 0                # condition-var drain
    tt.drop_local()                                 # host loses fast tier
    out, _ = restore(tt, "p", plane=PAR)
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))
    tt.close()


def test_two_tier_flush_surfaces_upload_error():
    class FailingRemote(InMemoryStore):
        def __init__(self):
            super().__init__()
            self.failed = False

        def put(self, key, data):
            if not self.failed and key.endswith("boom"):
                self.failed = True
                raise IOError("remote down")
            super().put(key, data)

    remote = FailingRemote()
    tt = TwoTierStore(InMemoryStore(), remote, upload_streams=3)
    tt.put("x/boom", b"1")
    with pytest.raises(IOError, match="remote down"):
        tt.flush()                      # surfaces the error AND re-queues
    tt.flush()                          # transient failure healed …
    assert remote.exists("x/boom")      # … and the chunk IS remote now:
    tt.close()                          # no clean flush before durability


def test_blocking_save_gc_serialized_with_async_writer():
    """A blocking save (+ its GC sweep) on a prefix with an async writer
    must run AFTER any in-flight async save: sweeping concurrently would
    reap chunks the in-flight save has put but not yet committed, then
    commit a manifest pointing at reaped keys."""
    from types import SimpleNamespace

    from repro.core.checkpoint_manager import CheckpointManager

    store = InMemoryStore(latency_s=0.001)
    mgr = CheckpointManager({"default": store}, plane=PAR)
    coord = SimpleNamespace(
        coord_id="c1", ckpt_prefix="p",
        asr=SimpleNamespace(name="app", policy=SimpleNamespace(
            store="default", codec="raw", keep_last=2, keep_every=0,
            plane=None)))
    trees = {s: _tree(100 + s, n_leaves=6) for s in (1, 2, 3, 4)}
    for s in (1, 2):
        mgr.save(coord, s, trees[s], blocking=False)
    mgr.save(coord, 3, trees[3], blocking=False)   # in flight on slow store
    mgr.save(coord, 4, trees[4], blocking=True)    # + GC(keep_last=2)
    mgr.wait(coord)
    from repro.ckpt import list_steps
    for s in list_steps(store, "p"):               # every committed step
        out, _ = restore(store, "p", s, plane=PAR)  # must fully restore
        for k, v in trees[s].items():
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(v))
    mgr.delete_all(coord)


def test_async_checkpointer_parallel_counters_and_gc():
    from repro.ckpt import gc as ckpt_gc
    store = InMemoryStore()
    ck = AsyncCheckpointer(store, "p", plane=PAR)
    tree = _tree(5, n_leaves=8)

    def on_commit(_step):
        ckpt_gc.collect(store, "p", keep_last=2)
    for s in (1, 2, 3):
        ck.save(s, tree, on_commit=on_commit)
    ck.wait()
    st = ck.stats()
    assert st["dedup_misses"] == 8                  # first save only
    assert st["dedup_hits"] == 16                   # 8 chunks x 2 resaves
    for s in (2, 3):
        out, _ = restore(store, "p", s, plane=PAR)
        for k, v in tree.items():
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(v))
    ck.close()
