"""Regression tests for scripts/bench_diff.py (ISSUE 9 satellite).

The baseline differ is itself a CI gate, so its failure modes — vanished
rows, drifted invariant metrics, insane values — need coverage against a
fixture baseline, not just the live benchmarks.
"""
import importlib.util
import json
import os

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                       "bench_diff.py")
_spec = importlib.util.spec_from_file_location("bench_diff", _SCRIPT)
bench_diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_diff)


def _write(dirpath, name, rows):
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "rows": [
            {"param": p, "metric": m, "value": v} for p, m, v in rows
        ]}, f)
    return path


BASE_ROWS = [
    ("host_slowdown", "detection_s", 20.0),
    ("host_slowdown", "telemetry_detected", 1.0),   # exact metric
    ("default", "overhead_frac", 0.01),
    ("default", "overhead_ok", 1.0),                # exact metric
]


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "baselines"
    fresh = tmp_path / "fresh"
    _write(str(base), "fx", BASE_ROWS)
    return str(base), str(fresh)


def test_identical_rows_pass(dirs, capsys):
    base, fresh = dirs
    _write(fresh, "fx", BASE_ROWS)
    assert bench_diff.diff_one("fx", base, fresh) == 0
    assert "ok   fx: 4 rows match (2 exact)" in capsys.readouterr().out


def test_inexact_metric_may_drift(dirs):
    base, fresh = dirs
    rows = [(p, m, 37.5 if m == "detection_s" else v)
            for p, m, v in BASE_ROWS]
    _write(fresh, "fx", rows)                       # timing drift is fine
    assert bench_diff.diff_one("fx", base, fresh) == 0


def test_missing_row_fails(dirs, capsys):
    base, fresh = dirs
    _write(fresh, "fx", BASE_ROWS[:-1])             # one row vanished
    assert bench_diff.diff_one("fx", base, fresh) == 1
    assert "row disappeared: default,overhead_ok" in capsys.readouterr().out


def test_extra_row_fails(dirs, capsys):
    base, fresh = dirs
    _write(fresh, "fx", BASE_ROWS + [("new", "surprise", 1.0)])
    assert bench_diff.diff_one("fx", base, fresh) == 1
    assert "unexpected new row" in capsys.readouterr().out


def test_regressed_exact_metric_fails(dirs, capsys):
    base, fresh = dirs
    rows = [(p, m, 0.0 if m == "telemetry_detected" else v)
            for p, m, v in BASE_ROWS]
    _write(fresh, "fx", rows)
    assert bench_diff.diff_one("fx", base, fresh) == 1
    assert "invariant metric drifted" in capsys.readouterr().out


def test_insane_inexact_value_fails(dirs, capsys):
    base, fresh = dirs
    rows = [(p, m, -0.5 if m == "overhead_frac" else v)
            for p, m, v in BASE_ROWS]
    _write(fresh, "fx", rows)                       # negative timing metric
    assert bench_diff.diff_one("fx", base, fresh) == 1
    assert "not a sane value" in capsys.readouterr().out


def test_missing_fresh_file_fails(dirs, capsys):
    base, fresh = dirs
    os.makedirs(fresh, exist_ok=True)
    assert bench_diff.diff_one("fx", base, fresh) == 1
    assert "fresh run produced no BENCH_fx.json" in capsys.readouterr().out


def test_committed_baselines_declare_their_exact_metrics():
    # every committed baseline should gate at least one invariant — the
    # differ otherwise degrades to a row-coverage check only
    bdir = os.path.join(os.path.dirname(_SCRIPT), os.pardir, "benchmarks",
                        "baselines")
    names = [f for f in os.listdir(bdir)
             if f.startswith("BENCH_") and f.endswith(".json")]
    assert names
    for fname in names:
        rows = bench_diff._load(os.path.join(bdir, fname))
        assert any(m in bench_diff.EXACT_METRICS for _, m in rows), fname
