"""Trainer, optimizer, data pipeline: determinism + correctness."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import InMemoryStore, restore, save_checkpoint
from repro.configs import get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.train import (AdamWConfig, TrainerApp, adamw_init, adamw_update,
                         lr_at)

CFG = dataclasses.replace(reduced(get_config("repro-100m")), dtype="float32")


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """TrainerApp timing rides active_clock(); run the suite on the shared
    discrete-event clock like every other timed suite. The train thread
    itself never sleeps on the clock, so pacing is unchanged — only the
    service-side daemons/waits go virtual."""
    yield


def test_pipeline_deterministic_and_checkpointable():
    p1 = TokenPipeline(CFG, 4, 16, seed=3)
    batches = [p1.next() for _ in range(5)]
    # resume from state after 2 batches
    p2 = TokenPipeline(CFG, 4, 16, seed=3)
    p2.next(), p2.next()
    state = p2.state_dict()
    p3 = TokenPipeline(CFG, 4, 16, seed=99)   # wrong seed, fixed by state
    p3.load_state_dict(state)
    for i in range(2, 5):
        np.testing.assert_array_equal(p3.next()["tokens"],
                                      batches[i]["tokens"])


def test_pipeline_batches_cover_vocab_range():
    p = TokenPipeline(CFG, 4, 64)
    b = p.next()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < CFG.vocab_size
    assert b["targets"][:, -1].max() == -1          # last target masked


def test_adamw_against_manual_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0, warmup_steps=0, total_steps=10,
                      schedule="constant")
    params = {"w": jnp.asarray([[1.0, 2.0]])}      # 2D => decay-eligible
    grads = {"w": jnp.asarray([[0.5, -0.5]])}
    st = adamw_init(params)
    new_p, st2, _ = adamw_update(cfg, grads, st, params)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"])[0, 0], expect,
                               rtol=1e-5)
    assert int(st2["count"]) == 1


def test_grad_clip_caps_update_norm():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    params = {"w": jnp.zeros((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    _, st, metrics = adamw_update(cfg, grads, adamw_init(params), params)
    assert float(metrics["grad_norm"]) > 100
    # effective m is built from clipped grads
    assert float(jnp.abs(st["m"]["w"]).max()) <= (1 - 0.9) * 1.0 + 1e-6


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine", min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (0, 9, 10, 60, 109)]
    assert lrs[0] < 0.2                      # warmup start
    assert abs(lrs[2] - 1.0) < 0.06          # warmup end
    assert lrs[3] < lrs[2]                   # decaying
    assert abs(lrs[4] - 0.1) < 0.03          # floor


def test_loss_decreases_over_training():
    app = TrainerApp(CFG, global_batch=4, seq_len=32, n_steps=40,
                     opt=AdamWConfig(lr=1e-2, warmup_steps=3,
                                     total_steps=40))
    app.start(None, None)
    while not app.is_done():
        time.sleep(0.05)
    app.stop()
    first = np.mean(app.losses[:5])
    last = np.mean(app.losses[-5:])
    assert last < first - 0.5, f"no learning: {first:.3f} -> {last:.3f}"


def test_bit_exact_resume_through_checkpoint():
    straight = TrainerApp(CFG, global_batch=2, seq_len=32, n_steps=8)
    straight.start(None, None)
    while not straight.is_done():
        time.sleep(0.02)
    straight.stop()

    half = TrainerApp(CFG, global_batch=2, seq_len=32, n_steps=4)
    half.start(None, None)
    while not half.is_done():
        time.sleep(0.02)
    half.stop()
    store = InMemoryStore()
    save_checkpoint(store, "t", 4, half.checkpoint_state())
    snap, _ = restore(store, "t")

    resumed = TrainerApp(CFG, global_batch=2, seq_len=32, n_steps=8)
    resumed.start(None, snap)
    while not resumed.is_done():
        time.sleep(0.02)
    resumed.stop()
    assert resumed.losses[-1] == straight.losses[-1], "resume not bit-exact"


def test_health_hook_detects_nan():
    app = TrainerApp(CFG, global_batch=2, seq_len=16, n_steps=5)
    app.start(None, None)
    while not app.is_done():
        time.sleep(0.02)
    app.stop()
    assert app.healthy()
    app.last_loss = float("nan")
    app.losses.append(float("nan"))
    assert not app.healthy()
