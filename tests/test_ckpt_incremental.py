"""Incremental content-addressed checkpointing: dedup on the write path,
mark-and-sweep GC over shared chunks, legacy-manifest compatibility, and
end-to-end chunk integrity."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, InMemoryStore, list_steps,
                        restore, save_checkpoint)
from repro.ckpt import gc as ckpt_gc
from repro.ckpt.layout import (COMMITTED, MANIFEST, cas_prefix,
                               step_prefix)
from repro.ckpt.reader import load_manifest


def _tree(scale=1.0):
    return {"w": jnp.arange(4096.0) * scale,
            "opt": {"m": jnp.ones(512), "v": jnp.ones(512) * 2},
            "step_count": 7}


def test_identical_resave_writes_only_manifest_and_marker():
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, _tree())
    puts_before = store.put_count
    bytes_before = store.bytes_in
    man = save_checkpoint(store, "p", 2, _tree())
    # exactly MANIFEST.json + COMMITTED — zero data chunks
    assert store.put_count - puts_before == 2
    keys_written = {k for k in store.list(step_prefix("p", 2))}
    assert keys_written == {f"{step_prefix('p', 2)}/{MANIFEST}",
                            f"{step_prefix('p', 2)}/{COMMITTED}"}
    dd = man.metadata["dedup"]
    assert dd["bytes_written"] == 0
    assert dd["dedup_misses"] == 0
    assert dd["dedup_hits"] == dd["chunks"] == 4
    # manifest+marker are tiny next to the deduped payload
    assert store.bytes_in - bytes_before < dd["bytes_deduped"] / 4
    out, _ = restore(store, "p", 2)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4096.0))


def test_partial_update_writes_only_dirty_chunks():
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, _tree())
    t = _tree()
    t["opt"]["m"] = jnp.ones(512) * 3              # dirty exactly one leaf
    man = save_checkpoint(store, "p", 2, t)
    dd = man.metadata["dedup"]
    assert dd["dedup_misses"] == 1
    assert dd["dedup_hits"] == 3
    assert dd["bytes_written"] == 512 * 4
    out, _ = restore(store, "p", 2)
    np.testing.assert_array_equal(np.asarray(out["opt"]["m"]),
                                  np.full(512, 3.0, np.float32))
    # step 1 still restores the old value (chunks weren't overwritten)
    out1, _ = restore(store, "p", 1)
    np.testing.assert_array_equal(np.asarray(out1["opt"]["m"]),
                                  np.ones(512, np.float32))


def test_identical_leaves_share_one_chunk():
    store = InMemoryStore()
    man = save_checkpoint(store, "p", 1,
                          {"a": jnp.ones(256), "b": jnp.ones(256)})
    assert man.leaves["a"].chunks[0].key == man.leaves["b"].chunks[0].key
    assert man.metadata["dedup"]["dedup_misses"] == 1


def test_gc_keeps_shared_chunks_and_sweeps_orphans():
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, _tree())        # w, m, v, step_count
    t2 = _tree()
    t2["opt"]["m"] = jnp.ones(512) * 9             # new chunk at step 2
    save_checkpoint(store, "p", 2, t2)
    n_cas = len(store.list(cas_prefix("p")))
    deleted = ckpt_gc.collect(store, "p", keep_last=1)
    assert deleted == [1]
    # step 1's unique chunk (old m) swept; the 3 shared chunks survive
    assert len(store.list(cas_prefix("p"))) == n_cas - 1
    assert list_steps(store, "p") == [2]
    out, _ = restore(store, "p")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4096.0))
    np.testing.assert_array_equal(np.asarray(out["opt"]["m"]),
                                  np.full(512, 9.0, np.float32))
    # idempotent: nothing left to sweep
    assert ckpt_gc.sweep_orphans(store, "p") == []


def test_gc_refcount_shared_across_retained_steps():
    store = InMemoryStore()
    for s in (1, 2, 3):
        save_checkpoint(store, "p", s, _tree())    # all steps share chunks
    ckpt_gc.collect(store, "p", keep_last=2)       # drops step 1 only
    for s in (2, 3):
        out, _ = restore(store, "p", s)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(4096.0))


def test_legacy_full_save_still_works_and_loads():
    store = InMemoryStore()
    man = save_checkpoint(store, "p", 1, _tree(), incremental=False)
    assert man.version == 1
    assert all(c.hash is None for li in man.leaves.values()
               for c in li.chunks)
    assert not store.list(cas_prefix("p"))         # chunks live in step dir
    out, _ = restore(store, "p")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4096.0))
    # incremental save on top of a legacy one: no hashes to dedup against
    man2 = save_checkpoint(store, "p", 2, _tree())
    assert man2.metadata["dedup"]["dedup_misses"] == 4


def test_pre_hash_manifest_json_loads():
    """Manifests written before ChunkInfo.hash / Manifest.version exist."""
    store = InMemoryStore()
    save_checkpoint(store, "p", 1, {"x": jnp.arange(16.0)},
                    incremental=False)
    sp = step_prefix("p", 1)
    d = json.loads(store.get(f"{sp}/{MANIFEST}").decode())
    del d["version"]
    for li in d["leaves"].values():
        for c in li["chunks"]:
            del c["hash"]
    store.put(f"{sp}/{MANIFEST}", json.dumps(d).encode())
    man = load_manifest(store, "p", 1)
    assert man.version == 1
    assert man.leaves["x"].chunks[0].hash is None
    out, _ = restore(store, "p")
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(16.0))


def test_corrupt_chunk_detected_by_digest():
    store = InMemoryStore()
    man = save_checkpoint(store, "p", 1, {"x": jnp.arange(16.0)})
    key = man.leaves["x"].chunks[0].key
    store.put(key, store.get(key)[:-4] + b"\x00\x00\x00\x00")
    with pytest.raises(ValueError, match="digest mismatch"):
        restore(store, "p")


def test_async_checkpointer_dedup_counters_and_cache():
    store = InMemoryStore()
    ck = AsyncCheckpointer(store, "p", codec="zlib")
    tree = _tree()
    ck.save(1, tree)
    ck.wait()
    puts_after_first = store.put_count
    for s in (2, 3):
        ck.save(s, tree)
    ck.wait()
    st = ck.stats()
    assert st["dedup_hits"] == 8                   # 4 chunks x 2 resaves
    # resaves put only manifest+marker
    assert store.put_count - puts_after_first == 4
    # the raw cache served the hits: store never even saw the content again
    assert store.dedup_hits == 0
    ck.close()
    for s in (1, 2, 3):
        out, _ = restore(store, "p", s)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(4096.0))


def test_async_cache_survives_gc_of_old_steps():
    """A chunk swept by GC must not be served from a stale writer cache."""
    store = InMemoryStore()
    ck = AsyncCheckpointer(store, "p")
    a, b = {"x": jnp.ones(256)}, {"x": jnp.ones(256) * 2}

    def on_commit(_step):
        ckpt_gc.collect(store, "p", keep_last=1)
    ck.save(1, a, on_commit=on_commit)
    ck.save(2, b, on_commit=on_commit)             # GC sweeps step 1's chunk
    ck.save(3, a, on_commit=on_commit)             # content of step 1 returns
    ck.wait()
    out, _ = restore(store, "p", 3)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.ones(256, np.float32))
    ck.close()


def test_delete_image_invalidates_writer_dedup_cache():
    """CheckpointManager.delete_image sweeps shared chunks; a later save of
    the same content must re-upload them, not dedup against reaped keys."""
    from types import SimpleNamespace

    from repro.core.checkpoint_manager import CheckpointManager

    store = InMemoryStore()
    mgr = CheckpointManager({"default": store})
    coord = SimpleNamespace(
        coord_id="c1", ckpt_prefix="p",
        asr=SimpleNamespace(name="app", policy=SimpleNamespace(
            store="default", codec="raw", keep_last=0, keep_every=0)))
    tree = {"x": jnp.ones(256)}
    mgr.save(coord, 1, tree, blocking=False)
    mgr.wait(coord)
    mgr.delete_image(coord, 1)                     # sweeps x's only chunk
    assert store.list(cas_prefix("p")) == []
    mgr.save(coord, 2, tree, blocking=False)       # same content returns
    mgr.wait(coord)
    out = mgr.load(coord, 2)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.ones(256, np.float32))
    mgr.delete_all(coord)


def test_cross_prefix_clone_dedups_on_ingest():
    """upload_image-style copy: chunk resolution goes through the manifest."""
    src = InMemoryStore()
    save_checkpoint(src, "a", 1, _tree())
    man = load_manifest(src, "a", 1)
    dst = InMemoryStore()
    for key in man.chunk_refs():
        dst.put_if_absent("b" + key[len("a"):], src.get(key))
    sp = step_prefix("b", 1)
    dst.put(f"{sp}/{MANIFEST}",
            man.to_json().replace("a/", "b/").encode())
    dst.put(f"{sp}/{COMMITTED}", b"1")
    out, _ = restore(dst, "b")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4096.0))
