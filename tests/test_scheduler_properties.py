"""Property-based invariant suite for the cloud-spanning GlobalScheduler.

For random seeded workloads (priorities, sizes, arrival order, home
clouds — drawn through ``WorkloadTrace.generate``) the scheduler must
uphold, at quiescence:

  (a) **capacity safety** — allocated VMs never exceed any cloud's
      capacity, and every RUNNING job holds exactly the VMs it asked for;
  (b) **priority work-conservation** — no job waits (QUEUED/SUSPENDED)
      that could fit on an allowed cloud, either in free capacity or by
      preempting strictly-lower-priority running work;
  (c) **no starvation** — with aging enabled and capacity turning over,
      every submitted job eventually reaches RUNNING or TERMINATED.

Runs under real hypothesis when installed, else the seeded in-repo shim.
``SCHED_PROP_EXAMPLES`` shrinks the example budget (CI smoke)."""
import os

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # bare env: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.ckpt import InMemoryStore
from repro.clusters import OpenStackBackend, SnoozeBackend
from repro.core import (ASR, CACSService, CheckpointPolicy, CoordState,
                        GlobalScheduler, SimulatedApp, WorkloadTrace)
from repro.sim import active_clock

import pytest


@pytest.fixture(autouse=True)
def _virtual_time(sim_clock):
    """Run this suite on the discrete-event virtual clock (repro.sim)."""
    yield


MAX_EXAMPLES = int(os.environ.get("SCHED_PROP_EXAMPLES", "6"))
N_HOSTS = {"snooze": 5, "openstack": 4}


def _build(aging_rate=0.0):
    backends = {"snooze": SnoozeBackend(n_hosts=N_HOSTS["snooze"]),
                "openstack": OpenStackBackend(n_hosts=N_HOSTS["openstack"])}
    svc = CACSService(backends, {"default": InMemoryStore()})
    sched = GlobalScheduler(svc, aging_rate=aging_rate)
    svc.attach_scheduler(sched)
    return svc, sched, backends


def _asr(job):
    return ASR(name=job.name, n_vms=job.n_vms, backend=job.backend,
               priority=job.priority,
               app_factory=lambda: SimulatedApp(iter_time_s=0.5,
                                                state_mb=0.005),
               policy=CheckpointPolicy(period_s=0))


def _quiesce(sched, max_passes=400):
    for _ in range(max_passes):
        if sched.tick() == 0 and sched.inflight_depth == 0:
            return
        active_clock().sleep(0.01)  # placements finish on the background pool
    raise AssertionError("scheduler did not quiesce (placement ping-pong?)")


def _assert_capacity_safe(svc, backends):
    for name, backend in backends.items():
        running = [c for c in svc.db.list()
                   if c.state == CoordState.RUNNING
                   and c.asr.backend == name]
        allocated = sum(len(c.vms) for c in running)
        assert allocated <= backend.sim.n_hosts, \
            f"{name}: {allocated} VMs allocated over {backend.sim.n_hosts}"
        for c in running:
            assert len(c.vms) == c.asr.n_vms, \
                f"{c.asr.name} runs with {len(c.vms)}/{c.asr.n_vms} VMs"


def _assert_no_schedulable_waiter(svc, sched, backends):
    """Invariant (b): a waiting job fits nowhere — not in free capacity,
    not by preempting strictly-lower-priority runners (the scheduler's
    own placement condition, re-derived independently)."""
    coords = svc.db.list()
    for q in coords:
        if q.state not in (CoordState.QUEUED, CoordState.SUSPENDED):
            continue
        eff = sched.effective_priority(q)
        # no replication in this env: jobs holding images are home-bound
        has_image = (q.state == CoordState.SUSPENDED
                     or svc.ckpt.latest(q) is not None)
        allowed = ([q.asr.backend] if has_image
                   else [n for n in backends
                         if not q.asr.clouds or n in q.asr.clouds])
        for name in allowed:
            free = backends[name].capacity()
            assert free < q.asr.n_vms, \
                f"{q.asr.name} waits while {name} has {free} free"
            preemptable = sum(
                len(c.vms) for c in coords
                if c.state == CoordState.RUNNING and c.asr.backend == name
                and sched.defense_priority(c) < eff)
            assert free + preemptable < q.asr.n_vms, \
                (f"{q.asr.name} (eff {eff}) waits though preempting "
                 f"lower-priority work on {name} would fit it")


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_capacity_and_priority_invariants(seed):
    trace = WorkloadTrace.generate(
        seed, n_jobs=6, backends=("snooze", "openstack"), max_vms=4,
        max_priority=9)
    svc, sched, backends = _build()
    try:
        for job in trace.jobs:             # arrival order, synchronous
            sched.submit(_asr(job))
        _quiesce(sched)
        _assert_capacity_safe(svc, backends)
        _assert_no_schedulable_waiter(svc, sched, backends)
    finally:
        sched.stop()
        svc.shutdown()


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_invariants_hold_under_capacity_turnover(seed):
    """(a) + (b) must also hold at every quiescent point of a churning
    system: jobs finish (terminate) in seeded order and free capacity."""
    rng_order = WorkloadTrace.generate(seed + 1, n_jobs=5,
                                       backends=("snooze", "openstack"),
                                       max_vms=3)
    svc, sched, backends = _build()
    try:
        for job in rng_order.jobs:
            sched.submit(_asr(job))
        for _ in range(12):
            _quiesce(sched)
            _assert_capacity_safe(svc, backends)
            _assert_no_schedulable_waiter(svc, sched, backends)
            running = sorted(
                (c for c in svc.db.list()
                 if c.state == CoordState.RUNNING),
                key=lambda c: c.asr.name)
            if not running:
                break
            svc.delete_coordinator(running[0].coord_id)   # one job finishes
    finally:
        sched.stop()
        svc.shutdown()


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_no_starvation_with_aging(seed):
    """Invariant (c): with aging enabled and capacity turning over, every
    submitted job eventually reaches RUNNING or TERMINATED — nothing
    waits forever, whatever its priority."""
    trace = WorkloadTrace.generate(
        seed, n_jobs=6, backends=("snooze", "openstack"), max_vms=4,
        max_priority=9)
    svc, sched, backends = _build(aging_rate=5.0)
    try:
        cids = {sched.submit(_asr(job)): job.name for job in trace.jobs}
        ran = set()
        for _ in range(400):
            sched.tick()
            active_clock().sleep(0.01)
            running = [cid for cid in cids
                       if cid in {c.coord_id for c in svc.db.list()}
                       and svc.db.get(cid).state == CoordState.RUNNING]
            ran.update(running)
            for cid in sorted(running):
                svc.delete_coordinator(cid)   # finished: free its capacity
            if ran == set(cids):
                break
        assert ran == set(cids), \
            f"starved jobs: {[cids[c] for c in set(cids) - ran]}"
    finally:
        sched.stop()
        svc.shutdown()
