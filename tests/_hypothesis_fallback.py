"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The real library is preferred (install via ``pip install -e .[test]``, see
pyproject.toml). This shim keeps the property-based tests *runnable* in bare
environments by drawing a fixed number of pseudo-random examples from a
seeded RNG — no shrinking, no failure database, but the same assertions run
over a deterministic sample of the input space.

Only the strategy surface this repo uses is implemented: integers, floats,
sampled_from, lists, tuples.
"""
from __future__ import annotations


import random
from typing import Any, Callable, List


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda r: r.choice(seq))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(r: random.Random) -> List[Any]:
            return [elements._draw(r)
                    for _ in range(r.randint(min_size, max_size))]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(lambda r: tuple(e._draw(r) for e in elements))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = random.Random(0)                 # deterministic examples
            for _ in range(n):
                fn(*args, *(s._draw(rng) for s in strats), **kwargs)
        # NOT functools.wraps: pytest would unwrap to fn's signature and
        # mistake the strategy-filled parameters for fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco
