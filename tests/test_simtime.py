"""Virtual-time layer: EventQueue determinism, SimClock semantics,
wall-clock-leak regression pins, and cross-process replay determinism
(same seed → identical trace bytes under different PYTHONHASHSEED)."""
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.sim import (TIME_SCALE, EventQueue, SimClock, SimEngine,
                       WallClock, active_clock, install_clock, use_clock)


# ---------------------------------------------------------------------------
# EventQueue
# ---------------------------------------------------------------------------

def test_events_pop_in_time_order():
    q = EventQueue()
    q.schedule(3.0, "c")
    q.schedule(1.0, "a")
    q.schedule(2.0, "b")
    assert [q.pop().kind for _ in range(3)] == ["a", "b", "c"]
    assert q.pop() is None


def test_ties_break_fifo_by_schedule_order():
    q = EventQueue()
    for i in range(50):
        q.schedule(7.0, f"k{i}")
    assert [q.pop().kind for _ in range(50)] == [f"k{i}" for i in range(50)]


def test_cancel_removes_event():
    q = EventQueue()
    keep = q.schedule(1.0, "keep")
    drop = q.schedule(0.5, "drop")
    assert q.cancel(drop)
    assert not q.cancel(drop), "double-cancel must be a no-op"
    assert len(q) == 1
    assert q.pop() is keep
    assert q.pop() is None


def test_reschedule_moves_event_and_loses_fifo_slot():
    q = EventQueue()
    a = q.schedule(1.0, "a")
    b = q.schedule(1.0, "b")
    # moving a to the same time re-queues it AFTER b (new seq)
    q.reschedule(a, 1.0)
    assert a.cancelled
    assert [q.pop().kind for _ in range(2)] == ["b", "a"]


def test_peek_and_next_time_skip_cancelled():
    q = EventQueue()
    first = q.schedule(1.0, "first")
    q.schedule(2.0, "second")
    q.cancel(first)
    assert q.next_time() == 2.0
    assert q.peek().kind == "second"


# ---------------------------------------------------------------------------
# SimClock
# ---------------------------------------------------------------------------

@pytest.fixture
def clk():
    c = SimClock()
    yield c
    c.close()


def test_sleep_advances_virtual_not_wall(clk):
    t0_wall = time.monotonic()
    clk.paper_sleep(500.0)                     # 500 paper seconds
    wall = time.monotonic() - t0_wall
    assert clk.now() >= 500.0
    assert wall < 2.0, f"virtual sleep burned {wall:.2f}s of wall time"


def test_wall_tuned_sleep_maps_through_time_scale(clk):
    clk.sleep(0.05)                            # a historical wall knob
    assert clk.now() == pytest.approx(0.05 / TIME_SCALE)


def test_concurrent_sleepers_wake_in_deadline_order(clk):
    order = []
    lock = threading.Lock()

    def sleeper(dt):
        clk.paper_sleep(dt)
        with lock:
            order.append(dt)

    threads = [threading.Thread(target=sleeper, args=(dt,))
               for dt in (30.0, 10.0, 20.0)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert order == [10.0, 20.0, 30.0]


def test_wait_times_out_in_virtual_time(clk):
    ev = threading.Event()
    t0_wall = time.monotonic()
    assert clk.wait(ev, timeout=5.0) is False   # 5 wall-tuned = 500 virtual
    assert time.monotonic() - t0_wall < 2.0
    assert clk.now() >= 5.0 / TIME_SCALE


def test_wait_notices_set_event(clk):
    """A set() landing before the virtual deadline wins the wait.  The
    setter sleeps 100 virtual seconds; the waiter's timeout is 6000 — the
    earlier virtual deadline fires first, whatever the wall timing."""
    ev = threading.Event()

    def setter():
        clk.paper_sleep(100.0)
        ev.set()

    threading.Thread(target=setter, daemon=True).start()
    assert clk.wait(ev, timeout=60.0) is True  # 60 wall-tuned = 6000 virtual


def test_close_wakes_all_sleepers():
    c = SimClock(grace_s=10.0)                 # advancer effectively stuck
    done = threading.Event()

    def sleeper():
        c.paper_sleep(1e9)
        done.set()

    threading.Thread(target=sleeper, daemon=True).start()
    time.sleep(0.05)
    c.close()
    assert done.wait(2.0), "close() must release blocked sleepers"


def test_install_clock_restores_previous():
    wall = active_clock()
    c = SimClock()
    prev = install_clock(c)
    try:
        assert active_clock() is c
    finally:
        install_clock(prev)
        c.close()
    assert active_clock() is wall
    assert isinstance(wall, WallClock)


# ---------------------------------------------------------------------------
# wall-clock-leak regression pins (satellite: the port exposed these)
# ---------------------------------------------------------------------------

def test_monitor_poll_loop_pinned_to_virtual_time(sim_clock):
    """The monitor's poll-interval wait used to be a raw Event.wait on
    wall time; 40 polls at 50 ms would cost 2+ wall seconds.  On the
    virtual clock they must complete in well under that."""
    from repro.clusters import SnoozeBackend
    from repro.core.monitoring import MonitoringManager

    backend = SnoozeBackend(n_hosts=8)
    vms = backend.allocate_vms(4, None, owner="t")
    mon = MonitoringManager(lambda cid, kind: None, poll_interval_s=0.05)
    mon.watch("t", vms, lambda: True, native_notifications=True)
    mon.start()
    t0 = time.monotonic()
    try:
        while mon.heartbeats < 40 and time.monotonic() - t0 < 10:
            active_clock().sleep(0.01)
    finally:
        mon.stop()
    wall = time.monotonic() - t0
    assert mon.heartbeats >= 40
    assert wall < 1.5, f"poll loop leaked wall time: {wall:.2f}s for 40 polls"


def test_chaos_event_pacing_pinned_to_virtual_time(sim_clock):
    """The controller sleeps to each event's virtual offset; an event 200
    virtual seconds out used to cost 2 wall seconds of pacing alone."""
    from repro.core.chaos import FaultEvent, FaultKind, FaultSchedule, \
        run_scenario

    sched = FaultSchedule(seed=1, events=[
        FaultEvent(5.0, FaultKind.VM_CRASH, vm_index=0),
        FaultEvent(205.0, FaultKind.VM_CRASH, vm_index=1),
    ])
    t0 = time.monotonic()
    result = run_scenario(sched)
    wall = time.monotonic() - t0
    assert all(o.ok for o in result.outcomes)
    assert wall < 1.9, f"chaos pacing leaked wall time: {wall:.2f}s"


# ---------------------------------------------------------------------------
# replay determinism across processes (PYTHONHASHSEED-proof)
# ---------------------------------------------------------------------------

_REPLAY_SNIPPET = """
import sys
sys.path.insert(0, {src!r})
from repro.sim import SimEngine
eng = SimEngine(n_hosts=64, seed=1234, host_mtbf_s=40_000.0)
eng.load(n_jobs=300, horizon_s=20_000.0)
eng.run()
print(eng.trace_digest())
print(eng.completed, eng.events_fired)
"""


def _run_replay(hashseed: str) -> str:
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    r = subprocess.run(
        [sys.executable, "-c", _REPLAY_SNIPPET.format(src=os.path.abspath(src))],
        capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, f"replay subprocess failed:\n{r.stderr}"
    return r.stdout


def test_replay_identical_across_fresh_processes():
    """Same seed → byte-identical event trace in two fresh interpreters
    with different hash randomization (nothing may depend on dict/set
    iteration order)."""
    out_a = _run_replay("0")
    out_b = _run_replay("424242")
    assert out_a == out_b
    digest, counts = out_a.strip().splitlines()
    assert len(digest) == 64
    completed, fired = map(int, counts.split())
    assert completed > 0 and fired > completed


def test_engine_trace_replay_in_process():
    def build():
        eng = SimEngine(n_hosts=32, seed=9, host_mtbf_s=30_000.0)
        eng.load(n_jobs=200, horizon_s=10_000.0)
        eng.run()
        return eng

    a, b = build(), build()
    assert a.trace_bytes() == b.trace_bytes()
    assert a.trace_digest() == b.trace_digest()
