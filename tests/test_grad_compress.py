"""Cross-pod compressed gradient reduction (subprocess: 8 devices)."""
from tests.conftest import run_subprocess


def test_compressed_pod_reduction_matches_reference():
    run_subprocess("""
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.data.pipeline import TokenPipeline
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model
    from repro.sharding.specs import make_axes
    from repro.train import AdamWConfig, init_state, make_train_step
    from repro.train.grad_compress import make_compressed_train_step

    cfg = dataclasses.replace(reduced(get_config('internlm2-1.8b')),
                              dtype='float32')
    model = build_model(cfg)
    mesh = make_test_mesh((2, 2, 2), ('pod', 'data', 'model'))
    axes = make_axes(mesh)
    opt = AdamWConfig(warmup_steps=1, total_steps=4)
    ref_step = jax.jit(make_train_step(model, opt, axes=axes))
    pipe = TokenPipeline(cfg, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
    state0 = init_state(model, jax.random.PRNGKey(0))
    with mesh:
        s1, m1 = ref_step(state0, batch)

    def delta(s2):
        return max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(s1['params']),
                            jax.tree.leaves(s2['params'])))

    for codec, tol in (('none', 1e-5), ('bf16', 5e-3), ('int8', 1e-2)):
        step = jax.jit(make_compressed_train_step(
            model, opt, mesh, axes=axes, codec=codec))
        with mesh:
            s2, m2 = step(init_state(model, jax.random.PRNGKey(0)), batch)
        assert abs(float(m2['loss']) - float(m1['loss'])) < 1e-5
        assert delta(s2) < tol, (codec, delta(s2))
    print('OK')
    """, devices=8, timeout=560)
